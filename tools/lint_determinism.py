#!/usr/bin/env python
"""AST lint: flag unannotated float accumulation in the analytic models.

The repo's north star is bit-identical scoring across replay paths,
worker counts and batch sizes, and the analytic models (dram / sram /
timing / residency) feed the search's total order.  A float ``sum()``
re-associated by a refactor is exactly the kind of silent nondeterminism
that breaks oracle exactness, so every accumulation in those modules must
be *annotated*: a ``# det:`` pragma on (or immediately above) the call
stating why it is exact -- integer-exact operands, or a deliberately
fixed left-to-right reduction.

Allowed without a pragma: ``math.fsum`` (correctly-rounded) and
``np.cumsum`` (fixed sequential prefix scan).  Everything else that spells
``sum`` -- the builtin, ``np.sum``, ``.sum()`` method calls -- needs the
pragma.

Usage::

    python tools/lint_determinism.py            # lint the default modules
    python tools/lint_determinism.py FILE...    # lint specific files

Exit 1 when any unannotated accumulation is found.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT_TARGETS = [
    REPO / "src/repro/core/dram.py",
    REPO / "src/repro/core/sram.py",
    REPO / "src/repro/core/timing.py",
    REPO / "src/repro/core/residency.py",
]
PRAGMA = "# det:"
EXEMPT = {"fsum", "cumsum"}


def _call_name(node: ast.Call) -> str | None:
    """The accumulation-relevant name of a call: 'sum' for the builtin,
    the attribute name for np.sum / arr.sum() / math.fsum."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def lint_file(path: Path) -> list[str]:
    src = path.read_text()
    rel = path.relative_to(REPO) if path.is_relative_to(REPO) else path
    lines = src.splitlines()
    tree = ast.parse(src, filename=str(path))
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name in EXEMPT or name != "sum":
            continue
        # pragma anywhere on the call's own lines, or in the contiguous
        # comment block immediately above the statement
        lo = node.lineno - 1
        while lo > 0 and lines[lo - 1].strip().startswith("#"):
            lo -= 1
        hi = min(len(lines), (node.end_lineno or node.lineno))
        if any(PRAGMA in lines[i] for i in range(lo, hi)):
            continue
        findings.append(
            f"{rel}:{node.lineno}: unannotated "
            f"accumulation `{ast.unparse(node)[:70]}` -- add a "
            f"`{PRAGMA} <why this reduction is exact>` pragma or use "
            f"math.fsum")
    return findings


def main(argv: list[str]) -> int:
    targets = ([Path(a).resolve() for a in argv] if argv
               else DEFAULT_TARGETS)
    findings: list[str] = []
    for path in targets:
        if not path.exists():
            print(f"lint_determinism: {path} does not exist",
                  file=sys.stderr)
            return 2
        findings.extend(lint_file(path))
    for f in findings:
        print(f)
    if findings:
        print(f"lint_determinism: {len(findings)} unannotated "
              f"accumulation(s)", file=sys.stderr)
        return 1
    n = len(targets)
    print(f"lint_determinism: {n} module(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
