"""Tensorized allocator replay (kernels/alloc_scan.py + replay="device").

The device replay must reproduce the journal-based Python replay bit for
bit: same frame-mask matrix, same boundary-I/O matrix, same buffer
maxima / write-buffer max / DRAM boundary total / spill feasibility --
for every cut tuple of every zoo net, every batch shape, and every
alloc_scan backend (numpy reference, jax.lax.scan, Pallas interpret).
On top sit the engine-level contracts: ``score_batch(replay="device")``
is bit-identical to the ``evaluate`` oracle with unchanged memo /
``evaluations`` bookkeeping, and ``search(replay="device")`` returns
byte-identical SearchResults serial and parallel.  The AllocState
export/import round-trip that seeds the scan is covered last."""
import itertools
import random

import numpy as np
import pytest

from repro.cnn import build_cnn
from repro.core.allocator import (alloc_step, arrays_to_state, graph_steps,
                                  init_alloc_state, state_to_arrays)
from repro.core.cutpoint import (CutpointEngine, evaluate, monotone_runs,
                                 search, split_blocks)
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
from repro.kernels.alloc_scan import alloc_scan_ref, pack_alloc_tables

ALL_CNNS = ["vgg16-conv", "yolov2", "yolov3", "resnet50", "resnet152",
            "efficientnet-b1", "retinanet", "mobilenet-v3"]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]

_GG_CACHE: dict = {}


def _grouped(name):
    got = _GG_CACHE.get(name)
    if got is None:
        gg = group_nodes(build_cnn(name))
        blocks = split_blocks(gg)
        runs = monotone_runs(blocks)
        got = _GG_CACHE[name] = (gg, blocks, runs)
    return got


def _mixed_tuples(runs, n_prefix=25, n_random=25, seed=17):
    dims = [range(len(r) + 1) for r in runs]
    tuples = list(itertools.islice(itertools.product(*dims), n_prefix))
    rng = random.Random(seed)
    tuples += [tuple(rng.randint(0, len(r)) for r in runs)
               for _ in range(n_random)]
    tuples.append(tuple(0 for _ in runs))
    tuples.append(tuple(len(r) for r in runs))
    return tuples


def _journal_outputs(engine, tuples):
    """Frame masks + the engine's journal-fed extraction for each tuple."""
    n = len(engine.gg.groups)
    b = len(tuples)
    out = {
        "frame": np.zeros((b, n), dtype=bool),
        "io": np.zeros((b, n), dtype=np.int64),
        "buff": np.zeros((b, 3), dtype=np.int64),
        "side_buff": np.zeros(b, dtype=np.int64),
        "wrf": np.zeros(b, dtype=np.int64),
        "bfm": np.zeros(b, dtype=np.int64),
        "feasible": np.zeros(b, dtype=bool),
    }
    for j, cuts in enumerate(tuples):
        alloc = engine._replay(cuts)
        out["frame"][j] = engine._frame
        out["io"][j] = engine._x_io
        out["buff"][j] = alloc.buff
        out["side_buff"][j] = alloc.side_buff
        out["wrf"][j] = engine._x_wrf
        out["bfm"][j] = engine._x_bfm
        out["feasible"][j] = engine._x_feas
    return out


def _assert_scan_equal(res, journal, ctx):
    for field in ["io", "buff", "side_buff", "wrf", "bfm", "feasible"]:
        got = getattr(res, field)
        want = journal[field]
        assert np.array_equal(got, want), (
            f"{ctx}: {field} mismatch at "
            f"{np.argwhere(np.asarray(got) != np.asarray(want))[:4]}")


# ------------------------------------------------- replay-level bit-identity
@pytest.mark.parametrize("name", ALL_CNNS)
def test_device_replay_matches_journal(name):
    """Fuzzed oracle bit-identity: frame masks, boundary-IO matrix and all
    per-candidate extraction scalars, whole zoo."""
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs)
    journal = _journal_outputs(engine, tuples)
    frame = engine._frame_matrix(tuples)
    assert np.array_equal(frame, journal["frame"]), name
    res = alloc_scan_ref(pack_alloc_tables(gg, KCU1500), frame)
    _assert_scan_equal(res, journal, name)


def test_zoo_quantities_fit_int32():
    """The jax/pallas backends run in int32; every replayed quantity of
    every zoo net must stay far inside that range (the numpy reference is
    int64, so this guard is what licenses the narrower backends).  Mixed
    random tuples are the maximizers here -- the all-row/all-frame
    corners barely cross any boundary (all-row never fills a buffer,
    all-frame rarely writes one out), so sampling only them would bound
    ~half the real worst case."""
    lim = 2 ** 31 - 1
    for name in ALL_CNNS:
        gg, blocks, runs = _grouped(name)
        engine = CutpointEngine(gg, KCU1500, blocks, runs)
        tuples = _mixed_tuples(runs, n_prefix=10, n_random=40, seed=13)
        res = alloc_scan_ref(pack_alloc_tables(gg, KCU1500),
                             engine._frame_matrix(tuples))
        worst = max(int(res.io.max(initial=0)), int(res.buff.max()),
                    int(res.bfm.max()), int(res.wrf.max()),
                    int(res.side_buff.max()))
        assert worst < lim // 4, (name, worst)


# -------------------------------------------------------- backend equality
@pytest.mark.parametrize("name", ["resnet50", "retinanet"])
def test_scan_backend_matches_reference(name):
    """jax.lax.scan replay == numpy reference, including a spilling net."""
    pytest.importorskip("jax")
    from repro.kernels.alloc_scan import alloc_scan_jax
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=12, n_random=12, seed=5)
    frame = engine._frame_matrix(tuples)
    tables = pack_alloc_tables(gg, KCU1500)
    journal = _journal_outputs(engine, tuples)
    _assert_scan_equal(alloc_scan_jax(tables, frame), journal, name)


@pytest.mark.parametrize("name", ["vgg16-conv", "resnet50"])
def test_pallas_backend_matches_reference(name):
    """Pallas interpret-mode replay == numpy reference (integer-exact,
    unlike the float32 scoring kernel)."""
    pytest.importorskip("jax")
    from repro.kernels.alloc_scan import alloc_scan_pallas
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=8, n_random=8, seed=2)
    frame = engine._frame_matrix(tuples)
    tables = pack_alloc_tables(gg, KCU1500)
    journal = _journal_outputs(engine, tuples)
    res = alloc_scan_pallas(tables, frame, interpret=True, block_b=8)
    _assert_scan_equal(res, journal, name)


# -------------------------------------------------- engine-level contracts
@pytest.mark.parametrize("name", ALL_CNNS)
def test_score_batch_device_matches_oracle(name):
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=15, n_random=15, seed=23)
    scored = engine.score_batch(tuples, memoize=False, replay="device")
    assert engine.evaluations == len(tuples)
    for cuts, fast in zip(tuples, scored):
        oracle = evaluate(gg, blocks, runs, cuts, KCU1500)
        for f in METRICS:
            assert getattr(oracle, f) == getattr(fast, f), (
                f"{name} cuts={cuts}: {f} {getattr(oracle, f)!r} != "
                f"{getattr(fast, f)!r}")


def test_device_b1_and_ragged_batches():
    gg, blocks, runs = _grouped("yolov2")
    tuples = _mixed_tuples(runs, n_prefix=9, n_random=10, seed=31)  # 21
    one = CutpointEngine(gg, KCU1500, blocks, runs, replay="device")
    singles = [one.score_batch([c], memoize=False)[0] for c in tuples]
    ragged = []
    re = CutpointEngine(gg, KCU1500, blocks, runs, replay="device")
    for i in range(0, len(tuples), 8):                  # 21 = 8 + 8 + 5
        ragged.extend(re.score_batch(tuples[i:i + 8], memoize=False))
    for cuts, a, b in zip(tuples, singles, ragged):
        oracle = evaluate(gg, blocks, runs, cuts, KCU1500)
        for f in METRICS:
            assert getattr(oracle, f) == getattr(a, f), (cuts, f)
            assert getattr(oracle, f) == getattr(b, f), (cuts, f)


def test_device_memo_bookkeeping_matches_journal():
    """Cache hits served, in-batch duplicates scored once, memo shared
    with evaluate -- and the stored metrics are the journal-exact ones."""
    gg, blocks, runs = _grouped("resnet50")
    engine = CutpointEngine(gg, KCU1500, blocks, runs, replay="device")
    t0 = tuple(0 for _ in runs)
    t1 = tuple(min(1, len(r)) for r in runs)
    t2 = tuple(len(r) for r in runs)
    warm = engine.evaluate(t0)                 # journal replay into memo
    n0 = engine.evaluations
    got = engine.score_batch([t0, t1, t1, t2])
    assert got[0] is warm
    assert got[1] is got[2]
    assert engine.evaluations == n0 + 2
    assert engine.evaluate(t1) is got[1]
    assert engine.evaluations == n0 + 2
    # journal engine scoring the same batch stores equal metrics
    ref = CutpointEngine(gg, KCU1500, blocks, runs)
    ref_got = ref.score_batch([t0, t1, t1, t2])
    for a, b in zip(got, ref_got):
        for f in METRICS:
            assert getattr(a, f) == getattr(b, f), f


def test_device_and_journal_interleave_on_one_engine():
    """Device batches must not disturb the journal checkpoints: alternate
    paths on one engine and check every result against the oracle."""
    gg, blocks, runs = _grouped("retinanet")
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=6, n_random=6, seed=41)
    for i, cuts in enumerate(tuples):
        if i % 2:
            got = engine.score_batch([cuts], memoize=False,
                                     replay="device")[0]
        else:
            got = engine.evaluate(cuts, memoize=False)
        oracle = evaluate(gg, blocks, runs, cuts, KCU1500)
        for f in METRICS:
            assert getattr(oracle, f) == getattr(got, f), (cuts, f)


# -------------------------------------------------- search-level contracts
def test_search_device_bit_identity_exhaustive():
    gg, _, _ = _grouped("resnet50")
    a = search(gg, KCU1500)
    b = search(gg, KCU1500, CompileOptions(engine="device"))
    assert a.best.cuts == b.best.cuts
    assert a.evaluated == b.evaluated
    for f in METRICS:
        assert getattr(a.best, f) == getattr(b.best, f), f
    assert a.best.policy == b.best.policy
    assert a.best.alloc.buff == b.best.alloc.buff


def test_search_device_bit_identity_descent():
    gg, _, _ = _grouped("mobilenet-v3")
    a = search(gg, KCU1500)
    b = search(gg, KCU1500, CompileOptions(engine="device"))
    assert a.best.cuts == b.best.cuts
    assert a.evaluated == b.evaluated
    for f in METRICS:
        assert getattr(a.best, f) == getattr(b.best, f), f


def test_search_parallel_device_bit_identity():
    gg, _, _ = _grouped("resnet50")
    serial = search(gg, KCU1500)
    parallel = search(gg, KCU1500,
                      CompileOptions(workers=2, engine="device"))
    assert serial.best.cuts == parallel.best.cuts
    assert serial.evaluated == parallel.evaluated
    for f in METRICS:
        assert getattr(serial.best, f) == getattr(parallel.best, f), f


# --------------------------------------------------- state export round-trip
def _states_equal(a, b):
    return (a.remaining == b.remaining
            and a.location == b.location
            and a.live_in_buffer == b.live_in_buffer
            and a.alloc.buff == b.alloc.buff
            and a.alloc.side_buff == b.alloc.side_buff
            and a.alloc.spilled == b.alloc.spilled
            and a.alloc.boundary_writes == b.alloc.boundary_writes
            and a.alloc.boundary_reads == b.alloc.boundary_reads)


@pytest.mark.parametrize("name", ["resnet50", "retinanet",
                                  "efficientnet-b1"])
def test_state_roundtrip_mid_replay(name):
    """Export/import at every quartile of an allocator walk must (a)
    reproduce the state exactly and (b) keep replaying to the same final
    allocation as the original."""
    gg, blocks, runs = _grouped(name)
    from repro.core.cutpoint import policy_from_cuts
    rng = random.Random(9)
    cuts = tuple(rng.randint(0, len(r)) for r in runs)
    policy = policy_from_cuts(gg, blocks, runs, cuts)
    steps = graph_steps(gg)
    for frac in (0, 1, 2, 3):
        stop = len(steps) * frac // 4
        state = init_alloc_state(gg, lean=True)
        for s in steps[:stop]:
            alloc_step(state, s, policy[s.gid])
        state.j_writes.clear()
        state.j_reads.clear()
        state.j_spills.clear()
        back = arrays_to_state(state_to_arrays(state))
        assert _states_equal(state, back), (name, stop)
        for s in steps[stop:]:
            alloc_step(state, s, policy[s.gid])
            alloc_step(back, s, policy[s.gid])
        assert _states_equal(state, back), (name, stop, "after continue")
