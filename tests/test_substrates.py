"""Substrate tests: data pipeline determinism, checkpoint round-trip +
atomic commit, optimizer behaviour, gradient compression, fault tolerance
(preempt -> restart -> identical trajectory)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.checkpoint import latest_step, restore, save
from repro.data.pipeline import DataConfig, Pipeline, SyntheticSource
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               schedule)
from repro.optim.compression import compress, decompress, init_error_state


# ------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_restartable():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=1000, seed=3)
    src = SyntheticSource(cfg)
    b5a = src.batch_at(5)
    b5b = SyntheticSource(cfg).batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(src.batch_at(6)["tokens"], b5a["tokens"])
    # labels are next-token shifted
    full_a = src.batch_at(5)
    assert np.array_equal(full_a["labels"][:, :-1], full_a["tokens"][:, 1:])


def test_synthetic_data_host_sharding_disjoint():
    a = SyntheticSource(DataConfig(seq_len=8, global_batch=8, vocab=500,
                                   n_hosts=2, host_id=0)).batch_at(0)
    b = SyntheticSource(DataConfig(seq_len=8, global_batch=8, vocab=500,
                                   n_hosts=2, host_id=1)).batch_at(0)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_fast_forward_matches_replay():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=100)
    p1 = Pipeline(cfg)
    it1 = iter(p1)
    seq = [next(it1)["tokens"] for _ in range(5)]
    p1.close()
    p2 = Pipeline(cfg)
    p2.fast_forward(3)
    got = next(iter(p2))["tokens"]
    p2.close()
    np.testing.assert_array_equal(got, seq[3])


def test_bin_token_source(tmp_path):
    path = tmp_path / "toks.bin"
    np.arange(10_000, dtype=np.uint32).tofile(path)
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=50_000,
                     path=str(path))
    from repro.data.pipeline import BinTokenSource
    src = BinTokenSource(cfg)
    b = src.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ------------------------------------------------------------- checkpoint
def tree_example(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32),
                       "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = tree_example()
    save(tree, tmp_path, step=12)
    assert latest_step(tmp_path) == 12
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = restore(abstract, tmp_path, 12)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, back)


def test_checkpoint_uncommitted_ignored(tmp_path):
    tree = tree_example()
    save(tree, tmp_path, step=5)
    d = tmp_path / "step_000000009"
    d.mkdir()
    (d / "host_0.ckpt").write_bytes(b"partial garbage")
    assert latest_step(tmp_path) == 5          # 9 has no COMMITTED marker


def test_checkpoint_latest_of_many(tmp_path):
    for s in (10, 30, 20):
        save(tree_example(s), tmp_path, step=s)
    assert latest_step(tmp_path) == 30


# ---------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"x": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum(p["x"] ** 2)

    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 0.2


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) < 0.11
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) <= 0.11


def test_gradient_clipping_applied():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"x": jnp.zeros(4)}
    state = init_opt_state(params)
    huge = {"x": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e5      # reported pre-clip


# -------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 1000))
def test_compression_error_feedback_bounds_error(scale, seed):
    g = scale * jax.random.normal(jax.random.key(seed), (64,))
    grads = {"g": g}
    err = init_error_state(grads)
    q, s, new_err = compress(grads, err)
    rec = decompress(q, s)
    resid = np.asarray(grads["g"] - rec["g"])
    # quantization error bounded by scale/2 per element
    assert np.max(np.abs(resid)) <= float(s["g"]) * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(new_err["g"]), resid, rtol=1e-5,
                               atol=1e-6)


def test_compression_accumulates_small_signals():
    """Error feedback must eventually transmit a signal smaller than one
    quantization step."""
    grads = {"g": jnp.full((4,), 1e-4)}
    big = {"g": jnp.zeros(4).at[0].set(1.0)}     # sets scale ~ 1/127
    err = init_error_state(grads)
    total = np.zeros(4)
    for i in range(100):
        g = {"g": grads["g"] + (big["g"] if i == 0 else 0)}
        q, s, err = compress(g, err)
        total += np.asarray(decompress(q, s)["g"])
    # 100 steps of 1e-4 = 1e-2 signal + the initial spike
    assert total[1] > 5e-3


# ---------------------------------------------------- fault tolerance (e2e)
def test_preempt_restart_identical_trajectory(tmp_path):
    """Train 6 steps straight vs train 3 + preempt + restore + 3 more:
    identical final loss (deterministic pipeline + checkpoint restore)."""
    from repro.configs import smoke_config
    from repro.data.pipeline import DataConfig
    from repro.launch.train import TrainConfig, train

    cfg = smoke_config("smollm-360m").replace(max_seq=16)
    dc = DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab)
    tc = dict(log_every=1, ckpt_every=3,
              ckpt_dir=str(tmp_path / "a"))
    outA = train(cfg, TrainConfig(steps=6, **tc), data_cfg=dc)

    tcB = dict(log_every=1, ckpt_every=3, ckpt_dir=str(tmp_path / "b"))
    train(cfg, TrainConfig(steps=3, **tcB), data_cfg=dc)
    outB = train(cfg, TrainConfig(steps=6, **tcB), data_cfg=dc)
    lossA = dict(outA["losses"])
    lossB = dict(outB["losses"])
    for s in (3, 4, 5):
        assert abs(lossA[s] - lossB[s]) < 1e-4, (s, lossA[s], lossB[s])


def test_async_checkpointer_survives_donation(tmp_path):
    """The async snapshot must not alias device buffers that the next
    (donating) step deletes."""
    import jax
    from repro.checkpoint.checkpoint import AsyncCheckpointer

    @jax.jit
    def bump(t):
        return jax.tree.map(lambda x: x + 1, t)

    bump_donating = jax.jit(lambda t: jax.tree.map(lambda x: x + 1, t),
                            donate_argnums=(0,))
    state = {"w": jnp.arange(1024.0)}
    ck = AsyncCheckpointer(tmp_path)
    ck.save(state, 1)
    state = bump_donating(state)       # donates the saved buffers
    ck.wait()
    assert latest_step(tmp_path) == 1
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    back = restore(abstract, tmp_path, 1)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.arange(1024.0))
