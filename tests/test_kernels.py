"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes/dtypes (+ hypothesis fuzzing of block shapes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_block import fused_block
from repro.kernels.ref import (flash_attention_ref, fused_block_ref,
                               ssd_scan_ref)
from repro.kernels.ssd_scan import ssd_scan

jax.config.update("jax_enable_x64", False)


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.key(key), shape)).astype(
        dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------------- fused block
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,f,bm,bf", [
    (64, 128, 256, 32, 128),
    (128, 96, 384, 64, 96),
    (256, 64, 128, 256, 128),
])
@pytest.mark.parametrize("gated,act,sandwich", [
    (True, "silu", False), (True, "gelu", True), (False, "gelu", False),
])
def test_fused_block_matches_ref(dtype, m, d, f, bm, bf, gated, act,
                                 sandwich):
    x = rnd(0, (m, d), dtype)
    scale = rnd(1, (d,), jnp.float32, 0.1)
    post = rnd(5, (d,), jnp.float32, 0.1)
    wg = rnd(2, (d, f), dtype, d ** -0.5)
    wu = rnd(3, (d, f), dtype, d ** -0.5)
    wd = rnd(4, (f, d), dtype, f ** -0.5)
    out = fused_block(x, scale, wg, wu, wd, post, act=act, gated=gated,
                      sandwich=sandwich, block_m=bm, block_f=bf,
                      interpret=True)
    ref = fused_block_ref(x, scale, wg, wu, wd, post, act=act, gated=gated,
                          sandwich=sandwich)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@settings(max_examples=12, deadline=None)
@given(n_m=st.integers(1, 4), n_f=st.integers(1, 4),
       bm=st.sampled_from([16, 32, 64]), bf=st.sampled_from([64, 128]))
def test_fused_block_block_shape_sweep(n_m, n_f, bm, bf):
    """Property: result is independent of the VMEM tiling."""
    d = 64
    m, f = n_m * bm, n_f * bf
    x = rnd(10, (m, d))
    scale = rnd(11, (d,), scale=0.1)
    wg = rnd(12, (d, f), scale=d ** -0.5)
    wu = rnd(13, (d, f), scale=d ** -0.5)
    wd = rnd(14, (f, d), scale=f ** -0.5)
    out = fused_block(x, scale, wg, wu, wd, block_m=bm, block_f=bf,
                      interpret=True)
    ref = fused_block_ref(x, scale, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,T,NH,NKV,hd,bq,bk", [
    (2, 128, 128, 4, 2, 32, 64, 64),       # GQA causal
    (1, 64, 64, 2, 1, 64, 32, 32),         # MQA
    (2, 128, 128, 2, 2, 16, 128, 32),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 32, 0.0), (True, 0, 50.0), (False, 0, 0.0),
])
def test_flash_matches_ref(dtype, B, S, T, NH, NKV, hd, bq, bk,
                           causal, window, softcap):
    q = rnd(0, (B, S, NH, hd), dtype)
    k = rnd(1, (B, T, NKV, hd), dtype)
    v = rnd(2, (B, T, NKV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=bq, block_k=bk,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_matches_model_blocked_attention():
    """The Pallas kernel, the jnp oracle and the model's blocked_attention
    must agree."""
    from repro.models.attention import blocked_attention
    q = rnd(0, (2, 128, 4, 32))
    k = rnd(1, (2, 128, 2, 32))
    v = rnd(2, (2, 128, 2, 32))
    a = flash_attention(q, k, v, causal=True, interpret=True)
    b = blocked_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(bq=st.sampled_from([16, 32, 64, 128]),
       bk=st.sampled_from([16, 32, 64, 128]),
       window=st.sampled_from([0, 16, 48]))
def test_flash_block_shape_sweep(bq, bk, window):
    q = rnd(20, (1, 128, 2, 32))
    k = rnd(21, (1, 128, 2, 32))
    v = rnd(22, (1, 128, 2, 32))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=bq, block_k=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- SSD
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,S,P,N,G,chunk", [
    (4, 64, 16, 8, 1, 16),
    (6, 128, 8, 16, 2, 32),
    (2, 32, 32, 32, 1, 32),
])
def test_ssd_scan_matches_sequential_ref(dtype, BH, S, P, N, G, chunk):
    BG = G * 1                              # one batch row per group here
    hg = BH // BG
    x = rnd(0, (BH, S, P), dtype)
    dt = jax.nn.softplus(rnd(1, (BH, S))).astype(jnp.float32)
    A = -jnp.exp(rnd(2, (BH, 1), scale=0.2)).astype(jnp.float32)
    D = rnd(3, (BH, 1)).astype(jnp.float32)
    Bm = rnd(4, (BG, S, N), dtype)
    Cm = rnd(5, (BG, S, N), dtype)
    out = ssd_scan(x, dt, A, D, Bm, Cm, chunk=chunk, nheads=hg,
                   interpret=True)
    ref = ssd_scan_ref(x, dt, A, D, Bm, Cm)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 \
        else dict(rtol=4e-2, atol=4e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_ssd_kernel_matches_model_chunked():
    """Kernel vs the model's lax.scan SSD (models/mamba2.ssd_chunked)."""
    from repro.models.mamba2 import ssd_chunked
    b, s, h, p, n = 2, 64, 4, 8, 16
    x = rnd(0, (b, s, h, p))
    dt = jax.nn.softplus(rnd(1, (b, s, h))).astype(jnp.float32)
    A = -jnp.exp(rnd(2, (h,), scale=0.2)).astype(jnp.float32)
    D = rnd(3, (h,)).astype(jnp.float32)
    Bm = rnd(4, (b, s, 1, n))
    Cm = rnd(5, (b, s, 1, n))
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)

    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s)
    Ak = jnp.tile(A[None, :], (b, 1)).reshape(b * h, 1)
    Dk = jnp.tile(D[None, :], (b, 1)).reshape(b * h, 1)
    Bk = Bm[:, :, 0, :]
    Ck = Cm[:, :, 0, :]
    y_kern = ssd_scan(xk, dtk, Ak, Dk, Bk, Ck, chunk=16, nheads=h,
                      interpret=True)
    y_kern = y_kern.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ RG-LRU
@pytest.mark.parametrize("B,S,W,q,bw", [
    (2, 64, 32, 16, 32), (1, 128, 64, 64, 32), (3, 32, 16, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_matches_model_scan(B, S, W, q, bw, dtype):
    from repro.kernels.rglru_scan import rglru_scan_kernel
    from repro.models.rglru import rglru_scan
    a = jax.nn.sigmoid(rnd(0, (B, S, W))).astype(dtype)
    b = rnd(1, (B, S, W), dtype)
    ref = rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32))
    out = rglru_scan_kernel(a, b, chunk=q, block_w=bw, interpret=True)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 \
        else dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@settings(max_examples=8, deadline=None)
@given(q=st.sampled_from([8, 16, 32]), bw=st.sampled_from([16, 32]))
def test_rglru_kernel_block_sweep(q, bw):
    from repro.kernels.rglru_scan import rglru_scan_kernel
    from repro.models.rglru import rglru_scan
    a = jax.nn.sigmoid(rnd(5, (2, 64, 32)))
    b = rnd(6, (2, 64, 32))
    out = rglru_scan_kernel(a, b, chunk=q, block_w=bw, interpret=True)
    ref = rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
