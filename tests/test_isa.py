"""ISA encoding hardening: round-trip fidelity and refusal to truncate.

Pins the encode-overflow bugfix: a field past its slot width used to be
silently masked (``& 0xFF`` etc.), emitting a corrupted-but-plausible
stream; ``encode()`` now raises ``ValueError``.  ``decode_stream()``
likewise rejects streams whose length is not a multiple of the 11-word
instruction size, and ``decode()`` rejects a bad terminator word.
Round-trip property tests (hypothesis, optional) prove every in-range
instruction survives encode -> decode bit-exactly.
"""
import numpy as np
import pytest

from repro.cnn import build_cnn
from repro.core.compiler import compile_graph
from repro.core.options import CompileOptions
from repro.core.isa import (ACTS, FIELD_WIDTHS, MODES, OFFCHIP, OPCODES,
                            WORDS, GroupInstruction, decode_stream,
                            encode_stream, field_overflows)
from tests.hypothesis_compat import given, settings, st


def _instr(**overrides) -> GroupInstruction:
    base = dict(gid=7, opcode=OPCODES["conv"], mode=MODES["frame"], k=3,
                stride=1, in_ch=64, out_ch=128, in_h=56, in_w=56,
                act=ACTS["relu"], fused_pool=0, fused_eltwise=1,
                fused_upsample=0, alloc_in=0, alloc_out=1,
                alloc_shortcut=2, src_main=6, src_shortcut=3)
    base.update(overrides)
    return GroupInstruction(**base)


# ------------------------------------------------------------- round trips
def test_round_trip_basic():
    i = _instr()
    j = GroupInstruction.decode(i.encode())
    assert i == j


def test_round_trip_sentinels():
    i = _instr(src_main=-1, src_shortcut=-1, fused_eltwise=0,
               alloc_in=OFFCHIP, alloc_out=OFFCHIP, alloc_shortcut=OFFCHIP)
    assert GroupInstruction.decode(i.encode()) == i


_small = {name: st.integers(min_value=0,
                            max_value=(1 << width) - 1)
          for name, width in FIELD_WIDTHS.items() if width < 32}
_wide = {name: st.integers(min_value=0, max_value=(1 << 32) - 1)
         for name, width in FIELD_WIDTHS.items() if width == 32}
_signed = st.integers(min_value=-(1 << 31), max_value=(1 << 31) - 1)


@settings(max_examples=200, deadline=None)
@given(fields=st.fixed_dictionaries({**_small, **_wide,
                                     "src_main": _signed,
                                     "src_shortcut": _signed}))
def test_round_trip_property(fields):
    """Any instruction whose fields all fit their slots round-trips
    bit-exactly through the 11-word encoding."""
    i = GroupInstruction(**fields)
    j = GroupInstruction.decode(i.encode())
    assert i == j


@settings(max_examples=100, deadline=None)
@given(name=st.sampled_from(sorted(n for n, w in FIELD_WIDTHS.items()
                                   if w < 32)),
       excess=st.integers(min_value=0, max_value=1 << 20))
def test_encode_overflow_raises_property(name, excess):
    """Any unsigned field one-past (or further past) its slot width must
    raise, never silently truncate."""
    i = _instr(**{name: (1 << FIELD_WIDTHS[name]) + excess})
    with pytest.raises(ValueError, match=name):
        i.encode()


# ------------------------------------------------------- overflow refusal
@pytest.mark.parametrize("name", sorted(n for n, w in FIELD_WIDTHS.items()
                                        if w < 32))
def test_encode_overflow_raises_each_field(name):
    i = _instr(**{name: 1 << FIELD_WIDTHS[name]})
    with pytest.raises(ValueError, match=f"field {name}="):
        i.encode()


@pytest.mark.parametrize("name", sorted(FIELD_WIDTHS))
def test_encode_negative_unsigned_raises(name):
    with pytest.raises(ValueError, match=f"field {name}="):
        _instr(**{name: -1}).encode()


@pytest.mark.parametrize("name,value", [("src_main", 1 << 31),
                                        ("src_shortcut", -(1 << 31) - 1)])
def test_encode_signed_overflow_raises(name, value):
    with pytest.raises(ValueError, match="signed 32-bit"):
        _instr(**{name: value}).encode()


def test_field_overflows_boundaries():
    assert not field_overflows("k", (1 << 8) - 1)
    assert field_overflows("k", 1 << 8)
    assert not field_overflows("src_main", -(1 << 31))
    assert field_overflows("src_main", 1 << 31)


# ----------------------------------------------------- stream validation
def test_decode_stream_rejects_misaligned():
    stream = encode_stream([_instr()])
    with pytest.raises(ValueError, match="multiple"):
        decode_stream(stream[:-1])
    with pytest.raises(ValueError, match="multiple"):
        decode_stream(np.concatenate([stream, stream[:5]]))


def test_decode_rejects_bad_terminator():
    w = _instr().encode()
    w[10] = 0xDEAD
    with pytest.raises(ValueError, match="terminator"):
        GroupInstruction.decode(w)


def test_zoo_stream_round_trip():
    """A real compiled plan's full stream round-trips instruction-exactly
    (this covers the sentinel encodings -1/-1 and OFFCHIP fields at
    scale)."""
    plan = compile_graph(build_cnn("resnet50", 224),
                         options=CompileOptions(exhaustive_limit=50_000))
    stream = encode_stream(plan.instructions)
    assert stream.size == WORDS * len(plan.instructions)
    assert decode_stream(stream) == plan.instructions
