import sys
from pathlib import Path

# Make src/ importable without requiring PYTHONPATH=src (CI sets it anyway),
# and the repo root for the benchmarks/ namespace package, so tests run from
# any cwd / launcher.
_root = Path(__file__).resolve().parent.parent
for _p in (_root / "src", _root):
    if str(_p) not in sys.path:
        sys.path.insert(0, str(_p))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
