import os
import sys
from pathlib import Path

# Make src/ importable without requiring PYTHONPATH=src (CI sets it anyway),
# and the repo root for the benchmarks/ namespace package, so tests run from
# any cwd / launcher.
_root = Path(__file__).resolve().parent.parent
for _p in (_root / "src", _root, _root / "tests"):
    if str(_p) not in sys.path:
        sys.path.insert(0, str(_p))

from hypothesis_compat import HAVE_HYPOTHESIS, st  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
    # The repo's own call sites are fully migrated to CompileOptions; any
    # legacy knob kwarg reaching resolve_options() from the test suite is
    # a regression, so the deprecation warning is promoted to an error.
    config.addinivalue_line(
        "filterwarnings", "error::repro.core.options.LegacyKnobWarning")


if HAVE_HYPOTHESIS:
    # Fuzz budgets: tier-1 runs the small "ci" profile so the suite stays
    # fast; the nightly CI job exports REPRO_FUZZ_PROFILE=nightly for the
    # full budget.  Tests that want the profile budget simply omit
    # max_examples from their @settings.
    from hypothesis import settings as _hsettings
    _hsettings.register_profile("ci", max_examples=20, deadline=None)
    _hsettings.register_profile("nightly", max_examples=250, deadline=None)
    _hsettings.load_profile(os.environ.get("REPRO_FUZZ_PROFILE", "ci"))


# --------------------------------------------------- shared graph strategy
# One definition of "small random residual CNN" for every property suite
# (tests/test_property_compiler.py, tests/test_branch_bound.py): sequential
# conv chains with random residual adds (including fan-out: one entry
# feeding two shortcut adds), pools and upsamples (so monotone runs vary in
# length *and* direction), and random kernel/channel choices.  Returns a
# validated ``repro.core.ir.Graph``; callers group it themselves
# (``group_nodes``) so they can also fuzz the policy / cut layer on top.
@st.composite
def random_cnn(draw):
    """Random small residual CNN graph with shortcut edges."""
    from repro.core.ir import Graph, make_input

    g = Graph("prop")
    size = draw(st.sampled_from([16, 32, 64]))
    make_input(g, size, size)
    n_blocks = draw(st.integers(2, 7))
    ch = draw(st.sampled_from([8, 16]))
    g.add("conv", out_ch=ch, k=3, act="relu")
    for _ in range(n_blocks):
        kind = draw(st.sampled_from(
            ["plain", "residual", "residual", "pool", "upsample", "fanout"]))
        if kind == "plain":
            g.add("conv", out_ch=ch, k=draw(st.sampled_from([1, 3])),
                  act="relu")
        elif kind == "pool":
            if g.nodes[-1].out_h >= 4:
                g.add("maxpool", k=2, stride=2)
        elif kind == "upsample":
            if g.nodes[-1].out_h <= 32:
                g.add("upsample", stride=2)
        elif kind == "fanout":
            # one entry is the shortcut operand of TWO adds: fan-out > 1
            # on the shortcut edge, two residual blocks sharing a source
            entry = g.nodes[-1]
            g.add("conv", out_ch=ch, k=1, act="relu")
            g.add("conv", out_ch=ch, k=3, act="linear")
            g.add("add", inputs=[len(g.nodes) - 1, entry.idx])
            g.add("conv", out_ch=ch, k=3, act="linear")
            g.add("add", inputs=[len(g.nodes) - 1, entry.idx])
        else:
            entry = g.nodes[-1]
            n_conv = draw(st.integers(1, 3))
            for i in range(n_conv):
                g.add("conv", out_ch=ch, k=draw(st.sampled_from([1, 3])),
                      act="linear" if i == n_conv - 1 else "relu")
            g.add("add", inputs=[len(g.nodes) - 1, entry.idx])
    g.validate()
    return g
