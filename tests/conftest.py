import sys
from pathlib import Path

# Make src/ importable without requiring PYTHONPATH=src (CI sets it anyway).
_src = Path(__file__).resolve().parent.parent / "src"
if str(_src) not in sys.path:
    sys.path.insert(0, str(_src))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
