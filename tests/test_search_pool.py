"""Parallel cut-space search: bit-identity with serial + failure modes.

``search(workers=N)`` must return a ``SearchResult`` bit-identical to the
serial path on every zoo CNN -- same winning Candidate (cuts, metrics,
policy, allocation), same ``evaluated`` count, same runs/blocks -- on both
the partitioned-exhaustive path and the per-start coordinate-descent
fallback.  Worker failures must surface as errors in the parent, never as
hangs or silently-wrong results.
"""
import itertools
import multiprocessing as mp

import pytest

from repro.cnn import build_cnn
from repro.core import search_pool
from repro.core.cutpoint import search
from repro.core.options import CompileOptions
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.search_pool import ParallelSearchDriver, partition_space

ALL_CNNS = ["vgg16-conv", "yolov2", "yolov3", "resnet50", "resnet152",
            "efficientnet-b1", "retinanet", "mobilenet-v3"]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]

# Keeps the test exhaustive on resnet50/152 (space 8748, partitioned
# across workers) while yolov2/yolov3/efficientnet/retinanet/mobilenet
# exercise the parallel coordinate-descent fallback -- the same split the
# default 8M limit produces, minus yolov2's quarter-hour exhaustive walk.
TEST_LIMIT = 200_000

HAS_FORK = "fork" in mp.get_all_start_methods()


def assert_results_identical(serial, parallel, ctx=""):
    assert serial.best.cuts == parallel.best.cuts, ctx
    for f in METRICS:
        assert getattr(serial.best, f) == getattr(parallel.best, f), (
            f"{ctx}: {f} serial={getattr(serial.best, f)!r} "
            f"parallel={getattr(parallel.best, f)!r}")
    assert serial.best.policy == parallel.best.policy, ctx
    assert serial.best.alloc.buff == parallel.best.alloc.buff, ctx
    assert serial.best.alloc.spilled == parallel.best.alloc.spilled, ctx
    assert (serial.best.alloc.boundary_writes
            == parallel.best.alloc.boundary_writes), ctx
    assert (serial.best.alloc.boundary_reads
            == parallel.best.alloc.boundary_reads), ctx
    assert serial.evaluated == parallel.evaluated, ctx
    assert serial.runs == parallel.runs, ctx
    assert serial.blocks == parallel.blocks, ctx


@pytest.mark.parametrize("name", ALL_CNNS)
def test_parallel_matches_serial(name):
    gg = group_nodes(build_cnn(name))
    serial = search(gg, KCU1500, CompileOptions(exhaustive_limit=TEST_LIMIT))
    parallel = search(gg, KCU1500,
                      CompileOptions(exhaustive_limit=TEST_LIMIT, workers=2))
    assert_results_identical(serial, parallel, ctx=name)


def test_parallel_matches_serial_forced_coordinate_descent():
    """exhaustive_limit=1 forces the descent fallback even on a small
    space: one worker task per deterministic start, ties broken by start
    order, evaluated = |union of per-start visited tuples|."""
    gg = group_nodes(build_cnn("resnet50", 224))
    serial = search(gg, KCU1500, CompileOptions(exhaustive_limit=1))
    parallel = search(gg, KCU1500,
                      CompileOptions(exhaustive_limit=1, workers=2))
    assert_results_identical(serial, parallel, ctx="forced-descent")


def test_parallel_exhaustive_below_min_space_cutoff():
    """Forcing the pool onto a tiny space (min_parallel_space=1) must
    still merge to the serial product-order argmin."""
    gg = group_nodes(build_cnn("vgg16-conv", 224))
    serial = search(gg, KCU1500)
    with ParallelSearchDriver(workers=2) as driver:
        parallel = driver.search(gg, KCU1500, min_parallel_space=1)
    assert_results_identical(serial, parallel, ctx="tiny-exhaustive")


def test_partition_space_is_disjoint_ordered_cover():
    runs = [[0, 1], [2], [3, 4, 5], [6, 7]]
    prefixes, suffix_dims = partition_space(runs, target_tasks=5)
    assert len(prefixes) >= 5
    dims = [range(len(r) + 1) for r in runs]
    full = list(itertools.product(*dims))
    covered = [p + s for p in prefixes
               for s in itertools.product(*[range(d + 1)
                                            for d in suffix_dims])]
    assert covered == full            # disjoint, complete, product order

    # degenerate: target larger than the space -> one task per tuple
    prefixes, suffix_dims = partition_space(runs, target_tasks=10**9)
    assert suffix_dims == []
    assert prefixes == full


def test_driver_map_is_ordered_and_reusable():
    with ParallelSearchDriver(workers=2) as driver:
        assert driver.map(abs, [-3, 1, -2]) == [3, 1, 2]
        # the same pool serves a search afterwards
        gg = group_nodes(build_cnn("resnet50", 224))
        result = driver.search(gg, KCU1500)
        assert result.best.feasible
        assert driver.map(abs, [-1]) == [1]


def test_invalid_objective_rejected_before_dispatch():
    """CompileOptions validates eagerly, so an invalid objective raises in
    the caller before any worker is touched (deterministic worker
    exceptions themselves are covered by the fault-tolerance suite)."""
    with pytest.raises(ValueError):
        CompileOptions(objective="bogus")


@pytest.mark.skipif(not HAS_FORK, reason="fork start method required to "
                    "inject the crash hook into workers")
def test_worker_hard_crash_surfaces_as_runtime_error():
    """A worker that dies without raising (os._exit) must surface as a
    RuntimeError naming the pool -- not hang -- and the driver must be
    usable again once the fault is gone."""
    gg = group_nodes(build_cnn("resnet50", 224))
    driver = ParallelSearchDriver(workers=2, mp_context="fork")
    search_pool._TEST_FAIL_HOOK = "exit"
    try:
        with pytest.raises(RuntimeError, match="worker process died"):
            driver.search(gg, KCU1500)
    finally:
        search_pool._TEST_FAIL_HOOK = None
    try:
        result = driver.search(gg, KCU1500)      # fresh pool, healthy
        assert_results_identical(search(gg, KCU1500), result, ctx="revive")
    finally:
        driver.close()


@pytest.mark.skipif(not HAS_FORK, reason="fork start method required to "
                    "inject the crash hook into workers")
def test_worker_raised_hook_propagates():
    gg = group_nodes(build_cnn("resnet50", 224))
    search_pool._TEST_FAIL_HOOK = "raise"
    try:
        with pytest.raises(RuntimeError, match="simulated worker failure"):
            with ParallelSearchDriver(workers=2, mp_context="fork") as d:
                d.search(gg, KCU1500)
    finally:
        search_pool._TEST_FAIL_HOOK = None
