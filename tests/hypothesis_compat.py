"""Optional-hypothesis shim for the test suite.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).  When it
is installed, this module re-exports the real ``given`` / ``settings`` /
``strategies``.  When it is missing, property-based tests are skipped
individually (via a ``@given`` replacement that applies ``pytest.mark.skip``)
while the rest of the module still collects and runs -- the suite must never
fail collection over a missing dev extra.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed "
                       "(pip install -r requirements-dev.txt)")(fn)
        return deco

    class settings:  # noqa: N801 - mirrors hypothesis.settings
        def __init__(self, *_args, **_kwargs):
            pass

        def __call__(self, fn):
            return fn

    class _Strategy:
        """Stands in for any strategy object/constructor; every attribute
        access, call, or combinator returns another inert strategy."""

        def __call__(self, *_args, **_kwargs):
            return self

        def __getattr__(self, _name):
            return self

    class _Strategies:
        def __getattr__(self, _name):
            return _Strategy()

    st = _Strategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
