"""Dry-mode memory-simulator audit of the analytical DRAM model.

``Simulator(execute=False)`` walks the compiled instruction stream
against the explicit memory model and counts every DRAM byte; the
analytical model (core/dram.py, eqs. (8)-(9)) must agree exactly -- for
every zoo net's *compiled* plan and for the all-row / all-frame corner
policies.  This cross-check is what exposed (and now pins) the
standalone row-mode ``add`` double count: ``row_fm_bytes`` charged the
second operand both as the fused-shortcut term and as an extra-operand
read, while the hardware does 2 reads + 1 write."""
import pytest

from repro.cnn import build_cnn
from repro.core.compiler import (all_frame_policy, all_row_policy,
                                 compile_graph)
from repro.core.grouping import group_nodes
from repro.core.options import CompileOptions
from repro.core.simulator import simulate

ZOO = [("vgg16-conv", 224), ("yolov2", 416), ("yolov3", 416),
       ("resnet50", 224), ("resnet152", 224), ("efficientnet-b1", 256),
       ("retinanet", 512), ("mobilenet-v3", 224)]

# Keeps detector-scale searches on the coordinate-descent path so the
# whole-zoo audit stays a tier-1-friendly few seconds; the plan is a real
# optimizer output either way.
AUDIT_LIMIT = 50_000
AUDIT_OPTS = CompileOptions(exhaustive_limit=AUDIT_LIMIT)


def _audit(plan, ctx):
    _, counters = simulate(plan.grouped, plan.alloc, plan.instructions,
                           execute=False)
    assert counters.weight_reads == plan.dram.weight_bytes, ctx
    assert counters.fm_total == plan.dram.fm_bytes, (
        f"{ctx}: simulator {counters.fm_total} != model "
        f"{plan.dram.fm_bytes} (drift "
        f"{counters.fm_total - plan.dram.fm_bytes:+d})")


@pytest.mark.parametrize("name,size", ZOO)
def test_fm_counters_match_model_on_compiled_plan(name, size):
    plan = compile_graph(build_cnn(name, size), options=AUDIT_OPTS)
    _audit(plan, f"{name}@{size} optimized")


@pytest.mark.parametrize("name,size", ZOO)
def test_fm_counters_match_model_on_corner_policies(name, size):
    g = build_cnn(name, size)
    gg = group_nodes(g)
    for policy_fn in (all_row_policy, all_frame_policy):
        plan = compile_graph(g, policy=policy_fn(gg))
        _audit(plan, f"{name}@{size} {policy_fn.__name__}")


@pytest.mark.parametrize("name,size", ZOO)
def test_compiled_plan_verifies_strict(name, size):
    """Every zoo net's compiled plan passes the static verifier with zero
    error-severity diagnostics (``verify="strict"``); the only tolerated
    warning class is the advisory BRAM bank count (SF031), which the
    optimizer's feasibility contract deliberately does not constrain and
    which mirrors the plan's own ``sram_report``."""
    plan = compile_graph(build_cnn(name, size),
                         options=AUDIT_OPTS.replace(verify="strict"))
    assert [d for d in plan.diagnostics if d.severity.value == "error"] \
        == []
    assert {d.code for d in plan.diagnostics} <= {"SF031"}, (
        f"{name}@{size}: unexpected warnings "
        f"{[d.render() for d in plan.diagnostics]}")


@pytest.mark.parametrize("name,size", [("yolov2", 416), ("resnet50", 224)])
def test_compiled_plan_verifies_strict_device_replay(name, size):
    """The device-replay search path produces the same verifiable plan:
    strict verification holds on both allocator replay engines."""
    plan = compile_graph(build_cnn(name, size),
                         options=AUDIT_OPTS.replace(engine="device",
                                                    verify="strict"))
    assert [d for d in plan.diagnostics if d.severity.value == "error"] \
        == []


def test_dry_run_counts_no_dangling_reads():
    """The dynamic twin of the static availability checks: a healthy
    plan's dry run never reads a DRAM tensor nothing wrote."""
    plan = compile_graph(build_cnn("retinanet", 512), options=AUDIT_OPTS)
    _, counters = simulate(plan.grouped, plan.alloc, plan.instructions,
                           execute=False)
    assert counters.dangling_reads == 0
