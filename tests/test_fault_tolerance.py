"""Fault-tolerant search runtime: every failure path, deterministically.

The contract under test (core/search_pool.py "Failure semantics"): task
results are pure functions of (token, sub-space), so retry after worker
death, transient-error re-dispatch, straggler duplicates, device-replay
fallback, journal resume and preemption drain must all merge to a
``SearchResult`` byte-identical to the clean serial run -- same cuts,
same metrics, same ``evaluated`` -- with every recovery surfaced on
``result.events``, and the genuine error paths (exhausted retries,
corrupt journal, deterministic worker exceptions) must raise, never hang
or silently degrade.  All injected faults come from the seeded chaos
harness (runtime/chaos.py), so each scenario reproduces exactly.
"""
import contextlib
import hashlib
import multiprocessing as mp
import signal

import pytest

from repro.cnn import build_cnn
from repro.core import search_pool
from repro.core.compiler import compile_graph
from repro.core.cutpoint import monotone_runs, search, split_blocks
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
from repro.core.search_pool import (TASKS_PER_WORKER, ParallelSearchDriver,
                                    SearchPreempted, partition_space)
from repro.runtime import chaos
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerMonitor

from test_search_pool import TEST_LIMIT, assert_results_identical

HAS_FORK = "fork" in mp.get_all_start_methods()
needs_fork = pytest.mark.skipif(
    not HAS_FORK, reason="fork start method required for workers to "
    "inherit the parent-installed chaos injector")

# Zoo slice for the fuzz sweep: resnet50/152 take the partitioned
# exhaustive path at TEST_LIMIT, the rest the per-start descent path, so
# both task shapes get fuzzed.
FUZZ_CNNS = ["vgg16-conv", "yolov3", "resnet50", "resnet152",
             "efficientnet-b1", "retinanet", "mobilenet-v3"]

TEST_OPTS = CompileOptions(exhaustive_limit=TEST_LIMIT)


@contextlib.contextmanager
def injected(injector):
    chaos.install(injector)
    try:
        yield injector
    finally:
        chaos.uninstall()


@pytest.fixture(scope="module")
def resnet():
    gg = group_nodes(build_cnn("resnet50"))
    return gg, search(gg, KCU1500, TEST_OPTS)


def resnet_prefixes(gg, workers=2):
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    return partition_space(runs, workers * TASKS_PER_WORKER)[0]


# ------------------------------------------------------- satellite fixes
def test_step_end_without_step_start_is_a_noop():
    """Used to crash with TypeError on ``None - float`` arithmetic."""
    m = StragglerMonitor()
    assert m.step_end(0) is False
    assert len(m.times) == 0
    m.step_start()
    assert m.step_end(1) is False          # normal pairing still works
    assert len(m.times) == 1


def test_straggler_monitor_honors_window():
    """The deque maxlen used to be hardcoded to 256, ignoring window."""
    m = StragglerMonitor(window=7)
    for i in range(50):
        m.observe(1.0 + i)
    assert m.times.maxlen == 7
    assert len(m.times) == 7
    assert list(m.times) == [1.0 + i for i in range(43, 50)]


def test_straggler_ewma_deadline_warmup_and_value():
    m = StragglerMonitor(threshold=3.0, alpha=0.5, min_samples=3)
    assert m.straggler_after() is None
    m.observe(1.0)
    m.observe(1.0)
    assert m.straggler_after() is None     # still warming up
    m.observe(2.0)
    # ewma: 1.0 -> 1.0 -> 0.5*2 + 0.5*1 = 1.5; deadline = 3 * 1.5
    assert m.straggler_after() == pytest.approx(4.5)


def test_preemption_guard_uninstall_restores_handlers():
    """install() used to overwrite the handlers permanently."""
    before = signal.getsignal(signal.SIGTERM)
    g = PreemptionGuard()
    g.install()
    assert signal.getsignal(signal.SIGTERM) == g._handler
    g.uninstall()
    assert signal.getsignal(signal.SIGTERM) == before
    with PreemptionGuard() as g2:          # context manager pairs them
        assert signal.getsignal(signal.SIGTERM) == g2._handler
        assert not g2.preempted
        g2.request()
        assert g2.preempted
    assert signal.getsignal(signal.SIGTERM) == before


# ------------------------------------------------------- chaos injector
def test_chaos_schedule_is_deterministic_and_scheduling_independent():
    a = chaos.ChaosInjector(seed=11, p_kill=0.2, p_raise=0.2, p_delay=0.2)
    b = chaos.ChaosInjector(seed=11, p_kill=0.2, p_raise=0.2, p_delay=0.2)
    keys = [(i, j) for i in range(10) for j in range(10)]
    plan_a = [a.event_for("task", k) for k in keys]
    # same seed, any consultation order -> same plan per (site, key)
    plan_b = [b.event_for("task", k) for k in reversed(keys)][::-1]
    assert plan_a == plan_b
    assert any(e is not None for e in plan_a)
    assert any(e is None for e in plan_a)
    # a different seed reshuffles the schedule
    c = chaos.ChaosInjector(seed=12, p_kill=0.2, p_raise=0.2, p_delay=0.2)
    assert [c.event_for("task", k) for k in keys] != plan_a
    # sites draw independently
    assert ([a.event_for("device", k) for k in keys] != plan_a)


def test_chaos_explicit_events_override_seeded_draw():
    inj = chaos.ChaosInjector(
        seed=0, p_kill=1.0,
        events={("task", "pinned"): chaos.ChaosEvent("delay", delay_s=0.0)})
    assert inj.event_for("task", "pinned").action == "delay"
    assert inj.event_for("task", "other").action == "kill"
    with pytest.raises(ValueError):
        chaos.ChaosEvent("segfault")


def test_chaos_fires_only_below_max_attempt():
    inj = chaos.ChaosInjector(seed=0, p_raise=1.0, max_attempt=2)
    with pytest.raises(chaos.ChaosError):
        inj.fire("task", "k", attempt=0)
    with pytest.raises(chaos.ChaosError):
        inj.fire("task", "k", attempt=1)
    inj.fire("task", "k", attempt=2)       # retry budget reached: no-op
    assert chaos.ChaosError.transient is True
    assert [f[3] for f in inj.fired] == ["raise", "raise"]


def test_chaos_maybe_fire_is_noop_without_injector():
    chaos.uninstall()
    chaos.maybe_fire("task", "anything")   # must not raise


# --------------------------------------------- retry & healing identity
@needs_fork
def test_worker_kill_heals_pool_and_result_is_bit_identical(resnet):
    gg, serial = resnet
    with injected(chaos.ChaosInjector(seed=7, p_kill=0.08)):
        with ParallelSearchDriver(workers=2, mp_context="fork") as d:
            r = d.search(gg, KCU1500, TEST_OPTS)
    assert_results_identical(serial, r, ctx="kill-retry")
    retries = [e for e in r.events if e.kind == "retry"]
    assert retries and all("died" in e.detail for e in retries)


@needs_fork
def test_transient_raise_is_retried_and_bit_identical(resnet):
    gg, serial = resnet
    with injected(chaos.ChaosInjector(seed=3, p_raise=0.15)):
        with ParallelSearchDriver(workers=2, mp_context="fork") as d:
            r = d.search(gg, KCU1500, TEST_OPTS)
    assert_results_identical(serial, r, ctx="transient-raise")
    retries = [e for e in r.events if e.kind == "retry"]
    assert retries and all("chaos" in e.detail for e in retries)


@needs_fork
def test_exhausted_retries_raise_instead_of_hanging(resnet):
    gg, _ = resnet
    # max_attempt high: the fault outlives every re-dispatch
    with injected(chaos.ChaosInjector(seed=7, p_kill=0.08, max_attempt=99)):
        with ParallelSearchDriver(workers=2, mp_context="fork",
                                  max_retries=1) as d:
            with pytest.raises(RuntimeError,
                               match="worker process died"):
                d.search(gg, KCU1500, TEST_OPTS)
    with injected(chaos.ChaosInjector(seed=3, p_raise=0.15,
                                      max_attempt=99)):
        with ParallelSearchDriver(workers=2, mp_context="fork",
                                  max_retries=1) as d:
            with pytest.raises(RuntimeError, match="failed after"):
                d.search(gg, KCU1500, TEST_OPTS)


@needs_fork
def test_deterministic_worker_exception_is_never_retried(resnet):
    """A worker exception without ``transient=True`` propagates unchanged
    on the first attempt -- no retry events, no healing (invalid knob
    values no longer reach workers at all: CompileOptions rejects them in
    the caller)."""
    gg, _ = resnet
    search_pool._TEST_FAIL_HOOK = "raise"
    try:
        with ParallelSearchDriver(workers=2, mp_context="fork",
                                  max_retries=5) as d:
            with pytest.raises(RuntimeError,
                               match="simulated worker failure"):
                d.search(gg, KCU1500, TEST_OPTS)
    finally:
        search_pool._TEST_FAIL_HOOK = None


# --------------------------------------------- deadlines & degradation
@needs_fork
def test_straggler_duplicate_rescues_delayed_task(resnet):
    """The victim's first attempt blocks on a chaos *hold* gate (not a
    wall-clock sleep, which races the deadline timer under load): it
    deterministically overruns the deadline, the speculative duplicate
    (attempt 1, past max_attempt) completes, and the gate is released
    before pool shutdown so ``close()`` never joins a blocked worker."""
    gg, serial = resnet
    victim = resnet_prefixes(gg)[1]
    inj = chaos.ChaosInjector()
    release = inj.hold("task", victim)
    with injected(inj):
        with ParallelSearchDriver(workers=2, mp_context="fork",
                                  task_deadline_s=0.5) as d:
            try:
                r = d.search(gg, KCU1500, TEST_OPTS)
            finally:
                release()
    assert_results_identical(serial, r, ctx="straggler")
    stragglers = [e for e in r.events if e.kind == "straggler"]
    # Membership, not equality: a slow CI box may legitimately flag a
    # second straggler; the held victim must always be one of them.
    assert victim in [e.task for e in stragglers]


@needs_fork
def test_device_replay_falls_back_to_journal_loudly(resnet):
    gg, serial = resnet
    victim = resnet_prefixes(gg)[2]
    ev = {("device", victim): chaos.ChaosEvent("raise")}
    with injected(chaos.ChaosInjector(events=ev)):
        with ParallelSearchDriver(workers=2, mp_context="fork") as d:
            r = d.search(gg, KCU1500, TEST_OPTS.replace(engine="device"))
    assert_results_identical(serial, r, ctx="device-fallback")
    falls = [e for e in r.events if e.kind == "device_fallback"]
    assert [e.task for e in falls] == [victim]
    assert "journal engine substituted" in falls[0].detail


def test_chaos_hold_gate_mechanics():
    """hold events need a gate, release unblocks fire(), and attempts at
    or past max_attempt (the straggler duplicate) never block."""
    with pytest.raises(ValueError, match="need a gate"):
        chaos.ChaosEvent("hold")
    inj = chaos.ChaosInjector()
    release = inj.hold("task", ("k",))
    inj.fire("task", ("k",), attempt=1)     # duplicate: no block
    release()
    inj.fire("task", ("k",), attempt=0)     # released gate: returns
    assert [f[:2] for f in inj.fired] == [("task", ("k",))]


# ------------------------------------------------- journal & preemption
def test_resume_skips_journaled_tasks_bit_identically(resnet, tmp_path):
    gg, serial = resnet
    with ParallelSearchDriver(workers=2) as d:
        first = d.search(gg, KCU1500,
                         TEST_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, first, ctx="journal-first")
    assert not first.events               # clean run: nothing to report
    recs = list(tmp_path.glob("search_*/task_*.rec"))
    assert recs                           # every task committed a record
    with ParallelSearchDriver(workers=2) as d:
        second = d.search(gg, KCU1500,
                          TEST_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, second, ctx="journal-second")
    resumed = [e for e in second.events if e.kind == "resume"]
    assert len(resumed) == len(recs)      # fully replayed from disk


@needs_fork
def test_killed_compile_resumes_from_task_journal(resnet, tmp_path):
    """The acceptance scenario at test scale: a parallel search killed
    mid-flight (injected worker death, retries exhausted) leaves its
    completed tasks journaled; the re-run resumes and merges to the
    byte-identical result, surfacing the resume events."""
    gg, serial = resnet
    # the doomed task is dispatched last (sliding window), so earlier
    # tasks deterministically complete and journal before it exhausts
    doomed = resnet_prefixes(gg)[-1]
    ev = {("task", doomed): chaos.ChaosEvent("kill", max_attempt=99)}
    with injected(chaos.ChaosInjector(events=ev)):
        with ParallelSearchDriver(workers=2, mp_context="fork",
                                  max_retries=1) as d:
            with pytest.raises(RuntimeError, match="worker process died"):
                d.search(gg, KCU1500,
                         TEST_OPTS.replace(resume_dir=tmp_path))
    survivors = len(list(tmp_path.glob("search_*/task_*.rec")))
    assert survivors > 0
    with ParallelSearchDriver(workers=2, mp_context="fork") as d:
        r = d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, r, ctx="resume-after-kill")
    assert len([e for e in r.events if e.kind == "resume"]) == survivors


def test_preemption_drains_and_resumes(resnet, tmp_path):
    gg, serial = resnet
    guard = PreemptionGuard()
    guard.request()                       # SIGTERM already latched
    with ParallelSearchDriver(workers=2, guard=guard) as d:
        with pytest.raises(SearchPreempted, match="resume to finish"):
            d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
    with ParallelSearchDriver(workers=2) as d:
        r = d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, r, ctx="resume-after-preempt")


def test_corrupt_journal_record_raises_not_resumes(resnet, tmp_path):
    from repro.checkpoint.checkpoint import JournalError
    gg, _ = resnet
    with ParallelSearchDriver(workers=2) as d:
        d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
    rec = sorted(tmp_path.glob("search_*/task_*.rec"))[0]
    rec.write_bytes(b"\x00garbage" + rec.read_bytes()[4:])
    with ParallelSearchDriver(workers=2) as d:
        with pytest.raises(JournalError, match="corrupt task-journal"):
            d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))


def test_journal_keyed_by_search_content(resnet, tmp_path):
    """A journal written for one (objective, partition) must not be
    consulted for another -- the content hash separates them."""
    gg, _ = resnet
    with ParallelSearchDriver(workers=2) as d:
        d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
        serial_sram = search(gg, KCU1500,
                             TEST_OPTS.replace(objective="sram"))
        r = d.search(gg, KCU1500,
                     TEST_OPTS.replace(objective="sram",
                                       resume_dir=tmp_path))
    assert not [e for e in r.events if e.kind == "resume"]
    assert_results_identical(serial_sram, r, ctx="objective-keyed")
    assert len(list(tmp_path.glob("search_*"))) == 2


# ------------------------------------------------------------ zoo fuzz
@needs_fork
@pytest.mark.parametrize("name", FUZZ_CNNS)
def test_fuzzed_chaos_preserves_bit_identity_across_zoo(name):
    """Seeded kill/raise/delay schedule over each zoo net (exhaustive
    and descent task shapes): whatever fires, the merged result must be
    byte-identical to the clean serial run."""
    gg = group_nodes(build_cnn(name))
    serial = search(gg, KCU1500, TEST_OPTS)
    # stable per-net seed (Python's str hash is salted per process)
    seed = int(hashlib.sha256(name.encode()).hexdigest()[:4], 16)
    inj = chaos.ChaosInjector(seed=seed, p_kill=0.03, p_raise=0.05,
                              p_delay=0.05, delay_s=0.2)
    # No task_deadline_s here: injected wall-clock delays must never race
    # a deadline timer (that interaction is covered deterministically by
    # the hold-gate straggler test above).
    with injected(inj):
        with ParallelSearchDriver(workers=2, mp_context="fork") as d:
            r = d.search(gg, KCU1500, TEST_OPTS)
    assert_results_identical(serial, r, ctx=f"fuzz-{name}")


@needs_fork
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_fuzzed_chaos_multi_seed_resume_round_trip(seed, tmp_path, resnet):
    """Different schedules, same invariant: chaos run journals into
    resume_dir, a clean resume completes it, both bit-identical."""
    gg, serial = resnet
    inj = chaos.ChaosInjector(seed=seed, p_kill=0.05, p_raise=0.05)
    with injected(inj):
        with ParallelSearchDriver(workers=2, mp_context="fork") as d:
            try:
                r = d.search(gg, KCU1500,
                             TEST_OPTS.replace(resume_dir=tmp_path))
            except RuntimeError:
                r = None                  # retries exhausted: resume below
    if r is not None:
        assert_results_identical(serial, r, ctx=f"fuzz-seed{seed}")
    with ParallelSearchDriver(workers=2, mp_context="fork") as d:
        r2 = d.search(gg, KCU1500, TEST_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, r2, ctx=f"fuzz-seed{seed}-resume")


# ------------------------------------------------------ compiler surface
@needs_fork
def test_compile_graph_resume_dir_end_to_end(tmp_path):
    graph = build_cnn("resnet50")
    clean = compile_graph(graph, KCU1500, TEST_OPTS.replace(workers=2))
    doomed = resnet_prefixes(group_nodes(graph))[-1]
    ev = {("task", doomed): chaos.ChaosEvent("kill", max_attempt=99)}
    with injected(chaos.ChaosInjector(events=ev)):
        with pytest.raises(RuntimeError, match="worker process died"):
            compile_graph(graph, KCU1500,
                          TEST_OPTS.replace(workers=2, max_retries=1,
                                            resume_dir=tmp_path))
    plan = compile_graph(graph, KCU1500,
                         TEST_OPTS.replace(workers=2,
                                           resume_dir=tmp_path))
    assert plan.candidate.cuts == clean.candidate.cuts
    assert plan.latency.cycles == clean.latency.cycles
    assert plan.search.evaluated == clean.search.evaluated
    assert plan.instructions == clean.instructions
    assert any(e.kind == "resume" for e in plan.search.events)
