"""Incremental cut-point engine: oracle contract + seed regression.

The engine (prefix-cached allocation + vectorized cost models) must return
bit-identical metrics to the direct ``evaluate`` oracle for every cut tuple,
and ``search`` must return exactly the candidates the seed implementation
found (same cuts, same metrics, bit-for-bit latencies)."""
import itertools
import random

import pytest

from repro.cnn import build_cnn
from repro.core.cutpoint import (CutpointEngine, evaluate, monotone_runs,
                                 search, split_blocks)
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500

ALL_CNNS = ["vgg16-conv", "yolov2", "yolov3", "resnet50", "resnet152",
            "efficientnet-b1", "retinanet", "mobilenet-v3"]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]


def _sample_tuples(runs, n_prefix=25, n_random=15, seed=0):
    """Deterministic mix of product-order (max prefix reuse) and random
    (worst-case restart) cut tuples."""
    dims = [range(len(r) + 1) for r in runs]
    tuples = list(itertools.islice(itertools.product(*dims), n_prefix))
    rng = random.Random(seed)
    tuples += [tuple(rng.randint(0, len(r)) for r in runs)
               for _ in range(n_random)]
    # extremes: all-row / all-frame encodings land on the space corners
    tuples.append(tuple(0 for _ in runs))
    tuples.append(tuple(len(r) for r in runs))
    return tuples


@pytest.mark.parametrize("name", ALL_CNNS)
def test_engine_matches_oracle(name):
    gg = group_nodes(build_cnn(name))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    for cuts in _sample_tuples(runs):
        oracle = evaluate(gg, blocks, runs, cuts, KCU1500)
        fast = engine.evaluate(cuts)
        for f in METRICS:
            assert getattr(oracle, f) == getattr(fast, f), (
                f"{name} cuts={cuts}: {f} oracle={getattr(oracle, f)!r} "
                f"engine={getattr(fast, f)!r}")


def test_engine_cache_returns_identical_metrics():
    gg = group_nodes(build_cnn("resnet50", 224))
    engine = CutpointEngine(gg, KCU1500)
    cuts = tuple(0 for _ in engine.runs)
    first = engine.evaluate(cuts)
    n = engine.evaluations
    assert engine.evaluate(cuts) is first          # memoized
    assert engine.evaluations == n


def test_engine_repeated_unmemoized_tuple():
    """Re-evaluating the same tuple with memoize=False must replay, not
    crash on a missing checkpoint, and stay bit-identical."""
    gg = group_nodes(build_cnn("resnet50", 224))
    engine = CutpointEngine(gg, KCU1500)
    cuts = tuple(1 for _ in engine.runs)
    a = engine.evaluate(cuts, memoize=False)
    b = engine.evaluate(cuts, memoize=False)
    for f in METRICS:
        assert getattr(a, f) == getattr(b, f)


# Seed search() outputs, recorded from the direct (pre-engine)
# implementation at PR 1.  The engine must reproduce them exactly:
# resnet50/resnet152 exercise the exhaustive path on a ResNet-style graph,
# efficientnet-b1/mobilenet-v3 the coordinate-descent fallback on SE-style
# graphs.
SEED_RESULTS = {
    ("resnet50", 224): dict(
        cuts=(5, 0, 2, 0, 2, 0, 1, 0), latency_cycles=2163251.1999999993,
        dram_total=25653440, dram_fm=150528, sram_total=5706728,
        bram18k=4352, feasible=True),
    ("resnet152", 224): dict(
        cuts=(5, 0, 2, 0, 2, 0, 1, 0), latency_cycles=4073779.2000000086,
        dram_total=60190912, dram_fm=150528, sram_total=5706728,
        bram18k=4352, feasible=True),
    ("efficientnet-b1", 256): dict(
        cuts=(0, 2, 1, 1, 0, 2, 1, 1, 0, 2, 1, 1, 0, 2, 1, 2, 1, 2, 1, 1,
              0, 2, 1, 2, 1, 2, 0),
        latency_cycles=818109.9999999995, dram_total=7913584,
        dram_fm=196608, sram_total=7040896, bram18k=4928, feasible=True),
    ("mobilenet-v3", 224): dict(
        cuts=(2, 0, 1, 0, 2, 1, 1, 0, 2, 1, 2, 1, 1, 0, 2, 0, 1, 1),
        latency_cycles=304965.0, dram_total=5599976, dram_fm=150528,
        sram_total=4523392, bram18k=3136, feasible=True),
}


@pytest.mark.parametrize("net,size", sorted(SEED_RESULTS))
def test_search_results_unchanged_from_seed(net, size):
    gg = group_nodes(build_cnn(net, size))
    best = search(gg, KCU1500).best
    expect = SEED_RESULTS[(net, size)]
    assert best.cuts == expect["cuts"]
    for f in METRICS:
        assert getattr(best, f) == expect[f], (
            f"{net}: {f} {getattr(best, f)!r} != seed {expect[f]!r}")


def test_search_best_is_true_argmin_on_exhaustive_space():
    """The exhaustive path must return the strict product-order argmin."""
    gg = group_nodes(build_cnn("vgg16-conv", 224))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    result = search(gg, KCU1500)
    dims = [range(len(r) + 1) for r in runs]
    best = None
    for cuts in itertools.product(*dims):
        c = evaluate(gg, blocks, runs, cuts, KCU1500)
        key = (not c.feasible, c.latency_cycles, c.sram_total)
        if best is None or key < best[0]:
            best = (key, c)
    assert result.best.cuts == best[1].cuts
    assert result.best.latency_cycles == best[1].latency_cycles


def test_search_materializes_full_candidate():
    """search() must still hand back a complete Candidate (policy + alloc),
    identical to what the oracle produces for the winning tuple."""
    gg = group_nodes(build_cnn("resnet50", 224))
    result = search(gg, KCU1500)
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    oracle = evaluate(gg, blocks, runs, result.best.cuts, KCU1500)
    assert result.best.policy == oracle.policy
    assert result.best.alloc.buff == oracle.alloc.buff
    assert result.best.alloc.spilled == oracle.alloc.spilled
    assert result.best.alloc.boundary_writes == oracle.alloc.boundary_writes
    assert result.best.alloc.boundary_reads == oracle.alloc.boundary_reads
