"""Compiler pipeline tests: grouping, allocation, ISA round-trip, and
simulator-vs-JAX-reference numerical equality + DRAM model cross-check."""
import numpy as np
import pytest

from repro.cnn import build_cnn
from repro.cnn.jax_ref import init_params, run_graph
from repro.core.allocator import allocate
from repro.core.compiler import all_frame_policy, all_row_policy, compile_graph
from repro.core.dram import baseline_total, dram_report
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.ir import Graph, make_input
from repro.core.isa import decode_stream, encode_stream, generate_instructions
from repro.core.simulator import simulate


def tiny_resnet(input_size=32) -> Graph:
    """Small residual CNN exercising conv/pool/add/SE/upsample/concat."""
    g = Graph("tiny")
    make_input(g, input_size, input_size)
    g.add("conv", out_ch=8, k=3, stride=2, act="relu")
    entry = g.nodes[-1]
    g.add("conv", out_ch=8, k=1, act="relu")
    g.add("conv", out_ch=8, k=3, act="linear")
    g.add("add", inputs=[len(g.nodes) - 1, entry.idx])
    skip = g.nodes[-1]
    # SE side path
    g.add("dwconv", k=3, act="swish")
    dw = g.nodes[-1]
    g.add("globalpool", inputs=[dw.idx])
    g.add("fc", out_ch=4, in_ch=8, in_h=1, in_w=1, out_h=1, out_w=1,
          act="swish")
    se = g.add("fc", out_ch=8, in_ch=4, in_h=1, in_w=1, out_h=1, out_w=1,
               act="sigmoid")
    g.add("scale", inputs=[dw.idx, se.idx])
    g.add("conv", out_ch=16, k=1, act="relu")
    g.add("maxpool", k=2, stride=2)
    g.add("upsample", stride=2)
    g.add("concat", inputs=[len(g.nodes) - 1, skip.idx])
    g.add("conv", out_ch=8, k=3, act="relu")
    g.validate()
    return g


ALL_CNNS = ["vgg16-conv", "yolov2", "yolov3", "resnet50", "resnet152",
            "efficientnet-b1", "retinanet", "mobilenet-v3"]


@pytest.mark.parametrize("name", ALL_CNNS)
def test_zoo_builds_and_validates(name):
    g = build_cnn(name)
    assert len(g) > 10
    assert g.total_macs() > 0
    assert g.total_weight_bytes() > 0


def test_efficientnet_group_count_matches_paper():
    gg = group_nodes(build_cnn("efficientnet-b1", 256))
    assert len(gg.groups) == 139          # paper Fig. 5(a): 139 groups


def test_allocator_three_buffers_suffice_for_residual_chain():
    g = build_cnn("resnet50", 224)
    gg = group_nodes(g)
    alloc = allocate(gg, all_frame_policy(gg))
    # ResNet has no long-path data: nothing may spill.
    assert not alloc.spilled
    assert all(b > 0 for b in alloc.buff)


def test_allocator_no_liveness_clobber():
    """No group may write its output into a buffer holding a still-live
    shortcut tensor (the core invariant of Algorithm 1)."""
    for name in ["resnet50", "efficientnet-b1", "yolov3"]:
        g = build_cnn(name)
        gg = group_nodes(g)
        alloc = allocate(gg, all_frame_policy(gg))
        live: dict[int, int] = {}
        remaining = {gi.gid: len(gg.group_consumers(gi)) for gi in gg.groups}
        for gr in gg.groups:
            for src in gg.group_inputs(gr):
                if src >= 0:
                    remaining[src] -= 1
            if gr.gid in alloc.alloc_out:
                b = alloc.alloc_out[gr.gid]
                if b in live:
                    owner = live[b]
                    assert remaining.get(owner, 0) <= 0, (
                        f"{name}: group {gr.gid} clobbers live tensor of "
                        f"group {owner} in buffer {b}")
                live[b] = gr.gid


def test_instruction_roundtrip():
    g = build_cnn("yolov3")
    gg = group_nodes(g)
    alloc = allocate(gg, all_row_policy(gg))
    ins = generate_instructions(gg, alloc)
    stream = encode_stream(ins)
    dec = decode_stream(stream)
    assert len(dec) == len(ins)
    for a, b in zip(ins, dec):
        assert a == b


@pytest.mark.parametrize("policy_fn", [all_row_policy, all_frame_policy])
def test_simulator_matches_jax_reference(policy_fn):
    g = tiny_resnet()
    gg = group_nodes(g)
    alloc = allocate(gg, policy_fn(gg))
    ins = generate_instructions(gg, alloc)
    params = init_params(g)
    x = np.random.default_rng(1).standard_normal(
        (1, 32, 32, 3), dtype=np.float32)
    ref = run_graph(g, params, x)
    out, counters = simulate(gg, alloc, ins, params, x, execute=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref[len(g.nodes) - 1]),
                               rtol=1e-5, atol=1e-5)
    assert counters.weight_reads == g.total_weight_bytes()


def test_simulator_matches_optimized_plan():
    g = tiny_resnet(64)
    plan = compile_graph(g)
    params = init_params(g)
    x = np.random.default_rng(2).standard_normal(
        (1, 64, 64, 3), dtype=np.float32)
    ref = run_graph(g, params, x)
    out, counters = simulate(plan.grouped, plan.alloc, plan.instructions,
                             params, x, execute=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref[len(g.nodes) - 1]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,size", [("resnet50", 224), ("yolov3", 416),
                                       ("efficientnet-b1", 256)])
def test_dram_model_matches_simulator_traffic(name, size):
    """Analytical eq. (8)/(9) must equal the byte counters of the memory
    simulator for the optimizer's chosen plan (dry mode: no tensors)."""
    g = build_cnn(name, size)
    plan = compile_graph(g)
    _, counters = simulate(plan.grouped, plan.alloc, plan.instructions,
                           execute=False)
    assert counters.weight_reads == plan.dram.weight_bytes
    assert counters.fm_total == plan.dram.fm_bytes, (
        f"{name}: simulator {counters.fm_total} vs model {plan.dram.fm_bytes}")


def test_frame_mode_beats_row_mode_on_dram():
    g = build_cnn("resnet50", 224)
    gg = group_nodes(g)
    row = dram_report(gg, allocate(gg, all_row_policy(gg)))
    frame = dram_report(gg, allocate(gg, all_frame_policy(gg)))
    assert frame.fm_bytes < 0.05 * row.fm_bytes


def test_optimizer_reduces_dram_vs_baseline():
    for name, size, lo, hi in [("resnet50", 256, 0.45, 0.9),
                               ("efficientnet-b1", 256, 0.6, 0.95)]:
        plan = compile_graph(build_cnn(name, size))
        red = plan.offchip_reduction
        assert lo <= red <= hi, f"{name}: reduction {red}"
        assert plan.candidate.feasible


def test_baseline_larger_than_weights():
    gg = group_nodes(build_cnn("resnet152", 256))
    assert baseline_total(gg) > gg.graph.total_weight_bytes()
