"""Per-architecture smoke tests on reduced configs (CPU, 1 device):
one forward/train step asserting shapes + finiteness, plus
prefill/decode-vs-full-forward consistency for cache-bearing families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.model import build_model

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, rng, seq=32, batch=2, mode="train"):
    tokens = jax.random.randint(rng, (batch, seq), 0, cfg.vocab)
    batch_d = {"tokens": tokens}
    if mode == "train":
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
        batch_d["labels"] = labels
    if cfg.family == "audio":
        batch_d["frames"] = jax.random.normal(
            rng, (batch, cfg.enc_seq, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "vlm":
        batch_d["patches"] = jax.random.normal(
            rng, (batch, cfg.vision_seq, cfg.d_model), jnp.float32) * 0.02
    return batch_d


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_loss_finite(name):
    cfg = smoke_config(name).replace(max_seq=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    # an untrained model should sit near ln(vocab)
    assert 0.2 * np.log(cfg.vocab) < float(metrics["nll"]) \
        < 3.0 * np.log(cfg.vocab), (name, float(metrics["nll"]))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_grads_finite(name):
    cfg = smoke_config(name).replace(max_seq=16)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1), seq=16)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    g = jax.jit(jax.grad(loss_fn))(params)
    flat = jax.tree.leaves(g)
    assert all(np.all(np.isfinite(x)) for x in flat), name
    # at least 90% of leaves receive nonzero gradient
    nz = sum(float(jnp.any(x != 0)) for x in flat)
    assert nz >= 0.7 * len(flat), (name, nz, len(flat))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_full_forward(name):
    """Cache correctness: prefill T tokens then decode one; its logits must
    match the full-forward logits at the same position."""
    cfg = smoke_config(name).replace(max_seq=32)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    full = make_batch(cfg, jax.random.key(1), seq=24, mode="prefill")
    tokens = full["tokens"]

    # full forward logits at position 23 (prefill all 24)
    logits_full, _ = jax.jit(model.prefill)(params, full)

    # prefill 16, decode tokens 16..23 one by one
    pre = dict(full)
    pre["tokens"] = tokens[:, :16]
    logits, cache = jax.jit(model.prefill)(params, pre)
    decode = jax.jit(model.decode_step)
    for t in range(16, 24):
        logits, cache = decode(params, cache, tokens[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_local_ring_cache_equivalence():
    """gemma2-style local attention with a ring cache must match a full
    cache when the window covers the sequence."""
    cfg = smoke_config("gemma2-2b").replace(max_seq=32, window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1), seq=24, mode="prefill")
    logits_full, _ = jax.jit(model.prefill)(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :12]
    logits, cache = jax.jit(model.prefill)(params, pre)
    decode = jax.jit(model.decode_step)
    for t in range(12, 24):
        logits, cache = decode(params, cache, batch["tokens"][:, t:t + 1])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_in_range():
    """Full configs must land near their nameplate sizes."""
    expected = {
        "smollm-360m": (0.25e9, 0.50e9),
        "gemma2-2b": (2.0e9, 3.5e9),
        "gemma2-27b": (22e9, 30e9),
        "granite-20b": (17e9, 24e9),
        # the assigned 48L x 64e x top-6 table gives 27.7B total / 3.6B
        # active; the "16b" label tracks a 27-layer checkpoint variant.
        "moonshot-v1-16b-a3b": (24e9, 30e9),
        "qwen3-moe-235b-a22b": (200e9, 260e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "whisper-base": (0.04e9, 0.12e9),
        "llama-3.2-vision-11b": (8.5e9, 12e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B not in [{lo}, {hi}]"


def test_moe_load_balance_loss_positive():
    cfg = smoke_config("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    _, metrics = jax.jit(model.loss)(params, batch)
    assert float(metrics["aux"]) > 0
