"""Batched mask-matrix candidate scorer: oracle contract + batch plumbing.

``CutpointEngine.score_batch`` must return metrics bit-identical to the
direct ``evaluate`` oracle for every cut tuple and every batch shape
(B=1, ragged tails, batches whose tuples jump across allocator-checkpoint
prefixes), the search must be byte-identical with batching on or off (and
serial or parallel), and the staged Pallas kernel must agree with its
float32 numpy reference in interpret mode."""
import itertools
import random

import numpy as np
import pytest

from repro.cnn import build_cnn
from repro.core.cutpoint import (CutpointEngine, evaluate, monotone_runs,
                                 search, split_blocks)
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions

ALL_CNNS = ["vgg16-conv", "yolov2", "yolov3", "resnet50", "resnet152",
            "efficientnet-b1", "retinanet", "mobilenet-v3"]
SMALL_EXHAUSTIVE = {"vgg16-conv": 224, "resnet50": 224}

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]

_GG_CACHE: dict = {}


def _grouped(name):
    got = _GG_CACHE.get(name)
    if got is None:
        gg = group_nodes(build_cnn(name))
        blocks = split_blocks(gg)
        runs = monotone_runs(blocks)
        got = _GG_CACHE[name] = (gg, blocks, runs)
    return got


def _assert_same(a, b, ctx):
    for f in METRICS:
        assert getattr(a, f) == getattr(b, f), (
            f"{ctx}: {f} {getattr(a, f)!r} != {getattr(b, f)!r} "
            f"(cuts={a.cuts})")


def _mixed_tuples(runs, n_prefix=40, n_random=40, seed=11):
    """Product-order head (max prefix reuse) + seeded random tuples
    (worst-case checkpoint restarts across arbitrary prefixes)."""
    dims = [range(len(r) + 1) for r in runs]
    tuples = list(itertools.islice(itertools.product(*dims), n_prefix))
    rng = random.Random(seed)
    tuples += [tuple(rng.randint(0, len(r)) for r in runs)
               for _ in range(n_random)]
    tuples.append(tuple(0 for _ in runs))
    tuples.append(tuple(len(r) for r in runs))
    return tuples


# ------------------------------------------------------------ oracle contract
@pytest.mark.parametrize("name", ALL_CNNS)
def test_score_batch_matches_oracle(name):
    """Random + product-order batches vs the direct oracle, whole zoo."""
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs)
    batched = engine.score_batch(tuples, memoize=False)
    for cuts, fast in zip(tuples, batched):
        oracle = evaluate(gg, blocks, runs, cuts, KCU1500)
        _assert_same(oracle, fast, name)


@pytest.mark.parametrize("name,size", sorted(SMALL_EXHAUSTIVE.items()))
def test_score_batch_exhaustive_on_small_nets(name, size):
    """Every tuple of the full cut space, scored in batches, must equal the
    per-tuple engine (itself oracle-exact) bit for bit."""
    gg, blocks, runs = _grouped(name)
    scalar = CutpointEngine(gg, KCU1500, blocks, runs)
    batched = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = list(itertools.product(*[range(len(r) + 1) for r in runs]))
    got = []
    for i in range(0, len(tuples), 1024):
        got.extend(batched.score_batch(tuples[i:i + 1024], memoize=False))
    assert len(got) == len(tuples)
    assert batched.evaluations == len(tuples)
    for cuts, m in zip(tuples, got):
        _assert_same(scalar.evaluate(cuts, memoize=False), m, name)


# ----------------------------------------------------------- batch boundaries
def test_batch_size_one_and_ragged_tail():
    gg, blocks, runs = _grouped("resnet50")
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=10, n_random=7)  # 19 tuples
    # B=1 batches
    singles = [engine.score_batch([c], memoize=False)[0] for c in tuples]
    # ragged: 19 = 8 + 8 + 3
    ragged_engine = CutpointEngine(gg, KCU1500, blocks, runs)
    ragged = []
    for i in range(0, len(tuples), 8):
        ragged.extend(ragged_engine.score_batch(tuples[i:i + 8],
                                                memoize=False))
    for cuts, a, b in zip(tuples, singles, ragged):
        _assert_same(a, b, "B=1 vs ragged")
        _assert_same(evaluate(gg, blocks, runs, cuts, KCU1500), a, "oracle")


def test_cross_prefix_batches():
    """A batch alternating between far-apart corners of the cut space
    forces a checkpoint restart on every element."""
    gg, blocks, runs = _grouped("yolov2")
    lo = tuple(0 for _ in runs)
    hi = tuple(len(r) for r in runs)
    rng = random.Random(5)
    mids = [tuple(rng.randint(0, len(r)) for r in runs) for _ in range(8)]
    batch = []
    for m in mids:
        batch += [lo, m, hi]
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    for cuts, m in zip(batch, engine.score_batch(batch, memoize=False)):
        _assert_same(evaluate(gg, blocks, runs, cuts, KCU1500), m,
                     "cross-prefix")


def test_empty_batch():
    gg, blocks, runs = _grouped("resnet50")
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    assert engine.score_batch([], memoize=False) == []
    assert engine.score_batch([], memoize=True) == []
    assert engine.evaluations == 0


def test_incremental_extraction_matches_set_walk():
    """The engine's journal-fed accumulators (``_x_bfm`` / ``_x_wrf``)
    must equal a from-scratch walk of the replayed allocation's boundary
    sets (``boundary_fm_bytes`` / ``wr_frame_max``) for every tuple --
    including random ones that force deep checkpoint restarts."""
    from repro.core.dram import boundary_fm_bytes
    from repro.core.sram import wr_frame_max
    for name in ["yolov2", "retinanet"]:
        gg, blocks, runs = _grouped(name)
        engine = CutpointEngine(gg, KCU1500, blocks, runs)
        for cuts in _mixed_tuples(runs, n_prefix=20, n_random=20, seed=9):
            alloc = engine._replay(cuts)
            assert engine._x_bfm == boundary_fm_bytes(
                alloc, engine._dt.out_size), (name, cuts)
            assert engine._x_wrf == wr_frame_max(
                engine._st, alloc, engine._frame), (name, cuts)


# ------------------------------------------------------------ memo semantics
def test_memoized_batch_matches_evaluate_bookkeeping():
    """Cache hits are returned (not recounted), in-batch duplicates are
    evaluated once, and score_batch/evaluate share one memo -- exactly the
    bookkeeping a per-tuple evaluate loop would produce."""
    gg, blocks, runs = _grouped("resnet50")
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    t0 = tuple(0 for _ in runs)
    t1 = tuple(min(1, len(r)) for r in runs)
    t2 = tuple(len(r) for r in runs)
    warm = engine.evaluate(t0)
    n0 = engine.evaluations
    got = engine.score_batch([t0, t1, t1, t2])
    assert got[0] is warm                      # cache hit returned as-is
    assert got[1] is got[2]                    # duplicate scored once
    assert engine.evaluations == n0 + 2        # only t1 and t2 replayed
    assert engine.evaluate(t1) is got[1]       # memo shared with evaluate
    assert engine.evaluations == n0 + 2


# ------------------------------------------------- search-level bit-identity
def test_search_batched_equals_per_tuple_exhaustive():
    gg, _, _ = _grouped("resnet50")
    a = search(gg, KCU1500, CompileOptions(batch_size=1))
    b = search(gg, KCU1500, CompileOptions(batch_size=1024))
    assert a.best.cuts == b.best.cuts
    assert a.evaluated == b.evaluated
    _assert_same(a.best, b.best, "search exhaustive")
    assert a.best.policy == b.best.policy
    assert a.best.alloc.buff == b.best.alloc.buff


@pytest.mark.parametrize("name", ["efficientnet-b1", "mobilenet-v3"])
def test_search_batched_equals_per_tuple_descent(name):
    """Coordinate-descent fallback: identical trajectory, memo and
    ``evaluated`` count with sweep pre-scoring on."""
    gg, _, _ = _grouped(name)
    a = search(gg, KCU1500, CompileOptions(batch_size=1))
    b = search(gg, KCU1500, CompileOptions(batch_size=512))
    assert a.best.cuts == b.best.cuts
    assert a.evaluated == b.evaluated
    _assert_same(a.best, b.best, name)


def test_search_parallel_batched_bit_identity():
    """workers=2 x batch_size>1 together must still reproduce the serial
    per-tuple SearchResult exactly (exhaustive path, space > the pool's
    min_parallel_space so it is actually partitioned)."""
    gg, _, _ = _grouped("resnet50")
    serial = search(gg, KCU1500, CompileOptions(batch_size=1))
    parallel = search(gg, KCU1500,
                      CompileOptions(workers=2, batch_size=1024))
    assert serial.best.cuts == parallel.best.cuts
    assert serial.evaluated == parallel.evaluated
    _assert_same(serial.best, parallel.best, "parallel+batched")


def test_search_parallel_batched_descent_bit_identity():
    gg, _, _ = _grouped("efficientnet-b1")
    serial = search(gg, KCU1500,
                    CompileOptions(batch_size=1, exhaustive_limit=1000))
    parallel = search(gg, KCU1500,
                      CompileOptions(workers=2, batch_size=512,
                                     exhaustive_limit=1000))
    assert serial.best.cuts == parallel.best.cuts
    assert serial.evaluated == parallel.evaluated
    _assert_same(serial.best, parallel.best, "parallel descent+batched")


# ------------------------------------------------------------- pallas kernel
def _batch_inputs(name, n_tuples=32):
    gg, blocks, runs = _grouped(name)
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    tuples = _mixed_tuples(runs, n_prefix=n_tuples // 2,
                           n_random=n_tuples // 2, seed=3)
    n = len(gg.groups)
    frame = np.zeros((len(tuples), n), dtype=bool)
    io = np.zeros((len(tuples), n))
    for j, cuts in enumerate(tuples):
        engine._replay(cuts)
        frame[j] = engine._frame
        io[j] = np.asarray(engine._x_io, dtype=np.float64)
    return engine, tuples, frame, io


def test_pallas_kernel_matches_numpy_reference():
    jax = pytest.importorskip("jax")                       # noqa: F841
    from repro.kernels.score_batch import (pack_tables, score_batch_pallas,
                                           score_batch_ref)
    for name in ["resnet50", "yolov2"]:
        engine, _, frame, io = _batch_inputs(name)
        tables = pack_tables(engine._lt, engine._dt, engine._st)
        bpc = KCU1500.dram_bytes_per_cycle
        ovh = KCU1500.group_overhead_cycles
        ref = score_batch_ref(tables, frame, io, bpc, ovh)
        ker = score_batch_pallas(tables, frame, io, bpc, ovh,
                                 interpret=True)
        assert ker.shape == ref.shape
        assert np.allclose(ker, ref, rtol=1e-5, atol=1e-2), (
            name, np.max(np.abs(ker - ref)))


def test_pallas_backend_tracks_numpy_backend():
    """backend='pallas' is float32-staged, not oracle-exact: its metrics
    must agree with the numpy backend to float32 relative precision and
    its bookkeeping (evaluations, memo) must be unchanged."""
    pytest.importorskip("jax")
    gg, blocks, runs = _grouped("resnet50")
    tuples = _mixed_tuples(runs, n_prefix=16, n_random=16)
    a = CutpointEngine(gg, KCU1500, blocks, runs).score_batch(
        tuples, memoize=False)
    pe = CutpointEngine(gg, KCU1500, blocks, runs, backend="pallas")
    b = pe.score_batch(tuples, memoize=False)
    assert pe.evaluations == len(tuples)
    for x, y in zip(a, b):
        assert x.cuts == y.cuts
        assert abs(x.latency_cycles - y.latency_cycles) \
            <= 1e-4 * max(1.0, x.latency_cycles)
        assert abs(x.dram_fm - y.dram_fm) <= 1e-4 * max(1, x.dram_fm)


def test_pallas_results_never_poison_the_memo():
    """A memoized pallas batch must not plant float32 results in the
    shared memo: a later evaluate() on the same engine still returns the
    bit-exact oracle metrics."""
    pytest.importorskip("jax")
    gg, blocks, runs = _grouped("resnet50")
    cuts = tuple(0 for _ in runs)
    engine = CutpointEngine(gg, KCU1500, blocks, runs, backend="pallas")
    engine.score_batch([cuts])            # memoize=True, pallas backend
    _assert_same(evaluate(gg, blocks, runs, cuts, KCU1500),
                 engine.evaluate(cuts), "post-pallas evaluate")
