"""Residency planner tests: optimality, budget respect, paper-policy vs DP
(hypothesis-fuzzed on synthetic block stacks)."""
import itertools

import pytest
from hypothesis_compat import given, settings, st

from repro.core.hw import V5E
from repro.core.residency import (LMBlockSpec, _block_cost, _evaluate,
                                  plan_cutpoint, plan_dp, streaming_baseline)

MB = 1 << 20


def mk_block(i, w=64 * MB, s=8 * MB, a=32 * MB, f=10 ** 12, kv=0):
    return LMBlockSpec(idx=i, kind="mlp", weight_bytes=w, stream_bytes=s,
                       act_bytes=a, flops=f, state_bytes=kv)


def segment_reference_hbm(blocks, modes, hw):
    """Independent HBM accounting: per-block base traffic plus, for each
    maximal resident segment, one entry read of the stream feeding its
    first block (the predecessor's output) and one exit write of its last
    block's output.  Pins the corrected boundary accounting without
    sharing _evaluate's per-block boundary attribution."""
    hbm = sum(_block_cost(b, m, hw)[0] for b, m in zip(blocks, modes))
    i, n = 0, len(blocks)
    while i < n:
        if modes[i] == "resident":
            j = i
            while j + 1 < n and modes[j + 1] == "resident":
                j += 1
            hbm += blocks[i - 1].stream_bytes if i else blocks[0].stream_bytes
            hbm += blocks[j].stream_bytes
            i = j + 1
        else:
            i += 1
    return hbm


def test_resident_cuts_hbm():
    blocks = [mk_block(i) for i in range(8)]
    base = streaming_baseline(blocks, V5E)
    dp = plan_dp(blocks, V5E)
    assert dp.hbm_bytes < base.hbm_bytes
    assert dp.est_seconds <= base.est_seconds + 1e-12
    # everything fits; at most the last block stays streaming (its exit
    # write would be serial, a streaming tail hides it under compute)
    assert dp.n_resident >= 7


def test_vmem_budget_respected():
    blocks = [mk_block(i, w=int(3e9)) for i in range(4)]   # weights too big
    dp = plan_dp(blocks, V5E, vmem_budget=16 * MB)
    assert dp.n_resident == 0
    assert dp.vmem_peak <= 16 * MB


def test_cutpoint_policy_is_contiguous():
    blocks = [mk_block(i, a=(64 if i % 2 else 8) * MB) for i in range(10)]
    cut = plan_cutpoint(blocks, V5E)
    modes = cut.modes
    # single cut: once resident, stays resident (where it fits)
    first_res = modes.index("resident") if "resident" in modes else len(modes)
    assert all(m == "resident" for m in modes[first_res:])


def test_dp_never_worse_than_cutpoint():
    blocks = [mk_block(i, w=(512 if i % 3 == 0 else 16) * MB)
              for i in range(12)]
    cut = plan_cutpoint(blocks, V5E)
    dp = plan_dp(blocks, V5E)
    assert dp.est_seconds <= cut.est_seconds + 1e-12


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 7),
       seed=st.integers(0, 10_000))
def test_dp_matches_bruteforce(n, seed):
    """DP vs brute force on heterogeneous stacks -- stream_bytes varies
    per block, so every segment boundary must charge the *predecessor's*
    stream (checked independently via segment_reference_hbm; charging the
    successor's, as the pre-fix code did, fails this)."""
    import random
    rng = random.Random(seed)
    blocks = [mk_block(i,
                       w=rng.choice([8, 64, 512, 4096]) * MB,
                       s=rng.choice([1, 8, 64, 256]) * MB,
                       a=rng.choice([4, 32, 256]) * MB,
                       f=rng.choice([10 ** 11, 10 ** 12, 10 ** 13]))
              for i in range(n)]
    dp = plan_dp(blocks, V5E)
    best = None
    for modes in itertools.product(["streaming", "resident"], repeat=n):
        if any(m == "resident"
               and blocks[i].resident_vmem(V5E) > V5E.vmem_bytes
               for i, m in enumerate(modes)):
            continue
        c = _evaluate(blocks, list(modes), V5E)
        assert c.hbm_bytes == segment_reference_hbm(blocks, list(modes), V5E)
        if best is None or c.est_seconds < best.est_seconds:
            best = c
    assert abs(dp.est_seconds - best.est_seconds) < 1e-9
    assert dp.hbm_bytes == segment_reference_hbm(blocks, dp.modes, V5E)


def test_boundary_accounting_3block():
    """Hand-computed regression for the corrected boundary accounting on a
    heterogeneous 3-block stack (stream widths 10 / 20 / 40 bytes)."""
    blocks = [
        LMBlockSpec(idx=0, kind="mlp", weight_bytes=100, stream_bytes=10,
                    act_bytes=1000, flops=0),
        LMBlockSpec(idx=1, kind="cross", weight_bytes=200, stream_bytes=20,
                    act_bytes=2000, flops=0),
        LMBlockSpec(idx=2, kind="vision", weight_bytes=400, stream_bytes=40,
                    act_bytes=4000, flops=0),
    ]
    # streaming b0 = w + act + 2s = 1120; b1 = 2240; b2 = 4480
    # resident  bi = w only
    # [str, res, str]: entry read into b1 is b0's output (10),
    # exit write charged at b2 is b1's output (20) -- NOT b1/b2's own 20/40
    plan = _evaluate(blocks, ["streaming", "resident", "streaming"], V5E)
    assert plan.hbm_bytes == 1120 + (200 + 10) + (4480 + 20)
    assert plan.per_block[1]["hbm"] == 210
    assert plan.per_block[2]["hbm"] == 4500
    # [res, res, str]: stack entry read sized like b0's stream (in == out)
    plan = _evaluate(blocks, ["resident", "resident", "streaming"], V5E)
    assert plan.hbm_bytes == (100 + 10) + 200 + (4480 + 20)
    # [str, str, res]: trailing segment exit write is b2's own output (40)
    plan = _evaluate(blocks, ["streaming", "streaming", "resident"], V5E)
    assert plan.hbm_bytes == 1120 + 2240 + (400 + 20) + 40


def test_cutpoint_records_forced_streaming():
    """plan.cut alone must not lie: blocks inside the resident suffix that
    fail the VMEM fit are forced streaming and flagged as such.  Memory-
    bound blocks (flops=0) so residency actually wins the sweep and the
    resident suffix is non-trivial."""
    blocks = [mk_block(i, f=0) if i % 3 else
              LMBlockSpec(idx=i, kind="moe", weight_bytes=64 * MB,
                          stream_bytes=8 * MB, act_bytes=32 * MB,
                          flops=0, vmem_resident=500 * MB)
              for i in range(9)]
    plan = plan_cutpoint(blocks, V5E)
    assert plan.cut is not None
    assert plan.vmem_peak <= V5E.vmem_bytes
    forced = [i for i, pb in enumerate(plan.per_block)
              if pb.get("forced_streaming")]
    assert forced, "sweep must keep a non-fitting block in its suffix"
    for i, (m, pb) in enumerate(zip(plan.modes, plan.per_block)):
        if i < plan.cut:
            assert m == "streaming" and "forced_streaming" not in pb
        elif i in forced:
            assert m == "streaming" and i % 3 == 0
        else:
            assert m == "resident"


def test_moe_blocks_stream():
    """Blocks whose working set (MoE dispatch buffers) exceeds VMEM must
    stay streaming -- the same conclusion the paper reaches for
    large-feature-map CNN layers."""
    blocks = []
    for i in range(8):
        b = mk_block(i)
        if i % 2:
            b = LMBlockSpec(idx=i, kind="moe", weight_bytes=b.weight_bytes,
                            stream_bytes=b.stream_bytes,
                            act_bytes=b.act_bytes, flops=b.flops,
                            vmem_resident=500 * MB)   # dispatch buffer
        blocks.append(b)
    dp = plan_dp(blocks, V5E)
    for i, m in enumerate(dp.modes):
        if i % 2:
            assert m == "streaming"
        else:
            assert m == "resident"


def test_lm_benchmark_reports():
    from benchmarks.residency_lm import report
    r = report("granite-20b", "decode_32k")
    assert r["dp_hbm_gb"] <= r["streaming_hbm_gb"]
    assert 0 <= r["hbm_reduction_pct"] <= 100
