"""Residency planner tests: optimality, budget respect, paper-policy vs DP
(hypothesis-fuzzed on synthetic block stacks)."""
import itertools

import pytest
from hypothesis_compat import given, settings, st

from repro.core.hw import V5E
from repro.core.residency import (LMBlockSpec, _evaluate, plan_cutpoint,
                                  plan_dp, streaming_baseline)

MB = 1 << 20


def mk_block(i, w=64 * MB, s=8 * MB, a=32 * MB, f=10 ** 12, kv=0):
    return LMBlockSpec(idx=i, kind="mlp", weight_bytes=w, stream_bytes=s,
                       act_bytes=a, flops=f, state_bytes=kv)


def test_resident_cuts_hbm():
    blocks = [mk_block(i) for i in range(8)]
    base = streaming_baseline(blocks, V5E)
    dp = plan_dp(blocks, V5E)
    assert dp.hbm_bytes < base.hbm_bytes
    assert dp.est_seconds <= base.est_seconds + 1e-12
    # everything fits; at most the last block stays streaming (its exit
    # write would be serial, a streaming tail hides it under compute)
    assert dp.n_resident >= 7


def test_vmem_budget_respected():
    blocks = [mk_block(i, w=int(3e9)) for i in range(4)]   # weights too big
    dp = plan_dp(blocks, V5E, vmem_budget=16 * MB)
    assert dp.n_resident == 0
    assert dp.vmem_peak <= 16 * MB


def test_cutpoint_policy_is_contiguous():
    blocks = [mk_block(i, a=(64 if i % 2 else 8) * MB) for i in range(10)]
    cut = plan_cutpoint(blocks, V5E)
    modes = cut.modes
    # single cut: once resident, stays resident (where it fits)
    first_res = modes.index("resident") if "resident" in modes else len(modes)
    assert all(m == "resident" for m in modes[first_res:])


def test_dp_never_worse_than_cutpoint():
    blocks = [mk_block(i, w=(512 if i % 3 == 0 else 16) * MB)
              for i in range(12)]
    cut = plan_cutpoint(blocks, V5E)
    dp = plan_dp(blocks, V5E)
    assert dp.est_seconds <= cut.est_seconds + 1e-12


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 7),
       seed=st.integers(0, 10_000))
def test_dp_matches_bruteforce(n, seed):
    import random
    rng = random.Random(seed)
    blocks = [mk_block(i,
                       w=rng.choice([8, 64, 512, 4096]) * MB,
                       s=rng.choice([1, 8, 64]) * MB,
                       a=rng.choice([4, 32, 256]) * MB,
                       f=rng.choice([10 ** 11, 10 ** 12, 10 ** 13]))
              for i in range(n)]
    dp = plan_dp(blocks, V5E)
    best = None
    for modes in itertools.product(["streaming", "resident"], repeat=n):
        if any(m == "resident"
               and blocks[i].resident_vmem(V5E) > V5E.vmem_bytes
               for i, m in enumerate(modes)):
            continue
        c = _evaluate(blocks, list(modes), V5E)
        if best is None or c.est_seconds < best.est_seconds:
            best = c
    assert abs(dp.est_seconds - best.est_seconds) < 1e-9


def test_moe_blocks_stream():
    """Blocks whose working set (MoE dispatch buffers) exceeds VMEM must
    stay streaming -- the same conclusion the paper reaches for
    large-feature-map CNN layers."""
    blocks = []
    for i in range(8):
        b = mk_block(i)
        if i % 2:
            b = LMBlockSpec(idx=i, kind="moe", weight_bytes=b.weight_bytes,
                            stream_bytes=b.stream_bytes,
                            act_bytes=b.act_bytes, flops=b.flops,
                            vmem_resident=500 * MB)   # dispatch buffer
        blocks.append(b)
    dp = plan_dp(blocks, V5E)
    for i, m in enumerate(dp.modes):
        if i % 2:
            assert m == "streaming"
        else:
            assert m == "resident"


def test_lm_benchmark_reports():
    from benchmarks.residency_lm import report
    r = report("granite-20b", "decode_32k")
    assert r["dp_hbm_gb"] <= r["streaming_hbm_gb"]
    assert 0 <= r["hbm_reduction_pct"] <= 100
