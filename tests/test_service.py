"""The compile service (repro.service): deterministic request hashing,
the ExecutionPlan codec's byte-identity contract, the persistent plan
cache's commit/eviction/corruption discipline, warm-started misses'
oracle-exactness, and the daemon's queueing/coalescing/failure
semantics."""
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.cnn import build_cnn
from repro.cnn.zoo import CNN_BUILDERS
from repro.core.compiler import compile_graph
from repro.core.hw import KCU1500, FPGAConfig
from repro.core.ir import Graph, make_input
from repro.core.isa import encode_stream
from repro.core.options import CompileOptions
from repro.service import (CACHE_SCHEMA_VERSION, CompileService, PlanCache,
                           PlanCodecError, ServiceClosed, ServiceOverloaded,
                           canonical_graph, decode_plan, encode_plan,
                           graph_fingerprint, hw_signature, request_key)

TEST_OPTS = CompileOptions(exhaustive_limit=50_000)


def assert_plans_identical(a, b, ctx=""):
    """The byte-identity contract the cache serves: every plan field the
    contract covers, compared bit-for-bit."""
    for f in ("cuts", "latency_cycles", "dram_total", "dram_fm",
              "sram_total", "bram18k", "feasible", "policy"):
        assert getattr(a.candidate, f) == getattr(b.candidate, f), (ctx, f)
    for f in ("policy", "alloc_in", "alloc_out", "alloc_shortcut", "buff",
              "side_buff", "spilled", "boundary_writes", "boundary_reads"):
        assert getattr(a.alloc, f) == getattr(b.alloc, f), (ctx, f)
    assert a.sram == b.sram, ctx
    assert a.dram == b.dram, ctx
    assert a.latency == b.latency, ctx
    sa = encode_stream(a.instructions).tobytes() if a.instructions else b""
    sb = encode_stream(b.instructions).tobytes() if b.instructions else b""
    assert sa == sb, f"{ctx}: instruction streams differ"
    assert a.diagnostics == b.diagnostics, ctx
    # NOT compared: search.pruned and search.events -- run history, not
    # plan content (a warm-started compile prunes more than a cold one
    # while producing the identical plan); the codec drops both.
    if a.search is not None or b.search is not None:
        assert a.search.evaluated == b.search.evaluated, ctx


# --------------------------------------------------------- canonical form
def _shuffled_twin(name="vgg16-conv", size=64):
    """The same net built twice: once via the zoo builder, once with its
    node list re-inserted in a different (still topological) order --
    here simply a field-identical rebuild with different names, plus a
    rebuild where independent chains interleave differently."""
    g1 = build_cnn(name, size)
    g2 = Graph(g1.name + "-rebuilt")
    g2.nodes = [n.clone(name=f"renamed_{n.idx}") for n in g1.nodes]
    g2.validate()
    return g1, g2


def test_canonical_graph_ignores_names():
    g1, g2 = _shuffled_twin()
    assert canonical_graph(g1) == canonical_graph(g2)
    assert graph_fingerprint(g1) == graph_fingerprint(g2)


def test_canonical_graph_insertion_order_independent():
    """Two topologically-valid insertion orders of the same diamond
    (conv -> two parallel convs -> add) must canonicalize identically;
    the two branches differ in kernel size so they are NOT automorphic
    twins."""
    def build(order):
        g = Graph("diamond")
        make_input(g, 16, 16)
        g.add("conv", out_ch=8, k=3, act="relu")        # idx 1
        stem = len(g.nodes) - 1
        if order == "ab":
            a = g.add("conv", inputs=[stem], out_ch=8, k=1, act="linear")
            b = g.add("conv", inputs=[stem], out_ch=8, k=3, act="linear")
        else:
            b = g.add("conv", inputs=[stem], out_ch=8, k=3, act="linear")
            a = g.add("conv", inputs=[stem], out_ch=8, k=1, act="linear")
        g.add("add", inputs=[a.idx, b.idx])
        g.validate()
        return g

    assert canonical_graph(build("ab")) == canonical_graph(build("ba"))
    assert (request_key(build("ab"), KCU1500, TEST_OPTS)
            == request_key(build("ba"), KCU1500, TEST_OPTS))


def test_canonical_graph_distinguishes_add_operand_order():
    """add's input order is semantic (inputs[1:] are the shortcut
    operands): swapping main/shortcut must change the canonical form."""
    def build(swap):
        g = Graph("ops")
        make_input(g, 16, 16)
        g.add("conv", out_ch=8, k=3, act="relu")
        entry = len(g.nodes) - 1
        g.add("conv", out_ch=8, k=1, act="relu")
        g.add("conv", out_ch=8, k=3, act="linear")
        main = len(g.nodes) - 1
        ins = [entry, main] if swap else [main, entry]
        g.add("add", inputs=ins)
        g.validate()
        return g

    assert canonical_graph(build(False)) != canonical_graph(build(True))


def test_request_key_cross_process_stable(tmp_path):
    """The hash must survive a fresh interpreter with a different
    PYTHONHASHSEED -- nothing in the pipeline may depend on Python's
    per-process hash randomization."""
    code = (
        "import sys; sys.path.insert(0, 'src'); sys.path.insert(0, 'tests')\n"
        "from repro.cnn import build_cnn\n"
        "from repro.core.hw import KCU1500\n"
        "from repro.core.options import CompileOptions\n"
        "from repro.service import request_key\n"
        "print(request_key(build_cnn('mobilenet-v3', 64), KCU1500,\n"
        "      CompileOptions(exhaustive_limit=50_000)))\n")
    keys = set()
    for seed in ("0", "12345"):
        out = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            cwd=Path(__file__).resolve().parent.parent,
            env={"PYTHONHASHSEED": seed, "PATH": "/usr/bin:/bin"})
        assert out.returncode == 0, out.stderr
        keys.add(out.stdout.strip())
    assert len(keys) == 1
    assert keys.pop() == request_key(build_cnn("mobilenet-v3", 64),
                                     KCU1500, TEST_OPTS)


def test_request_key_plan_fields_only():
    g = build_cnn("vgg16-conv", 64)
    base = request_key(g, KCU1500, TEST_OPTS)
    sched = request_key(g, KCU1500, TEST_OPTS.replace(
        workers=8, engine="device", verify="strict", batch_size=7))
    assert sched == base
    assert request_key(g, KCU1500, TEST_OPTS.replace(prune=False)) != base
    assert request_key(g, KCU1500,
                       TEST_OPTS.replace(objective="sram")) != base
    hw2 = FPGAConfig(name="other", freq=KCU1500.freq, ti=KCU1500.ti,
                     to=KCU1500.to * 2, mults_normal=KCU1500.mults_normal,
                     mults_dw=KCU1500.mults_dw, dram_bw=KCU1500.dram_bw,
                     bram18k_total=KCU1500.bram18k_total,
                     sram_budget=KCU1500.sram_budget,
                     group_overhead_cycles=KCU1500.group_overhead_cycles)
    assert request_key(g, hw2, TEST_OPTS) != base


# ----------------------------------------------------------------- codec
@pytest.mark.parametrize("name", sorted(CNN_BUILDERS))
def test_codec_round_trip_zoo(name):
    g = build_cnn(name)
    plan = compile_graph(g, options=TEST_OPTS)
    back = decode_plan(encode_plan(plan), g, KCU1500)
    assert_plans_identical(plan, back, ctx=f"codec-{name}")


def test_codec_rejects_garbage_and_stale_schema():
    with pytest.raises(PlanCodecError, match="undecodable"):
        decode_plan(b"not msgpack at all", build_cnn("vgg16-conv", 64),
                    KCU1500)
    import msgpack
    stale = msgpack.packb({"v": CACHE_SCHEMA_VERSION + 1})
    with pytest.raises(PlanCodecError, match="schema"):
        decode_plan(stale, build_cnn("vgg16-conv", 64), KCU1500)


# ----------------------------------------------------------------- cache
def test_cache_put_get_and_digest_check(tmp_path):
    c = PlanCache(tmp_path)
    c.put("a" * 64, b"payload", meta={"x": 1})
    assert ("a" * 64) in c and len(c) == 1
    assert c.get("a" * 64) == b"payload"
    # flip a byte: digest check must turn the record into a miss AND
    # delete it
    rec = next(tmp_path.glob("plan_*.rec"))
    blob = bytearray(rec.read_bytes())
    blob[-1] ^= 0xFF
    rec.write_bytes(bytes(blob))
    assert c.get("a" * 64) is None
    assert len(c) == 0


def test_cache_lru_eviction(tmp_path):
    c = PlanCache(tmp_path, capacity=2)
    c.put("k1" + "0" * 62, b"one", meta={})
    time.sleep(0.02)
    c.put("k2" + "0" * 62, b"two", meta={})
    time.sleep(0.02)
    assert c.get("k1" + "0" * 62) == b"one"   # touch: k2 is now LRU
    time.sleep(0.02)
    c.put("k3" + "0" * 62, b"three", meta={})
    assert len(c) == 2
    assert c.get("k2" + "0" * 62) is None
    assert c.get("k1" + "0" * 62) == b"one"
    assert c.get("k3" + "0" * 62) == b"three"


def test_cache_nearest_same_family_closest_hw(tmp_path):
    c = PlanCache(tmp_path)
    sig_near = [["ti", 16], ["to", 32], ["sram_budget", 4_000_000]]
    sig_far = [["ti", 16], ["to", 32], ["sram_budget", 16_000_000]]
    c.put("n1" + "0" * 62, b"x",
          meta={"graph_fp": "famA", "hw_sig": sig_near, "cuts": [1, 2]})
    c.put("n2" + "0" * 62, b"x",
          meta={"graph_fp": "famA", "hw_sig": sig_far, "cuts": [3, 4]})
    c.put("n3" + "0" * 62, b"x",
          meta={"graph_fp": "famB", "hw_sig": sig_near, "cuts": [9, 9]})
    query = [["ti", 16], ["to", 32], ["sram_budget", 5_000_000]]
    assert c.nearest("famA", query) == (1, 2)
    assert c.nearest("famC", query) is None


# ---------------------------------------------------------------- daemon
def test_service_hit_is_byte_identical_to_cold_compile(tmp_path):
    g = build_cnn("mobilenet-v3", 64)
    cold = compile_graph(g, options=TEST_OPTS)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        miss = svc.compile(g)
        hit = svc.compile(g)
        assert svc.stats["misses"] == 1 and svc.stats["hits"] == 1
    assert_plans_identical(cold, miss, ctx="cold-vs-miss")
    assert_plans_identical(cold, hit, ctx="cold-vs-hit")
    assert encode_plan(cold) == encode_plan(hit)


def test_service_hit_survives_restart_and_strict_verify(tmp_path):
    g = build_cnn("vgg16-conv", 64)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        svc.compile(g)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        t = svc.submit(g, options=TEST_OPTS.replace(verify="strict"))
        plan = t.result(timeout=60)
        assert t.hit
        assert plan.diagnostics is not None
        assert svc.stats["hits"] == 1 and svc.stats["misses"] == 0


def test_service_warm_start_exact_on_new_hw(tmp_path):
    """A miss for a known net on a NEW hw config warm-starts from the
    nearest cached plan and must still return the oracle-exact argmin:
    bit-identical (including `evaluated`) to a cold compile_graph."""
    g = build_cnn("resnet50", 64)
    hw2 = FPGAConfig(name="kcu1500-smallsram", freq=KCU1500.freq,
                     ti=KCU1500.ti, to=KCU1500.to,
                     mults_normal=KCU1500.mults_normal,
                     mults_dw=KCU1500.mults_dw, dram_bw=KCU1500.dram_bw,
                     bram18k_total=KCU1500.bram18k_total,
                     sram_budget=KCU1500.sram_budget // 2,
                     group_overhead_cycles=KCU1500.group_overhead_cycles)
    cold = compile_graph(g, hw2, options=TEST_OPTS)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        svc.compile(g)                         # seeds the family record
        t = svc.submit(g, hw2)
        warm = t.result(timeout=120)
        assert not t.hit and t.warm_started
        assert svc.stats["warm_starts"] == 1
    assert_plans_identical(cold, warm, ctx="warm-vs-cold")


def test_service_coalesces_identical_inflight_requests(tmp_path):
    g = build_cnn("vgg16-conv", 64)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        t1 = svc.submit(g)
        t2 = svc.submit(g)
        assert t1 is t2
        assert svc.stats["coalesced"] == 1
        p1 = t1.result(timeout=60)
        # after completion the key is no longer in-flight: a resubmit is
        # a fresh ticket served from the cache
        t3 = svc.submit(g)
        assert t3 is not t1
        p3 = t3.result(timeout=60)
    assert_plans_identical(p1, p3, ctx="coalesce")


def test_service_overload_backpressure(tmp_path):
    """A full bounded queue rejects at submit() -- the daemon never
    buffers unboundedly.  A gate stalls the single worker so the queue
    genuinely fills."""
    gate = threading.Event()
    started = threading.Event()
    nets = [build_cnn(n, 64) for n in ("vgg16-conv", "mobilenet-v3",
                                       "resnet50", "yolov2")]
    with CompileService(tmp_path, options=TEST_OPTS, max_pending=2,
                        threads=1) as svc:
        orig = svc._fulfil

        def stalled(ticket, graph, hw, opts):
            started.set()
            gate.wait(timeout=30)
            return orig(ticket, graph, hw, opts)

        svc._fulfil = stalled
        tickets = [svc.submit(nets[0])]
        # wait for the worker to dequeue the first request, then fill
        # the 2-slot queue exactly
        assert started.wait(timeout=30)
        tickets += [svc.submit(nets[1]), svc.submit(nets[2])]
        with pytest.raises(ServiceOverloaded, match="retry with backoff"):
            svc.submit(nets[3])
        assert svc.stats["overloads"] == 1
        gate.set()
        for t in tickets:
            t.result(timeout=120)


def test_service_failure_fails_ticket_not_daemon(tmp_path):
    bad = Graph("bad")                 # no input node: compile must fail
    g = build_cnn("vgg16-conv", 64)
    with CompileService(tmp_path, options=TEST_OPTS) as svc:
        t = svc.submit(bad)
        with pytest.raises(Exception):
            t.result(timeout=60)
        assert svc.stats["failures"] == 1
        assert len(svc.cache) == 0     # nothing cached on failure
        # the daemon keeps serving
        svc.compile(g)
        assert svc.stats["misses"] == 2


def test_service_closed_rejects_submit(tmp_path):
    svc = CompileService(tmp_path, options=TEST_OPTS)
    svc.close()
    with pytest.raises(ServiceClosed):
        svc.submit(build_cnn("vgg16-conv", 64))
    svc.close()                        # idempotent
