"""Fused device search pipeline (kernels/search_pipeline.py): the
``engine="pipeline"`` contract.

Three layers of bit-identity, mirroring how the pipeline is built:

* **argmin_lanes** -- the hierarchical masked-minima reduction must pick
  the identical ``(key, index)`` winner as the host's stable lexsort on
  fuzzed batches stuffed with duplicated key components, under all three
  backends (numpy reference / traced lax / Pallas-interpret kernel);
* **pipeline_subspace** -- on real partitioned sub-spaces (prefix x
  suffix product) every variant must return the same
  ``(CandidateMetrics, pruned)`` as the host branch-and-bound walk, for
  every objective;
* **search(engine="pipeline")** -- end to end, serial and workers=2 and
  under a forced 2-device jax host, the SearchResult must be
  bit-identical to the journal engine's, ``evaluated`` included (the
  pipeline scores everything in-kernel and reports ``pruned=0``, which
  under the default ``count_pruned=True`` reproduces the journal count).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cnn import build_cnn
from repro.core.cutpoint import (CutpointEngine, branch_bound_subspace,
                                 monotone_runs, search, split_blocks)
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
from repro.core.search_pool import partition_space
from repro.kernels.search_pipeline import (OBJECTIVES, VARIANTS,
                                           argmin_lanes, pipeline_subspace)
from repro.kernels.score_batch import HAVE_JAX

from test_search_pool import (METRICS, TEST_LIMIT, assert_results_identical)

TEST_OPTS = CompileOptions(exhaustive_limit=TEST_LIMIT)

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax not importable")


def _jax_variants():
    return [v for v in VARIANTS if v == "reference" or HAVE_JAX]


# ------------------------------------------------------------ argmin fuzz
def _host_winner(infeas, primary, secondary, idx):
    """The oracle: stable lexicographic first-minimum."""
    order = np.lexsort((idx, secondary, primary, infeas))
    j = int(order[0])
    return (float(infeas[j]), float(primary[j]), float(secondary[j]),
            int(idx[j]))


def _fuzz_lanes(rng, n):
    """Key batches designed to tie: every component is drawn from a tiny
    value set, so duplicated full keys are the common case and only the
    index tie-break separates winners."""
    infeas = rng.choice([0.0, 1.0], size=n)
    primary = rng.choice([3.0, 7.0, 7.0, 11.0, 1e9], size=n)
    secondary = rng.choice([2.0, 5.0, 5.0, 123456.0], size=n)
    idx = rng.permutation(10 * n)[:n].astype(np.float64)
    return infeas, primary, secondary, idx


@pytest.mark.parametrize("backend", ["reference", "lax", "pallas"])
def test_argmin_lanes_fuzzed_duplicate_keys(backend):
    if backend != "reference" and not HAVE_JAX:
        pytest.skip("jax not importable")
    rng = np.random.default_rng(20260808)
    trials = 60 if backend != "pallas" else 12
    for t in range(trials):
        n = int(rng.integers(1, 300))
        lanes = _fuzz_lanes(rng, n)
        assert argmin_lanes(*lanes, backend=backend) \
            == _host_winner(*lanes), (backend, t, n)


@pytest.mark.parametrize("backend", ["reference", "lax", "pallas"])
def test_argmin_lanes_all_infeasible_and_singleton(backend):
    if backend != "reference" and not HAVE_JAX:
        pytest.skip("jax not importable")
    # all-infeasible batch: the winner is still the best infeasible lane
    lanes = (np.ones(7), np.arange(7.0, 0.0, -1.0),
             np.zeros(7), np.arange(7.0))
    assert argmin_lanes(*lanes, backend=backend) == (1.0, 1.0, 0.0, 6)
    # singleton batch
    lanes = (np.array([0.0]), np.array([42.0]),
             np.array([9.0]), np.array([3.0]))
    assert argmin_lanes(*lanes, backend=backend) == (0.0, 42.0, 9.0, 3)


def test_argmin_lanes_duplicated_key_takes_smallest_index():
    # four lanes with the identical winning key: index decides, exactly
    # as the host merge tie-breaks equal-key candidates by cut tuple
    infeas = np.array([1.0, 0.0, 0.0, 0.0, 0.0])
    primary = np.array([0.0, 5.0, 5.0, 5.0, 6.0])
    secondary = np.array([0.0, 2.0, 2.0, 2.0, 1.0])
    idx = np.array([0.0, 17.0, 4.0, 9.0, 1.0])
    for backend in ["reference"] + (["lax", "pallas"] if HAVE_JAX else []):
        assert argmin_lanes(infeas, primary, secondary, idx,
                            backend=backend) == (0.0, 5.0, 2.0, 4), backend


def test_argmin_lanes_rejects_bad_input():
    with pytest.raises(ValueError, match="equal-length"):
        argmin_lanes(np.zeros(3), np.zeros(2), np.zeros(3), np.zeros(3))
    with pytest.raises(ValueError, match="backend"):
        argmin_lanes(np.zeros(3), np.zeros(3), np.zeros(3), np.zeros(3),
                     backend="cuda")


# ------------------------------------------------- sub-space bit-identity
def _engine(name="resnet50", size=224):
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    return CutpointEngine(gg, KCU1500, blocks, runs), runs


@pytest.mark.parametrize("variant", VARIANTS)
def test_pipeline_subspace_matches_branch_bound(variant):
    if variant != "reference" and not HAVE_JAX:
        pytest.skip("jax not importable")
    engine, runs = _engine()
    prefixes, suffix_dims = partition_space(runs, target_tasks=8)
    host = CutpointEngine(engine.gg, engine.hw, engine.blocks, engine.runs)
    for prefix in prefixes[:3]:
        want, _pruned = branch_bound_subspace(host, prefix, suffix_dims,
                                              "latency", prune=False)
        got, pruned = pipeline_subspace(engine, prefix, suffix_dims,
                                        "latency", batch_size=256,
                                        variant=variant)
        assert pruned == 0
        assert got.cuts == want.cuts, (variant, prefix)
        for f in METRICS:
            assert getattr(got, f) == getattr(want, f), (variant, prefix, f)


@pytest.mark.parametrize("objective", OBJECTIVES)
def test_pipeline_subspace_objectives(objective):
    engine, runs = _engine()
    prefixes, suffix_dims = partition_space(runs, target_tasks=8)
    host = CutpointEngine(engine.gg, engine.hw, engine.blocks, engine.runs)
    variants = _jax_variants()
    want, _ = branch_bound_subspace(host, prefixes[0], suffix_dims,
                                    objective, prune=False)
    for variant in variants:
        got, _ = pipeline_subspace(engine, prefixes[0], suffix_dims,
                                   objective, batch_size=128,
                                   variant=variant)
        assert got.cuts == want.cuts, (objective, variant)
        for f in METRICS:
            assert getattr(got, f) == getattr(want, f), (objective, variant)


def test_pipeline_subspace_counts_full_enumeration():
    """``evaluations`` is credited with the whole sub-space S, matching
    the journal path's scored+pruned accounting."""
    engine, runs = _engine()
    prefixes, suffix_dims = partition_space(runs, target_tasks=8)
    S = 1
    for d in suffix_dims:
        S *= d + 1
    before = engine.evaluations
    pipeline_subspace(engine, prefixes[0], suffix_dims, "latency",
                      variant="reference")
    assert engine.evaluations == before + S


def test_pipeline_subspace_singleton_space():
    """A fully-pinned sub-space (every dim 0) short-circuits to the one
    candidate, still crediting one evaluation."""
    engine, runs = _engine()
    cuts = tuple(0 for _ in runs)
    before = engine.evaluations
    m, pruned = pipeline_subspace(engine, cuts, [], "latency")
    assert pruned == 0 and m.cuts == cuts
    assert engine.evaluations == before + 1


def test_pipeline_subspace_validates_arguments():
    engine, runs = _engine()
    with pytest.raises(ValueError, match="objective"):
        pipeline_subspace(engine, (), [len(r) for r in runs], "bogus")
    with pytest.raises(ValueError, match="variant"):
        pipeline_subspace(engine, (), [len(r) for r in runs], "latency",
                          variant="cuda")
    with pytest.raises(ValueError, match="cover all"):
        pipeline_subspace(engine, (0,), [len(r) for r in runs], "latency",
                          variant="reference")


# -------------------------------------------------- end-to-end bit-identity
@pytest.mark.parametrize("variant", VARIANTS)
def test_search_pipeline_matches_journal_exhaustive(variant):
    """resnet50's 8748-tuple space, enumerated exhaustively: every
    pipeline variant returns the journal SearchResult byte-for-byte,
    ``evaluated`` and ``path`` included."""
    if variant != "reference" and not HAVE_JAX:
        pytest.skip("jax not importable")
    gg = group_nodes(build_cnn("resnet50"))
    journal = search(gg, KCU1500, TEST_OPTS)
    piped = search(gg, KCU1500,
                   TEST_OPTS.replace(engine=f"pipeline:{variant}"))
    assert_results_identical(journal, piped, ctx=f"pipeline:{variant}")
    assert piped.path == journal.path == "exhaustive"
    assert piped.pruned == 0


def test_search_pipeline_parallel_matches_serial_journal():
    """workers=2: disjoint sub-spaces each fused on device, merged with
    the deterministic (key, cuts) order -- still journal-identical."""
    gg = group_nodes(build_cnn("resnet50"))
    journal = search(gg, KCU1500, TEST_OPTS)
    piped = search(gg, KCU1500,
                   TEST_OPTS.replace(engine="pipeline", workers=2))
    assert_results_identical(journal, piped, ctx="pipeline-workers2")


def test_search_pipeline_descent_path_matches_journal():
    """Beyond exhaustive_limit the pipeline engine's search falls back to
    the host-driven coordinate descent (score_batch under the journal
    replay) -- results and path must match the journal engine exactly."""
    gg = group_nodes(build_cnn("mobilenet-v3"))
    journal = search(gg, KCU1500, TEST_OPTS)
    piped = search(gg, KCU1500, TEST_OPTS.replace(engine="pipeline"))
    assert journal.path == piped.path == "descent"
    assert_results_identical(journal, piped, ctx="pipeline-descent")


def test_search_pipeline_batch_suffix():
    """An @batch engine suffix only changes chunking, never the result."""
    gg = group_nodes(build_cnn("vgg16-conv"))
    journal = search(gg, KCU1500, TEST_OPTS)
    for spelling in ("pipeline:reference@64", "pipeline:reference@4096"):
        piped = search(gg, KCU1500, TEST_OPTS.replace(engine=spelling))
        assert_results_identical(journal, piped, ctx=spelling)


@needs_jax
def test_search_pipeline_sharded_two_devices():
    """The shard_map path: a subprocess forced to expose two host
    devices must produce the identical SearchResult as the journal
    engine (contiguous index ranges per device, deterministic merge).
    Subprocess because device count is fixed at first jax import."""
    code = """
import jax
assert jax.device_count() == 2, jax.devices()
from repro.cnn import build_cnn
from repro.core.cutpoint import search
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
gg = group_nodes(build_cnn("resnet50"))
opts = CompileOptions(exhaustive_limit=200_000)
journal = search(gg, KCU1500, opts)
piped = search(gg, KCU1500, opts.replace(engine="pipeline:lax"))
assert piped.best.cuts == journal.best.cuts
for f in ("latency_cycles", "dram_total", "dram_fm", "sram_total",
          "bram18k", "feasible"):
    assert getattr(piped.best, f) == getattr(journal.best, f), f
assert piped.evaluated == journal.evaluated
print("SHARDED-OK", piped.evaluated)
"""
    env = dict(os.environ)
    kept = [f for f in env.get("XLA_FLAGS", "").split()
            if "xla_force_host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        kept + ["--xla_force_host_platform_device_count=2"])
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(p) for p in sys.path if p] + [env.get("PYTHONPATH", "")])
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert "SHARDED-OK" in out.stdout, out.stdout


@pytest.mark.skipif("fork" not in
                    __import__("multiprocessing").get_all_start_methods(),
                    reason="no fork start method on this platform")
def test_parallel_pipeline_ratchets_fork_to_spawn():
    # Forking a parent that has already run jit'd code hands the children
    # XLA's locked mutexes and deadlocks them, so the driver must ratchet
    # its *defaulted* fork context to spawn exactly for the engine specs
    # whose workers execute jax -- and leave explicit contexts alone.
    from repro.core.options import resolve_engine
    from repro.core.search_pool import (ParallelSearchDriver,
                                        _engine_needs_jax)

    assert _engine_needs_jax(resolve_engine("pipeline:lax"))
    assert _engine_needs_jax(resolve_engine("pipeline:pallas"))
    assert _engine_needs_jax(resolve_engine("device:scan"))
    assert _engine_needs_jax(resolve_engine("device:pallas"))
    assert not _engine_needs_jax(resolve_engine("journal"))
    assert not _engine_needs_jax(resolve_engine("pipeline:reference"))
    assert not _engine_needs_jax(resolve_engine("device"))  # -> reference

    opts = CompileOptions(exhaustive_limit=TEST_LIMIT)
    with ParallelSearchDriver(workers=2) as d:
        out = d._jax_safe_opts(opts)
        assert out.engine == "journal" and \
            d._ctx.get_start_method() == "fork"
        out = d._jax_safe_opts(opts.replace(engine="pipeline:lax"))
        assert out.engine == "pipeline:lax"
        assert d._ctx.get_start_method() == "spawn"
        # one-way for the driver's lifetime: later numpy engines reuse
        # the (universally safe) spawn pool instead of churning workers
        d._jax_safe_opts(opts)
        assert d._ctx.get_start_method() == "spawn"

    # an explicit context is the caller's choice, hazards included
    with ParallelSearchDriver(workers=2, mp_context="fork") as d:
        out = d._jax_safe_opts(opts.replace(engine="pipeline:lax"))
        assert out.engine == "pipeline:lax"
        assert d._ctx.get_start_method() == "fork"

    # a parent whose __main__ spawn cannot re-import (stdin scripts)
    # degrades the engine to the bit-identical journal replay instead
    from repro.core import search_pool as sp
    with ParallelSearchDriver(workers=2) as d:
        orig = sp._spawn_main_viable
        sp._spawn_main_viable = lambda: False
        try:
            with pytest.warns(RuntimeWarning, match="journal engine"):
                out = d._jax_safe_opts(
                    opts.replace(engine="pipeline:lax@512"))
        finally:
            sp._spawn_main_viable = orig
        assert out.engine == "journal@512"
        assert d._ctx.get_start_method() == "fork"
