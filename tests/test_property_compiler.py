"""Property-based tests (hypothesis): the analytical DRAM model must match
the instruction-stream simulator for RANDOM residual CNNs under RANDOM
reuse policies, and the allocator must never clobber live tensors.

The graph generator is the shared ``random_cnn`` strategy in conftest.py
(also used by tests/test_branch_bound.py), so fuzzing covers shortcut
fan-out, upsamples and varying monotone-run shapes -- not just the zoo."""
import numpy as np
from conftest import random_cnn
from hypothesis_compat import given, settings, st

from repro.core.allocator import allocate
from repro.core.dram import dram_report
from repro.core.grouping import group_nodes
from repro.core.isa import generate_instructions
from repro.core.simulator import simulate


@settings(deadline=None)
@given(g=random_cnn(), seed=st.integers(0, 999))
def test_dram_model_equals_simulator_on_random_graphs(g, seed):
    gg = group_nodes(g)
    rng = np.random.default_rng(seed)
    policy = {gr.gid: ("row" if rng.random() < 0.5 else "frame")
              for gr in gg.groups}
    alloc = allocate(gg, policy)
    ins = generate_instructions(gg, alloc)
    _, counters = simulate(gg, alloc, ins, execute=False)
    rep = dram_report(gg, alloc)
    assert counters.fm_total == rep.fm_bytes
    assert counters.weight_reads == rep.weight_bytes


@settings(deadline=None)
@given(g=random_cnn())
def test_allocator_never_clobbers_live_tensors(g):
    gg = group_nodes(g)
    alloc = allocate(gg, {gr.gid: "frame" for gr in gg.groups})
    remaining = {gr.gid: len(gg.group_consumers(gr)) for gr in gg.groups}
    live: dict[int, int] = {}
    for gr in gg.groups:
        for src in gg.group_inputs(gr):
            if src >= 0:
                remaining[src] -= 1
        if gr.gid in alloc.alloc_out:
            b = alloc.alloc_out[gr.gid]
            if b in live:
                assert remaining.get(live[b], 0) <= 0, \
                    f"group {gr.gid} clobbers live group {live[b]}"
            live[b] = gr.gid


@settings(deadline=None)
@given(g=random_cnn(), seed=st.integers(0, 99))
def test_simulator_numerics_on_random_graphs(g, seed):
    """Random policy execution must equal the direct JAX reference."""
    from repro.cnn.jax_ref import init_params, run_graph
    gg = group_nodes(g)
    rng = np.random.default_rng(seed)
    policy = {gr.gid: ("row" if rng.random() < 0.5 else "frame")
              for gr in gg.groups}
    alloc = allocate(gg, policy)
    ins = generate_instructions(gg, alloc)
    params = init_params(g, seed)
    size = g.nodes[0].out_h
    x = rng.standard_normal((1, size, size, 3), dtype=np.float32)
    out, _ = simulate(gg, alloc, ins, params, x, execute=True)
    ref = run_graph(g, params, x)[len(g.nodes) - 1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
