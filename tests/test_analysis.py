"""Static verifier: clean plans verify clean, broken plans are caught.

Three layers of evidence:

* **unit** -- diagnostics vocabulary, journal-derived live intervals, and
  hand-crafted corruptions each hitting their dedicated code;
* **mutation kill** -- the seeded fuzzer (analysis/mutate.py) must achieve
  a 100% kill rate for every applicable violation class across several
  zoo nets, with at least one expected code per class;
* **differential** -- every mutant the dynamic Simulator detects (an
  exception, dangling reads, or counter drift vs the original plan's
  reports) must also be caught statically: the O(plan) verifier never
  lags the dynamic oracle.
"""
import dataclasses

import pytest

from repro.analysis import (CLASSES, Severity, VerificationError,
                            errors_of, journal_trace, kill_matrix,
                            mutate_plan, render_report, simulator_detects,
                            verify_execution_plan, verify_plan)
from repro.analysis.__main__ import main as analysis_cli
from repro.analysis.diagnostics import CODES, make
from repro.cnn import build_cnn
from repro.core.compiler import compile_graph
from repro.core.isa import OFFCHIP
from repro.core.options import CompileOptions

NETS = [("yolov2", 416), ("resnet50", 224), ("retinanet", 512)]
AUDIT_LIMIT = 50_000
AUDIT_OPTS = CompileOptions(exhaustive_limit=AUDIT_LIMIT)


@pytest.fixture(scope="module")
def plans():
    return {name: compile_graph(build_cnn(name, size), options=AUDIT_OPTS)
            for name, size in NETS}


# ---------------------------------------------------------------- unit
def test_unknown_code_rejected():
    with pytest.raises(KeyError):
        make("SF999", "nope")


def test_diagnostic_render_shape():
    d = make("SF020", "boom", gid=15, word=6, context="buf1<-g12")
    out = d.render()
    assert out.startswith("SF020 @g15.w6 [error] boom")
    assert "buf1<-g12" in out
    assert "clean" in render_report("net", [])


def test_verification_error_message():
    err = VerificationError("net", [make("SF050", "field k overflows")])
    assert "1 error(s)" in str(err) and "SF050" in str(err)


def test_every_code_has_catalog_entry():
    for code, (title, sev) in CODES.items():
        assert code.startswith("SF") and len(code) == 5
        assert title and isinstance(sev, Severity)


def test_journal_intervals_cover_alloc_out(plans):
    """Every buffer assignment in the allocation is backed by a journal
    interval owned by that gid and starting there."""
    plan = plans["resnet50"]
    trace = journal_trace(plan.grouped, plan.alloc.policy)
    assert trace.intervals
    for gid, b in plan.alloc.alloc_out.items():
        iv = trace.owner_at(b, gid)
        assert iv is not None and iv.owner == gid, (gid, b, iv)
    # the replayed allocation is bit-identical to the plan's
    assert trace.alloc.alloc_out == plan.alloc.alloc_out
    assert trace.alloc.spilled == plan.alloc.spilled


# ------------------------------------------------ hand-crafted corruptions
def _fresh(plan):
    return [dataclasses.replace(i) for i in plan.instructions]


def _codes(diags):
    return {d.code for d in diags}


def test_detects_wrong_src_main(plans):
    plan = plans["resnet50"]
    ins = _fresh(plan)
    victim = next(i for i in ins if i.src_main >= 0 and i.gid >= 2)
    victim.src_main = (victim.src_main + 1) % victim.gid
    diags = verify_plan(plan.grouped, plan.alloc, ins, plan.hw,
                        feasible=True)
    assert "SF015" in _codes(errors_of(diags))


def test_detects_missing_instruction(plans):
    plan = plans["resnet50"]
    diags = verify_plan(plan.grouped, plan.alloc,
                        plan.instructions[:-1], plan.hw, feasible=True)
    assert "SF014" in _codes(errors_of(diags))


def test_detects_duplicate_instruction(plans):
    plan = plans["resnet50"]
    ins = _fresh(plan) + [dataclasses.replace(plan.instructions[3])]
    diags = verify_plan(plan.grouped, plan.alloc, ins, plan.hw,
                        feasible=True)
    codes = _codes(errors_of(diags))
    assert "SF012" in codes or "SF013" in codes


def test_detects_use_before_def(plans):
    plan = plans["resnet50"]
    ins = _fresh(plan)
    victim = next(i for i in ins if i.src_shortcut != -1)
    victim.src_shortcut = victim.gid + 1
    diags = verify_plan(plan.grouped, plan.alloc, ins, plan.hw,
                        feasible=True)
    assert "SF010" in _codes(errors_of(diags))


def test_detects_row_mode_onchip_alloc(plans):
    plan = plans["yolov2"]
    ins = _fresh(plan)
    victim = next(i for i in ins if i.mode == 0)
    victim.alloc_out = 1
    diags = verify_plan(plan.grouped, plan.alloc, ins, plan.hw,
                        feasible=True)
    assert "SF053" in _codes(errors_of(diags))


def test_detects_journal_divergence(plans):
    """Tampering with the allocation record (not the stream) trips the
    journal replay cross-check."""
    plan = plans["resnet50"]
    alloc = dataclasses.replace(
        plan.alloc, alloc_out=dict(plan.alloc.alloc_out),
        spilled=set(plan.alloc.spilled))
    gid = next(iter(sorted(alloc.alloc_out)))
    alloc.alloc_out[gid] = (alloc.alloc_out[gid] + 1) % 3
    diags = verify_plan(plan.grouped, alloc, plan.instructions, plan.hw,
                        feasible=True)
    assert "SF024" in _codes(errors_of(diags))


# ----------------------------------------------------- mutation-kill gate
def test_mutation_kill_matrix(plans):
    """100% kill rate: every applicable (net, class, seed) mutant must be
    caught with at least one of its expected codes, and every class must
    apply on at least one net."""
    rows = kill_matrix(plans, seeds=(0, 1, 2))
    applied = [r for r in rows if r["applied"]]
    assert applied, "no mutation applied anywhere"
    missed = [r for r in applied if not r["killed"]]
    assert not missed, f"mutants survived the verifier: {missed}"
    applied_classes = {r["cls"] for r in applied}
    assert applied_classes == set(CLASSES), (
        f"classes never applied on any net: "
        f"{set(CLASSES) - applied_classes}")


def test_mutants_are_deterministic(plans):
    plan = plans["resnet50"]
    a = mutate_plan(plan, "clobber_alloc", seed=5)
    b = mutate_plan(plan, "clobber_alloc", seed=5)
    assert a.description == b.description
    assert a.instructions == b.instructions


def test_mutation_does_not_touch_original(plans):
    plan = plans["resnet50"]
    before = [dataclasses.replace(i) for i in plan.instructions]
    spilled = set(plan.alloc.spilled)
    for cls in CLASSES:
        mutate_plan(plan, cls, seed=0)
    assert plan.instructions == before
    assert plan.alloc.spilled == spilled


@pytest.mark.parametrize("cls", sorted(CLASSES))
def test_simulator_detection_implies_static_kill(plans, cls):
    """Differential gate: the static verifier dominates the dynamic
    oracle on every injected mutant."""
    for name, plan in plans.items():
        for seed in (0, 1):
            m = mutate_plan(plan, cls, seed)
            if m is None:
                continue
            dynamic = simulator_detects(plan, m)
            static = bool(errors_of(m.verify()))
            assert not dynamic or static, (
                f"{name}/{cls}/seed{seed}: simulator detects "
                f"({m.description}) but the static verifier is silent")


# --------------------------------------------------------- compiler knob
def test_compile_verify_knob_off_strict():
    g = build_cnn("vgg16-conv", 224)
    off = compile_graph(g, options=CompileOptions(verify="off"))
    assert off.diagnostics == []
    strict = compile_graph(g, options=CompileOptions(verify="strict"))
    assert errors_of(strict.diagnostics) == []
    with pytest.raises(ValueError, match="verify"):
        compile_graph(g, options=CompileOptions(verify="loose"))


# ------------------------------------------------------------------- CLI
def test_cli_strict_single_net(capsys):
    assert analysis_cli(["--net", "vgg16-conv", "--strict"]) == 0
    out = capsys.readouterr().out
    assert "vgg16-conv" in out and "clean" in out


def test_cli_report_and_kill_gate(tmp_path, capsys):
    report = tmp_path / "verify.txt"
    code = analysis_cli(["--net", "yolov2", "--strict", "--mutation-kill",
                         "--seeds", "1", "--report", str(report)])
    assert code == 0
    text = report.read_text()
    assert "yolov2" in text and "mutants killed" in text


def test_cli_rejects_unknown_net(capsys):
    with pytest.raises(SystemExit):
        analysis_cli(["--net", "lenet"])
