"""Launch-layer tests: sharding plans, HLO parsing, and a subprocess
dry-run of one real cell per plan kind (the 512-device env var must be set
before jax initializes, hence subprocess)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.utils.hlo import parse_collectives


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = 1
        for v in shape.values():
            self.size *= v


MESH1 = FakeMesh({"data": 16, "model": 16})


def test_plan_kinds():
    from repro.launch.sharding import make_plan
    assert make_plan(get_config("gemma2-27b"), MESH1).kind == "tp"
    assert make_plan(get_config("qwen3-moe-235b-a22b"), MESH1).kind == "tp"
    # heads (8/15/10) indivisible by 16 -> hybrid
    for arch in ("gemma2-2b", "smollm-360m", "recurrentgemma-2b",
                 "whisper-base"):
        plan = make_plan(get_config(arch), MESH1)
        assert plan.kind == "hybrid", arch
        assert plan.rules["heads"] is None
    # mamba2 is attention-free -> tp
    assert make_plan(get_config("mamba2-2.7b"), MESH1).kind == "tp"


def test_plan_divisibility_never_violated():
    from repro.launch.sharding import make_plan
    for name, cfg in ARCHS.items():
        plan = make_plan(cfg, MESH1)
        if plan.rules.get("heads") == "model":
            assert cfg.n_heads % 16 == 0, name
        if plan.rules.get("kv") == "model":
            assert cfg.n_kv_heads % 16 == 0, name
        if plan.rules.get("experts") == "model":
            assert cfg.n_experts % 16 == 0, name
        if plan.rules.get("vocab") == "model":
            assert cfg.vocab % 16 == 0, name


def test_batch_spec_fallbacks():
    from repro.launch.sharding import make_plan
    plan = make_plan(get_config("gemma2-27b"), MESH1)

    class M(FakeMesh):
        pass

    m = M({"data": 16, "model": 16})
    assert tuple(plan.batch_spec(m, 256)) != ()       # divides data
    assert tuple(plan.batch_spec(m, 1)) == ()         # replicated


def test_hlo_parser_trip_counts_and_bytes():
    hlo = """HloModule test, is_scheduled=true

%body.1 (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %ar = f32[8,128]{1,0} all-reduce(%x), to_apply=%add.1
}

%cond.1 (p: (s32[], f32[8,128])) -> pred[] {
  %c = s32[] constant(12)
}

ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %w = (s32[], f32[8,128]) while(%t), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"12"}}
  %ag = f32[128,128]{1,0} all-gather(%y), dimensions={0}
}
"""
    rep = parse_collectives(hlo)
    # all-reduce: 8*128*4 bytes * 2 (physical) * 12 (trip count)
    assert rep.bytes_by_kind["all-reduce"] == 8 * 128 * 4 * 2 * 12
    assert rep.bytes_by_kind["all-gather"] == 128 * 128 * 4
    assert rep.trip_counts.get("body.1") == 12


def test_costmodel_sane():
    from repro.configs import SHAPES
    from repro.utils.costmodel import attention_fraction, cell_cost
    cfg = get_config("gemma2-27b")
    cc = cell_cost(cfg, SHAPES["train_4k"], 256)
    # 6*N*D within 2x of the analytic total (remat factor + attention)
    n = cfg.param_count()
    d = 4096 * 256
    assert 0.8 * 6 * n * d < cc.flops < 3.0 * 6 * n * d
    af = attention_fraction(cfg, 4096, 2048, "train")
    assert 0.05 < af < 0.6


@pytest.mark.slow
def test_dryrun_subprocess_one_cell(tmp_path):
    """End-to-end launch check: one real cell lowers+compiles under the
    production mesh in a fresh interpreter (XLA_FLAGS must precede jax
    init)."""
    out = tmp_path / "dry"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "train_4k", "--out", str(out)],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=Path(__file__).parent.parent)
    assert "[OK]" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
    data = json.loads((out / "whisper-base_train_4k_pod1.json").read_text())
    assert data["ok"] and data["fits_hbm"]
    assert data["chips"] == 256
    assert data["collectives"]["total_bytes"] > 0
