"""ResidencyEngine oracle-equivalence tests: the O(N) engine must agree
bit-for-bit with the per-cut `_evaluate` oracle (est_seconds / hbm_bytes /
vmem_peak) on fuzzed heterogeneous stacks and on every arch the LM
residency benchmark plans, and its DP must pick the same modes as the
reference transition-by-transition DP."""
import random

import pytest
from hypothesis_compat import given, settings, st

from repro.core.hw import V5E
from repro.core.residency import (LMBlockSpec, ResidencyEngine, _evaluate,
                                  plan_cutpoint, plan_dp)

MB = 1 << 20


def rand_stack(n, seed):
    """Heterogeneous stack: stream/state/vmem vary per block (the shapes
    the boundary accounting and fit-gating must price correctly)."""
    rng = random.Random(seed)
    return [LMBlockSpec(
        idx=i,
        kind=rng.choice(["attn", "mlp", "moe", "cross", "vision"]),
        weight_bytes=rng.choice([8, 64, 512, 4096]) * MB,
        stream_bytes=rng.choice([1, 8, 64, 256]) * MB,
        act_bytes=rng.choice([4, 32, 256]) * MB,
        flops=rng.choice([10 ** 11, 10 ** 12, 10 ** 13]),
        state_bytes=rng.choice([0, 0, 16, 128]) * MB,
        vmem_resident=rng.choice([0, 0, 0, 32, 500]) * MB)
        for i in range(n)]


def reference_dp_modes(blocks, hw, vmem_budget=None):
    from benchmarks.residency_throughput import direct_dp
    modes, _, _ = direct_dp(blocks, hw, vmem_budget)
    return modes


def assert_engine_matches_oracle(blocks, vmem_budget=None):
    eng = ResidencyEngine(blocks, V5E, vmem_budget)
    for cut in range(len(blocks) + 1):
        modes, forced = eng.cut_modes(cut)
        oracle = _evaluate(blocks, modes, V5E)
        est, hbm, vmem = eng.evaluate_cut(cut)
        assert est == oracle.est_seconds          # bit-for-bit, no tolerance
        assert hbm == oracle.hbm_bytes
        assert vmem == oracle.vmem_peak
        assert all(modes[i] == "streaming" for i in forced)
    return eng


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10 ** 6))
def test_engine_cut_equivalence_fuzz(n, seed):
    blocks = rand_stack(n, seed)
    budget = random.Random(seed ^ 0xbeef).choice(
        [None, 16 * MB, 64 * MB, 256 * MB])
    assert_engine_matches_oracle(blocks, budget)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 10 ** 6))
def test_engine_dp_matches_reference_fuzz(n, seed):
    blocks = rand_stack(n, seed)
    budget = random.Random(seed ^ 0xcafe).choice([None, 16 * MB, 64 * MB])
    eng = ResidencyEngine(blocks, V5E, budget)
    assert eng.dp_modes() == reference_dp_modes(blocks, V5E, budget)
    # materialized plans go through the oracle, so bit-equality follows
    dp = plan_dp(blocks, V5E, budget, engine=eng)
    ref = _evaluate(blocks, reference_dp_modes(blocks, V5E, budget), V5E)
    assert (dp.est_seconds, dp.hbm_bytes, dp.vmem_peak) == \
        (ref.est_seconds, ref.hbm_bytes, ref.vmem_peak)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 10 ** 6))
def test_plan_cutpoint_matches_direct_sweep(n, seed):
    from benchmarks.residency_throughput import direct_sweep
    blocks = rand_stack(n, seed)
    plan = plan_cutpoint(blocks, V5E)
    direct, evals, _ = direct_sweep(blocks, V5E)
    assert evals == n + 1
    assert (plan.cut, plan.est_seconds, plan.hbm_bytes, plan.vmem_peak) == \
        (direct.cut, direct.est_seconds, direct.hbm_bytes, direct.vmem_peak)


@pytest.mark.parametrize("arch,shape", [
    ("granite-20b", "decode_32k"), ("granite-20b", "prefill_32k"),
    ("gemma2-27b", "decode_32k"), ("moonshot-v1-16b-a3b", "decode_32k"),
    ("smollm-360m", "decode_32k"), ("mamba2-2.7b", "decode_32k"),
    ("qwen3-moe-235b-a22b", "decode_32k"),
])
def test_engine_matches_oracle_on_lm_archs(arch, shape):
    from benchmarks.residency_lm import make_blocks
    from repro.configs import SHAPES, get_config
    blocks = make_blocks(get_config(arch), SHAPES[shape])
    eng = assert_engine_matches_oracle(blocks)
    assert eng.dp_modes() == reference_dp_modes(blocks, V5E)


def test_engine_synthetic_throughput_stacks():
    from benchmarks.residency_throughput import make_stack
    for kind in ("uniform-lm", "moe-interleave", "hetero-vision-cross"):
        assert_engine_matches_oracle(make_stack(kind, 64))


def test_engine_empty_and_single():
    assert plan_cutpoint([], V5E).modes == []
    assert plan_dp([], V5E).modes == []
    blocks = rand_stack(1, 7)
    assert_engine_matches_oracle(blocks)
