"""Branch-and-bound pruning of the cut space: the property/differential
layer proving the oracle-exactness invariant (core/cutpoint.py).

Three families of proof, per ISSUE 8:

* **Admissibility** -- ``CutpointEngine.prefix_bound`` is a true lower
  bound on every completion of a cut prefix, checked against brute force
  on small completion slices, across the whole zoo (seeded fuzz) and on
  hypothesis-generated random residual CNNs (the shared ``random_cnn``
  strategy in conftest.py).  At full depth the bound must EQUAL the
  exact primary metric -- the property the deflated-bound mutation class
  in analysis/mutate.py is killed by.
* **Bit-identity** -- pruned vs unpruned search returns the identical
  argmin cut + CandidateMetrics (and identical ``evaluated`` under
  ``count_pruned=True``) serially, at ``workers=2``, under
  ``replay="device"``, on the coordinate-descent fallback, and across a
  mid-search preemption (SIGTERM-latched guard) + ``resume_dir`` resume.
* **Mutation kill** -- every seeded deflate/inflate bound mutant must
  fail the differential suite, 100%, while the genuine bound survives.
"""
import itertools

import numpy as np
import pytest
from conftest import random_cnn
from hypothesis_compat import given, settings, st

from repro.analysis.mutate import (BOUND_CLASSES, bound_kill_matrix,
                                   bound_survives_differential)
from repro.cnn import build_cnn
from repro.core.cutpoint import (CutpointEngine, _key, branch_bound_subspace,
                                 search)
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
from repro.core.search_pool import ParallelSearchDriver, SearchPreempted
from repro.runtime.fault_tolerance import PreemptionGuard

from test_search_pool import ALL_CNNS, TEST_LIMIT, assert_results_identical

OBJECTIVES = ["latency", "sram", "dram"]
PRUNE_OPTS = CompileOptions(exhaustive_limit=TEST_LIMIT)


@pytest.fixture(scope="module")
def engines():
    return {name: CutpointEngine(group_nodes(build_cnn(name)), KCU1500)
            for name in ALL_CNNS}


def _small_slice_depth(dims, max_slice=256):
    """Deepest prefix depth whose completion count fits ``max_slice``."""
    depth, total = len(dims), 1
    while depth > 1 and total * dims[depth - 1] <= max_slice:
        depth -= 1
        total *= dims[depth]
    return depth


def _assert_bound_admissible(engine, prefix_tuple, depth, ctx=""):
    """Brute-force every completion of ``prefix_tuple[:depth]`` and check
    the bound key never exceeds any completion's objective key.  Returns
    the per-objective best completion key for callers that want to chain
    further (sound) one-sided checks against the same slice."""
    dims = [len(r) + 1 for r in engine.runs]
    batch = [prefix_tuple[:depth] + s for s in
             itertools.product(*[range(d) for d in dims[depth:]])]
    scored = engine.score_batch(batch, memoize=False)
    best = {}
    for obj in OBJECTIVES:
        lb = engine.prefix_bound(prefix_tuple, depth, obj)
        bound_key = (False, lb, 0)
        best[obj] = min(_key(c, obj) for c in scored)
        assert bound_key <= best[obj], (
            f"{ctx}: inadmissible {obj} bound at depth {depth}: "
            f"{bound_key} > best completion {best[obj]}")
    return best


# ------------------------------------------------------- admissibility
@pytest.mark.parametrize("name", ALL_CNNS)
def test_bound_admissible_fuzzed_prefixes_zoo(name, engines):
    """Fuzzed random prefixes on every zoo net: lower bound <= true best
    completion cost, brute-force verified on small completion slices."""
    engine = engines[name]
    dims = [len(r) + 1 for r in engine.runs]
    if not dims:
        pytest.skip("no monotone runs")
    rng = np.random.default_rng(abs(hash(name)) % (2 ** 31))
    depth = _small_slice_depth(dims)
    trials = 8 if len(dims) > 1 else 2
    for _ in range(trials):
        t = tuple(int(rng.integers(0, d)) for d in dims)
        if depth == len(dims):
            continue
        best = _assert_bound_admissible(engine, t, depth, ctx=name)
        # The brute-forced slice is a SUBSET of the completions of every
        # shallower prefix of t, and min over a subset >= min over the
        # superset, so each shallower bound must also stay <= the slice
        # minimum.  (One-sided: requires no depth-monotonicity of the
        # bound, only admissibility at each depth.)
        for d2 in range(1, depth):
            for obj in OBJECTIVES:
                lb = engine.prefix_bound(t, d2, obj)
                assert (False, lb, 0) <= best[obj], (
                    f"{name}: inadmissible {obj} bound at depth {d2}: "
                    f"lb={lb} vs slice best {best[obj]}")


@pytest.mark.parametrize("name", ALL_CNNS)
def test_bound_exact_at_full_depth_zoo(name, engines):
    """depth == len(runs): the completion is unique, so the bound must
    equal the exact primary metric bit-for-bit (all objectives) -- the
    property that kills deflated-bound mutants."""
    engine = engines[name]
    dims = [len(r) + 1 for r in engine.runs]
    if not dims:
        pytest.skip("no monotone runs")
    nr = len(dims)
    rng = np.random.default_rng(abs(hash(name + "x")) % (2 ** 31))
    for _ in range(6):
        t = tuple(int(rng.integers(0, d)) for d in dims)
        m = engine.evaluate(t, memoize=False)
        for obj in OBJECTIVES:
            lb = engine.prefix_bound(t, nr, obj)
            assert lb == _key(m, obj)[1], (
                f"{name}/{obj}: full-depth bound {lb!r} != exact "
                f"{_key(m, obj)[1]!r} at {t}")


@pytest.mark.slow
@settings(deadline=None)
@given(g=random_cnn(), data=st.data())
def test_bound_admissible_on_random_graphs(g, data):
    """The shared hypothesis graph strategy: admissibility + full-depth
    exactness must hold on random residual CNNs with shortcut fan-out,
    pools and upsamples -- not just the zoo."""
    gg = group_nodes(g)
    engine = CutpointEngine(gg, KCU1500)
    dims = [len(r) + 1 for r in engine.runs]
    if not dims:
        return
    t = tuple(data.draw(st.integers(0, d - 1), label=f"cut{i}")
              for i, d in enumerate(dims))
    depth = _small_slice_depth(dims, max_slice=128)
    if depth < len(dims):
        _assert_bound_admissible(engine, t, depth, ctx="random-graph")
    m = engine.evaluate(t, memoize=False)
    for obj in OBJECTIVES:
        assert engine.prefix_bound(t, len(dims), obj) == _key(m, obj)[1]


# --------------------------------------------------------- bit-identity
@pytest.mark.parametrize("name", ALL_CNNS)
def test_pruned_search_identical_serial(name):
    gg = group_nodes(build_cnn(name))
    unpruned = search(gg, KCU1500, PRUNE_OPTS.replace(prune=False))
    pruned = search(gg, KCU1500, PRUNE_OPTS)
    assert_results_identical(unpruned, pruned, ctx=f"serial-{name}")
    assert unpruned.pruned == 0


@pytest.mark.parametrize("objective", OBJECTIVES)
def test_pruned_search_identical_all_objectives(objective):
    gg = group_nodes(build_cnn("resnet50"))
    unpruned = search(gg, KCU1500,
                      PRUNE_OPTS.replace(objective=objective, prune=False))
    pruned = search(gg, KCU1500, PRUNE_OPTS.replace(objective=objective))
    assert_results_identical(unpruned, pruned, ctx=f"obj-{objective}")
    assert pruned.pruned > 0          # resnet50's space genuinely prunes


def test_pruned_search_identical_workers2():
    gg = group_nodes(build_cnn("resnet50"))
    unpruned = search(gg, KCU1500, PRUNE_OPTS.replace(prune=False))
    pruned = search(gg, KCU1500, PRUNE_OPTS.replace(workers=2))
    assert_results_identical(unpruned, pruned, ctx="workers2")


def test_pruned_search_identical_device_replay():
    gg = group_nodes(build_cnn("resnet50"))
    unpruned = search(gg, KCU1500, PRUNE_OPTS.replace(prune=False))
    pruned = search(gg, KCU1500, PRUNE_OPTS.replace(engine="device"))
    assert_results_identical(unpruned, pruned, ctx="device")
    pruned2 = search(gg, KCU1500,
                     PRUNE_OPTS.replace(workers=2, engine="device"))
    assert_results_identical(unpruned, pruned2, ctx="device-workers2")


def test_pruned_search_identical_coordinate_descent():
    """exhaustive_limit=1 forces descent, where pruning is a no-op by
    construction (a pruned trial could never win strict-< improvement):
    identical results, zero pruned."""
    gg = group_nodes(build_cnn("resnet50"))
    unpruned = search(gg, KCU1500,
                      CompileOptions(exhaustive_limit=1, prune=False))
    pruned = search(gg, KCU1500, CompileOptions(exhaustive_limit=1))
    assert_results_identical(unpruned, pruned, ctx="descent")
    assert pruned.pruned == 0


def test_pruned_search_resumes_after_preemption(tmp_path):
    """Mid-search preemption (latched SIGTERM guard) + resume_dir: the
    resumed pruned search merges to the unpruned serial result, with the
    journal's partially-complete task set feeding the incumbent."""
    gg = group_nodes(build_cnn("resnet50"))
    serial = search(gg, KCU1500, PRUNE_OPTS.replace(prune=False))
    guard = PreemptionGuard()
    guard.request()                        # SIGTERM already latched
    with ParallelSearchDriver(workers=2, guard=guard) as d:
        with pytest.raises(SearchPreempted, match="resume to finish"):
            d.search(gg, KCU1500, PRUNE_OPTS.replace(resume_dir=tmp_path))
    with ParallelSearchDriver(workers=2) as d:
        r = d.search(gg, KCU1500, PRUNE_OPTS.replace(resume_dir=tmp_path))
    assert_results_identical(serial, r, ctx="preempt-resume")


def test_count_pruned_accounting():
    """count_pruned=True (default): evaluated == full enumeration count.
    count_pruned=False: evaluated counts only scored candidates, and
    scored + pruned == the enumeration count."""
    gg = group_nodes(build_cnn("resnet50"))
    base = search(gg, KCU1500, PRUNE_OPTS.replace(prune=False))
    counted = search(gg, KCU1500, PRUNE_OPTS)
    raw = search(gg, KCU1500, PRUNE_OPTS.replace(count_pruned=False))
    assert counted.evaluated == base.evaluated
    assert raw.evaluated + raw.pruned == base.evaluated
    assert raw.best.cuts == base.best.cuts


# ------------------------------------------------- subspace-level checks
def test_branch_bound_subspace_prune_off_is_plain_enumeration():
    """prune=False must degenerate to the chunked exhaustive walk: same
    argmin, same evaluations, zero pruned."""
    gg = group_nodes(build_cnn("vgg16-conv"))
    e1 = CutpointEngine(gg, KCU1500)
    e2 = CutpointEngine(gg, KCU1500)
    dims = [len(r) for r in e1.runs]
    b1, p1 = branch_bound_subspace(e1, (), dims, "latency", prune=False)
    b2, p2 = branch_bound_subspace(e2, (), dims, "latency", prune=True)
    assert p1 == 0
    assert b1.cuts == b2.cuts
    assert _key(b1, "latency") == _key(b2, "latency")
    space = 1
    for d in dims:
        space *= d + 1
    assert e1.evaluations == space
    assert e2.evaluations + p2 == space


def test_branch_bound_subspace_external_incumbent_can_prune_everything():
    """An unbeatable external incumbent prunes the whole sub-space: best
    is None and pruned counts every bounded-away candidate (the parallel
    driver's fully-pruned-task case)."""
    gg = group_nodes(build_cnn("resnet50"))
    engine = CutpointEngine(gg, KCU1500)
    dims = [len(r) for r in engine.runs]
    best, pruned = branch_bound_subspace(
        engine, (), dims, "latency",
        incumbent_key=(False, -1.0, 0), prune=True)
    space = 1
    for d in dims:
        space *= d + 1
    assert best is None
    assert pruned + engine.evaluations == space
    assert pruned > 0


def test_score_batch_skip_mask_contract():
    """Skipped lanes return None, are never replayed, and do not count
    toward evaluations; surviving lanes are bit-identical."""
    gg = group_nodes(build_cnn("vgg16-conv"))
    engine = CutpointEngine(gg, KCU1500)
    dims = [len(r) + 1 for r in engine.runs]
    batch = list(itertools.product(*[range(d) for d in dims]))[:8]
    ref = CutpointEngine(gg, KCU1500).score_batch(batch, memoize=False)
    skip = [i % 2 == 1 for i in range(len(batch))]
    out = engine.score_batch(batch, memoize=False, skip=skip)
    assert engine.evaluations == len(batch) - sum(skip)
    for c, r, s in zip(out, ref, skip):
        if s:
            assert c is None
        else:
            assert c.cuts == r.cuts and _key(c, "latency") == _key(
                r, "latency")
    with pytest.raises(ValueError, match="memoize=False"):
        engine.score_batch(batch, memoize=True, skip=skip)


def test_score_batch_skip_mask_device_replay():
    gg = group_nodes(build_cnn("vgg16-conv"))
    ref_e = CutpointEngine(gg, KCU1500)
    dev_e = CutpointEngine(gg, KCU1500, replay="device")
    dims = [len(r) + 1 for r in ref_e.runs]
    batch = list(itertools.product(*[range(d) for d in dims]))[:8]
    skip = [i % 3 == 0 for i in range(len(batch))]
    ref = ref_e.score_batch(batch, memoize=False, skip=skip)
    dev = dev_e.score_batch(batch, memoize=False, skip=skip)
    assert ref_e.evaluations == dev_e.evaluations
    for a, b in zip(ref, dev):
        if a is None:
            assert b is None
            continue
        for f in ("latency_cycles", "dram_total", "dram_fm", "sram_total",
                  "bram18k", "feasible"):
            assert getattr(a, f) == getattr(b, f)


# ----------------------------------------------------- mutation-kill gate
def test_bound_differential_sound(engines):
    """The genuine bound passes its own differential suite (a gate that
    kills everything proves nothing)."""
    for name in ("vgg16-conv", "resnet50", "mobilenet-v3"):
        assert bound_survives_differential(engines[name], seed=0,
                                           probes=4), name


def test_bound_mutation_kill_matrix(engines):
    """100% kill: every deflate/inflate bound mutant on every probed net
    must fail the differential suite."""
    probe = {n: engines[n]
             for n in ("vgg16-conv", "resnet50", "mobilenet-v3", "yolov2")}
    rows = bound_kill_matrix(probe, seeds=(0, 1, 2), probes=4)
    missed = [r for r in rows if not r["killed"]]
    assert not missed, f"bound mutants survived the differential: {missed}"
    assert {r["cls"] for r in rows} == set(BOUND_CLASSES)
