"""The unified CompileOptions API (core/options.py) and its deprecation
shim: field validation, the plan-affecting / scheduling-only split, the
legacy-kwarg mapping, and the journal-key regression the split fixed
(resume_dir journals keyed on ``plan_key()``, so pruned vs unpruned runs
sharing a resume_dir can never cross-resume)."""
import warnings

import pytest

from repro.cnn import build_cnn
from repro.core.compiler import compile_graph
from repro.core.cutpoint import search
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import (PLAN_FIELDS, SCHEDULE_FIELDS,
                                CompileOptions, EngineSpec,
                                LegacyKnobWarning, degrade_engine,
                                resolve_engine, resolve_options)
from repro.core.search_pool import ParallelSearchDriver

from test_search_pool import TEST_LIMIT, assert_results_identical

TEST_OPTS = CompileOptions(exhaustive_limit=TEST_LIMIT)


# ------------------------------------------------------------ dataclass
def test_defaults_and_replace():
    o = CompileOptions()
    assert o.objective == "latency" and o.workers == 1
    assert o.replace(workers=4).workers == 4
    assert o.workers == 1                  # frozen: replace copies
    with pytest.raises(Exception):         # FrozenInstanceError
        o.workers = 2


@pytest.mark.parametrize("bad", [
    {"objective": "bogus"}, {"engine": "tape"}, {"backend": "cuda"},
    {"verify": "loose"}, {"exhaustive_limit": -1}, {"batch_size": 0},
    {"workers": 0}, {"max_retries": -1}, {"task_deadline_s": 0.0},
])
def test_validation_rejects_bad_values(bad):
    with pytest.raises(ValueError):
        CompileOptions(**bad)


def test_plan_key_schedule_partition_all_fields():
    """Every field is in exactly one of the two views; replacing a
    scheduling field never changes plan_key() and vice versa."""
    import dataclasses
    names = {f.name for f in dataclasses.fields(CompileOptions)}
    assert set(PLAN_FIELDS) | set(SCHEDULE_FIELDS) == names
    assert not set(PLAN_FIELDS) & set(SCHEDULE_FIELDS)
    base = CompileOptions()
    sched = base.replace(workers=8, batch_size=2, engine="device",
                         max_retries=0, verify="warn")
    assert sched.plan_key() == base.plan_key()
    assert sched.schedule() != base.schedule()
    plan = base.replace(objective="sram", prune=False)
    assert plan.plan_key() != base.plan_key()
    assert plan.schedule() == base.schedule()


def test_schedule_normalizes_resume_dir(tmp_path):
    sched = dict(CompileOptions(resume_dir=tmp_path).schedule())
    assert sched["resume_dir"] == str(tmp_path)


def test_options_hashable_and_equal():
    assert CompileOptions() == CompileOptions()
    assert hash(CompileOptions(workers=2)) == hash(CompileOptions(workers=2))


# ------------------------------------------------------- engine grammar
@pytest.mark.parametrize("spelling,name,variant,batch", [
    ("journal", "journal", "", None),
    ("journal@256", "journal", "", 256),
    ("device", "device", "reference", None),
    ("device:reference", "device", "reference", None),
    ("device:scan", "device", "scan", None),
    ("device:pallas@2048", "device", "pallas", 2048),
    ("pipeline:reference", "pipeline", "reference", None),
    ("pipeline:lax", "pipeline", "lax", None),
    ("pipeline:pallas", "pipeline", "pallas", None),
    ("pipeline:lax@512", "pipeline", "lax", 512),
])
def test_engine_grammar_accepts(spelling, name, variant, batch):
    spec = resolve_engine(spelling)
    assert spec.name == name
    if variant:                       # "" = engine-default, checked below
        assert spec.variant == variant
    assert spec.batch_size == batch
    # every valid spelling is also a valid CompileOptions value
    assert CompileOptions(engine=spelling).engine == spelling


def test_engine_grammar_default_variants():
    assert resolve_engine("journal").variant == ""
    assert resolve_engine("device").variant == "reference"
    # pipeline auto-selects lax when jax imports (it is baked into the
    # test environment), the numpy reference otherwise
    assert resolve_engine("pipeline").variant in ("lax", "reference")


@pytest.mark.parametrize("bad", [
    "tape", "device:cuda", "pipeline:jit", "journal:fast", "device@0",
    "device@-1", "device@x", "pipeline@", "", 42, None,
])
def test_engine_grammar_rejects(bad):
    with pytest.raises(ValueError):
        resolve_engine(bad)
    if isinstance(bad, str):
        with pytest.raises(ValueError):
            CompileOptions(engine=bad)


def test_engine_spec_spelling_roundtrip():
    for spelling in ("journal", "journal@64", "device:scan",
                     "device:pallas@2048", "pipeline:lax@512"):
        spec = resolve_engine(spelling)
        assert resolve_engine(spec.spelling()) == spec, spelling
    assert EngineSpec("journal", "", None).spelling() == "journal"


def test_engine_spec_batch_override():
    """An @N suffix wins over the batch_size field; otherwise the field
    fills the spec."""
    assert CompileOptions(engine="journal@64",
                          batch_size=1024).engine_spec().batch_size == 64
    assert CompileOptions(engine="journal",
                          batch_size=77).engine_spec().batch_size == 77


@pytest.mark.parametrize("engine,want", [
    ("journal", "journal"), ("device", "journal"),
    ("device:pallas", "journal"), ("pipeline:lax", "journal"),
    ("pipeline:lax@512", "journal@512"), ("device@128", "journal@128"),
])
def test_degrade_engine_routes_to_journal(engine, want):
    assert degrade_engine(engine) == want
    # degraded spellings are themselves valid and stable
    assert degrade_engine(want) == want


# ------------------------------------------------------------ the shim
def test_legacy_kwargs_warn_and_map():
    with pytest.warns(LegacyKnobWarning, match="compile_test"):
        opts = resolve_options(None, {"workers": 3, "prune": False},
                               site="compile_test")
    assert opts == CompileOptions(workers=3, prune=False)


@pytest.mark.parametrize("replay,engine", [
    ("journal", "journal"), ("device", "device"),
])
def test_retired_replay_knob_maps_onto_engine(replay, engine):
    """The retired ``replay=`` spelling lands on ``engine=`` with the
    meaning unchanged, under the usual LegacyKnobWarning."""
    with pytest.warns(LegacyKnobWarning):
        opts = resolve_options(None, {"replay": replay}, site="s")
    assert opts == CompileOptions(engine=engine)


def test_replay_plus_engine_is_type_error():
    with pytest.raises(TypeError, match="not both"):
        resolve_options(None, {"replay": "device", "engine": "device"},
                        site="s")


def test_retired_replay_shim_equivalent_search():
    """End to end: the legacy ``replay="device"`` spelling must produce
    the identical SearchResult as ``engine="device"`` via options."""
    gg = group_nodes(build_cnn("vgg16-conv"))
    via_opts = search(gg, KCU1500,
                      TEST_OPTS.replace(engine="device"))
    with pytest.warns(LegacyKnobWarning):
        via_legacy = search(gg, KCU1500, replay="device",
                            exhaustive_limit=TEST_LIMIT)
    assert_results_identical(via_opts, via_legacy, ctx="shim-replay")


def test_unknown_legacy_kwarg_is_type_error():
    with pytest.raises(TypeError, match="nworkers"):
        resolve_options(None, {"nworkers": 3}, site="s")


def test_options_plus_legacy_is_type_error():
    with pytest.raises(TypeError, match="not both"):
        resolve_options(CompileOptions(), {"workers": 3}, site="s")


def test_non_options_object_is_type_error():
    with pytest.raises(TypeError, match="CompileOptions"):
        resolve_options({"workers": 3}, {}, site="s")


def test_entry_points_accept_legacy_spelling():
    """All three entry points still accept the old loose kwargs (under a
    LegacyKnobWarning) and produce the same plan as the options path."""
    gg = group_nodes(build_cnn("vgg16-conv"))
    via_opts = search(gg, KCU1500, TEST_OPTS)
    with pytest.warns(LegacyKnobWarning):
        via_legacy = search(gg, KCU1500, exhaustive_limit=TEST_LIMIT)
    assert_results_identical(via_opts, via_legacy, ctx="shim-search")
    with pytest.warns(LegacyKnobWarning):
        with ParallelSearchDriver(workers=2) as d:
            via_driver = d.search(gg, KCU1500,
                                  exhaustive_limit=TEST_LIMIT)
    assert_results_identical(via_opts, via_driver, ctx="shim-driver")
    g = build_cnn("vgg16-conv")
    p1 = compile_graph(g, options=TEST_OPTS)
    with pytest.warns(LegacyKnobWarning):
        p2 = compile_graph(g, exhaustive_limit=TEST_LIMIT)
    assert p1.candidate.cuts == p2.candidate.cuts
    assert p1.latency.cycles == p2.latency.cycles


def test_no_warning_on_options_path():
    gg = group_nodes(build_cnn("vgg16-conv"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", LegacyKnobWarning)
        search(gg, KCU1500, TEST_OPTS)


# ----------------------------------------------- journal-key regression
def test_journal_key_includes_plan_fields(tmp_path):
    """Regression for the PR 6 journal key: a pruned and an unpruned
    search sharing one resume_dir must write DIFFERENT journals -- the
    old payload-only key made the second run resume the first run's
    completed tasks and return its (differently-accounted) result."""
    gg = group_nodes(build_cnn("resnet50"))
    opts = TEST_OPTS.replace(workers=2, resume_dir=tmp_path)
    pruned = search(gg, KCU1500, opts)
    unpruned = search(gg, KCU1500, opts.replace(prune=False))
    assert_results_identical(pruned, unpruned, ctx="journal-key")
    assert unpruned.pruned == 0            # genuinely re-ran, not resumed
    assert pruned.pruned > 0
    journals = list(tmp_path.glob("*"))
    assert len(journals) >= 2, (
        f"pruned/unpruned shared a journal: {journals}")


def test_journal_key_distinguishes_count_pruned(tmp_path):
    gg = group_nodes(build_cnn("resnet50"))
    opts = TEST_OPTS.replace(workers=2, resume_dir=tmp_path)
    counted = search(gg, KCU1500, opts)
    raw = search(gg, KCU1500, opts.replace(count_pruned=False))
    assert counted.best.cuts == raw.best.cuts
    assert raw.evaluated + raw.pruned == counted.evaluated
    assert raw.evaluated < counted.evaluated
