"""Parameter definitions and core layer math (pure JAX, framework-free).

Every parameter is declared once as a :class:`ParamDef` carrying its shape,
dtype, initializer and *logical axis names*; from the same definition tree we
derive (a) materialized params for smoke tests/examples, (b) abstract
ShapeDtypeStructs for the multi-pod dry-run, and (c) PartitionSpecs through
the per-arch logical->mesh rules in launch/sharding.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------- ParamDef
@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis per dim
    dtype: str = "float32"
    init: str = "normal"                  # normal | zeros | ones
    scale: float = 0.0                    # 0 -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def D(shape, axes, init="normal", scale=0.0, dtype="float32") -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), dtype, init, scale)


def materialize(defs, rng: jax.Array, dtype_override: str | None = None):
    """ParamDef tree -> array tree (deterministic per-leaf fold-in)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    out = []
    for i, d in enumerate(leaves):
        key = jax.random.fold_in(rng, i)
        dt = jnp.dtype(dtype_override or d.dtype)
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dt)
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(1, d.shape[-1])
            scale = d.scale or (1.0 / np.sqrt(fan_in))
            arr = (scale * jax.random.truncated_normal(
                key, -2.0, 2.0, d.shape, jnp.float32)).astype(dt)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract(defs, dtype_override: str | None = None):
    """ParamDef tree -> ShapeDtypeStruct tree (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape,
                                       jnp.dtype(dtype_override or d.dtype)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def partition_specs(defs, rules: dict[str | None, str | None]):
    """ParamDef tree -> PartitionSpec tree via logical->mesh axis rules.

    A logical axis missing from ``rules`` is replicated.  Two logical axes
    mapping to the same mesh axis would be illegal; rules authors must keep
    them distinct per tensor (validated here)."""
    from jax.sharding import PartitionSpec as P

    def one(d: ParamDef):
        mesh_axes = []
        used: set = set()
        for ax in d.axes:
            m = rules.get(ax)
            if m is not None and m in used:
                m = None                      # avoid double-mapping
            if m is not None:
                if isinstance(m, tuple):
                    used.update(m)
                else:
                    used.add(m)
            mesh_axes.append(m)
        return P(*mesh_axes)

    return jax.tree.map(one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# -------------------------------------------------------------- grad fence
@jax.custom_vjp
def grad_fence(x):
    """Identity forward; backward casts the cotangent to the primal dtype.

    Attention computes scores/softmax in fp32 (as it must), so without a
    fence the cotangents leaving its backward are fp32 and every TP dx
    all-reduce moves twice the bytes.  Production flash kernels emit bf16
    dq/dk/dv; this reproduces that contract for the XLA path."""
    return x


def _fence_fwd(x):
    return x, jnp.zeros((), x.dtype)


def _fence_bwd(res, g):
    return (g.astype(res.dtype),)


grad_fence.defvjp(_fence_fwd, _fence_bwd)


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [*, S] -> (sin, cos) each [*, S, head_dim/2], fp32."""
    freq = theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32)
                     / (head_dim // 2))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., S, H, hd]; sin/cos [..., S, hd/2] broadcast over heads."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


# ------------------------------------------------------------- activations
def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    raise ValueError(name)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------- embedding
def embed_defs(cfg) -> dict:
    # std 1/sqrt(d): input scaling by sqrt(d) then yields unit-RMS inputs
    # and unit-scale tied-unembed logits.
    d = {"tok": D((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                  scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        d["head"] = D((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return d


def embed_lookup(embed: dict, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(embed["tok"], tokens, axis=0)
    # gemma-style sqrt(d) scaling keeps tied heads sane
    return (x * np.sqrt(cfg.d_model)).astype(jnp.dtype(cfg.dtype))


def unembed(embed: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, embed["tok"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, embed["head"].astype(x.dtype))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


# --------------------------------------------------------------------- MLP
def mlp_defs(cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    out = {
        "pre_norm": D((d,), ("embed",), init="zeros"),
        "w_up": D((d, ff), ("embed", "ff")),
        "w_down": D((ff, d), ("ff", "embed")),
    }
    if cfg.mlp_gated:
        out["w_gate"] = D((d, ff), ("embed", "ff"))
    if cfg.sandwich_norm:
        out["post_norm"] = D((d,), ("embed",), init="zeros")
    return out


def mlp_apply(p: dict, x: jax.Array, cfg) -> jax.Array:
    """(Gated-)linear-unit MLP with residual; the resident-mode Pallas
    kernel (kernels/fused_block.py) fuses exactly this function."""
    h = rms_norm(x, p["pre_norm"])
    u = h @ p["w_up"].astype(h.dtype)
    if cfg.mlp_gated:
        a = act_fn(cfg.act)(h @ p["w_gate"].astype(h.dtype))
        u = a * u
    else:
        u = act_fn(cfg.act)(u)
    y = u @ p["w_down"].astype(h.dtype)
    if cfg.sandwich_norm:
        y = rms_norm(y, p["post_norm"])
    return x + y


# ---------------------------------------------------------------- losses
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -1) -> jax.Array:
    """logits [..., V] fp32, labels int [...]."""
    mask = (labels != ignore_id)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
