"""Unified model API over the 10 assigned architectures.

  model = Model(cfg)
  params = model.init(rng)                     # or .abstract_params()
  loss, metrics = model.loss(params, batch)    # train
  logits, cache = model.prefill(params, batch)
  logits, cache = model.decode_step(params, cache, tokens, pos)

Batch dicts (all int32 unless noted):
  train:   {"tokens": [B,S], "labels": [B,S]}            (+ stubs below)
  prefill: {"tokens": [B,S]}                              (+ stubs below)
  audio adds  "frames":  [B, enc_seq, d]  bf16  (conv frontend STUB)
  vlm   adds  "patches": [B, vision_seq, d] bf16 (vision tower STUB)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import (D, abstract, cross_entropy, embed_defs,
                                 embed_lookup, materialize, partition_specs,
                                 rms_norm, softcap)
from repro.models.transformer import apply_stack, stack_cache, stack_defs

LOSS_CHUNK = 8192      # tokens per unembed chunk (bounds logits memory)


def _sinusoidal(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10000 ** (2 * i / d))
    return np.concatenate([np.sin(ang), np.cos(ang)], -1).astype(np.float32)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- params
    def param_defs(self) -> dict:
        cfg = self.cfg
        defs = {
            "embed": embed_defs(cfg),
            "stack": stack_defs(cfg, decoder=True),
            "final_norm": D((cfg.d_model,), ("embed",), init="zeros"),
        }
        if cfg.family == "audio":
            defs["enc_stack"] = stack_defs(cfg, decoder=False)
            defs["enc_norm"] = D((cfg.d_model,), ("embed",), init="zeros")
        return defs

    def init(self, rng: jax.Array, dtype: str | None = None):
        return materialize(self.param_defs(), rng, dtype)

    def abstract_params(self, dtype: str | None = None):
        return abstract(self.param_defs(), dtype)

    def pspecs(self, rules: dict):
        return partition_specs(self.param_defs(), rules)

    # -------------------------------------------------------------- stubs
    def _context(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            frames = batch["frames"].astype(jnp.dtype(cfg.dtype))
            pe = jnp.asarray(_sinusoidal(frames.shape[1], cfg.d_model),
                             frames.dtype)
            x = frames + pe
            x, _, _ = apply_stack(params["enc_stack"], x, cfg, decoder=False,
                                  remat="none")
            return rms_norm(x, params["enc_norm"])
        if cfg.family == "vlm":
            return batch["patches"].astype(jnp.dtype(cfg.dtype))
        return None

    # --------------------------------------------------------------- loss
    def loss(self, params, batch, remat: str = "full"):
        cfg = self.cfg
        ctx = self._context(params, batch)
        x = embed_lookup(params["embed"], batch["tokens"], cfg)
        x, _, aux = apply_stack(params["stack"], x, cfg, cache=None,
                                pos=0, ctx=ctx, remat=remat)
        x = rms_norm(x, params["final_norm"])
        nll = self._chunked_xent(params, x, batch["labels"])
        return nll + aux, {"nll": nll, "aux": aux}

    def _chunked_xent(self, params, x, labels):
        """Cross entropy scanned over *sequence* chunks: the batch dim stays
        intact (and batch-sharded -- reshaping across batch would force XLA
        to all-gather the full activations), logits memory is bounded to
        [B, chunk, V/shard], and each chunk is rematerialized in the
        backward pass."""
        cfg = self.cfg
        B, S, d = x.shape
        chunk = min(max(1, LOSS_CHUNK // B), S)
        n = S // chunk
        rem = S - n * chunk

        def unembed_chunk(xc):
            if cfg.tie_embeddings:
                logits = jnp.einsum("bsd,vd->bsv", xc,
                                    params["embed"]["tok"].astype(xc.dtype))
            else:
                logits = jnp.einsum("bsd,dv->bsv", xc,
                                    params["embed"]["head"].astype(xc.dtype))
            return softcap(logits.astype(jnp.float32), cfg.final_softcap)

        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def step(acc, inp):
            xc, lc = inp
            logits = unembed_chunk(xc)
            mask = lc != -1
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None].clip(0),
                                       axis=-1)[..., 0]
            return (acc[0] + ((lse - gold) * mask).sum(),
                    acc[1] + mask.sum()), None

        xs = (x[:, :n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1),
              labels[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1))
        (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.int32(0)), xs)
        if rem:
            (tot, cnt), _ = step((tot, cnt),
                                 (x[:, n * chunk:], labels[:, n * chunk:]))
        return tot / jnp.maximum(cnt, 1)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int, dtype=None):
        dtype = jnp.dtype(self.cfg.dtype) if dtype is None else dtype
        cache = stack_cache(self.cfg, batch, max_len, decoder=True,
                            dtype=dtype)
        cache["pos"] = jnp.zeros((), jnp.int32)
        return cache

    def abstract_cache(self, batch: int, max_len: int, dtype=None):
        return jax.eval_shape(
            partial(self.init_cache, batch, max_len, dtype))

    def prefill(self, params, batch, cache=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cache is None:
            # Cache must cover the planned decode horizon, not just S.
            cache = self.init_cache(B, max(cfg.max_seq, S))
        ctx = self._context(params, batch)
        x = embed_lookup(params["embed"], tokens, cfg)
        pos = cache["pos"]
        stack_c = {k: v for k, v in cache.items() if k != "pos"}
        x, new_cache, _ = apply_stack(params["stack"], x, cfg, cache=stack_c,
                                      pos=pos, ctx=ctx, remat="none",
                                      fill_cross=True)
        x = rms_norm(x, params["final_norm"])
        logits = self._last_logits(params, x)
        new_cache["pos"] = pos + S
        return logits, new_cache

    def decode_step(self, params, cache, tokens, pos=None):
        """tokens [B,1]; returns (logits [B,V], new_cache)."""
        cfg = self.cfg
        pos = cache["pos"] if pos is None else pos
        x = embed_lookup(params["embed"], tokens, cfg)
        stack_c = {k: v for k, v in cache.items() if k != "pos"}
        x, new_cache, _ = apply_stack(params["stack"], x, cfg, cache=stack_c,
                                      pos=pos, ctx=None, remat="none")
        x = rms_norm(x, params["final_norm"])
        logits = self._last_logits(params, x)
        new_cache["pos"] = pos + tokens.shape[1]
        return logits, new_cache

    def _last_logits(self, params, x):
        cfg = self.cfg
        xl = x[:, -1]
        if cfg.tie_embeddings:
            logits = jnp.einsum("bd,vd->bv", xl,
                                params["embed"]["tok"].astype(xl.dtype))
        else:
            logits = jnp.einsum("bd,dv->bv", xl,
                                params["embed"]["head"].astype(xl.dtype))
        return softcap(logits.astype(jnp.float32), cfg.final_softcap)

    # --------------------------------------------------------- batch specs
    def batch_spec(self, seq_len: int, batch: int, mode: str) -> dict:
        """ShapeDtypeStructs for every model input of a shape cell."""
        cfg = self.cfg
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        spec: dict = {}
        if mode == "train":
            spec["tokens"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
            spec["labels"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
        elif mode == "prefill":
            spec["tokens"] = jax.ShapeDtypeStruct((batch, seq_len), i32)
        elif mode == "decode":
            spec["tokens"] = jax.ShapeDtypeStruct((batch, 1), i32)
        if cfg.family == "audio" and mode != "decode":
            spec["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.enc_seq, cfg.d_model), bf16)
        if cfg.family == "vlm" and mode != "decode":
            spec["patches"] = jax.ShapeDtypeStruct(
                (batch, cfg.vision_seq, cfg.d_model), bf16)
        return spec


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
