"""Composable block definitions + scan-over-layer-groups stack.

Layer kinds ('global' | 'local' | 'cross' | 'ssm' | 'recurrent' | 'enc' |
'encdec') are cycled per the config ``pattern``; one *group* = one full
pattern cycle, and the stack is a lax.scan over stacked group params, which
keeps the lowered HLO size independent of depth (94-layer qwen3 compiles as
fast as 6-layer whisper).  A remainder (depth % pattern) is applied as
explicit tail layers (e.g. recurrentgemma's 26 = 3*8 + 2).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.attention import (blocked_attention, cache_update,
                                    cp_attention, init_kv_cache,
                                    plain_attention, ring_positions)
from repro.models.layers import (D, ParamDef, apply_rope, grad_fence,
                                 mlp_apply, mlp_defs, rms_norm, rope_angles)
from repro.models.mamba2 import init_ssm_state, ssm_apply, ssm_defs
from repro.models.moe import moe_apply, moe_defs
from repro.models.rglru import init_rglru_state, rglru_apply, rglru_defs

# --------------------------------------------------------------- sharding ctx
_CTX: dict = {"batch_axes": None, "model_axis": None, "mesh": None,
              "seq_shard": False, "cp": False}


def set_mesh_axes(batch_axes=None, model_axis=None, mesh=None,
                  seq_shard: bool = False, cp: bool = False) -> None:
    _CTX["batch_axes"] = batch_axes
    _CTX["model_axis"] = model_axis
    _CTX["mesh"] = mesh
    _CTX["seq_shard"] = seq_shard
    _CTX["cp"] = cp


def shard_hidden(x: jax.Array) -> jax.Array:
    """Constrain activations between blocks.  Default: batch-sharded,
    feature-replicated.  With seq_shard (Megatron-SP, TP plans): the
    sequence dim additionally shards over the model axis, so the TP
    boundary all-reduces lower to reduce-scatter + all-gather (half the
    physical link bytes) and the resident stream per device shrinks
    n_model-fold."""
    if _CTX["batch_axes"] is None:
        return x
    from jax.sharding import PartitionSpec as P
    seq_ax = _CTX["model_axis"] if (_CTX["seq_shard"] and x.ndim == 3) \
        else None
    spec = P(_CTX["batch_axes"], seq_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------------------- definitions
def attn_defs(cfg, kind: str) -> dict:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        "pre_norm": D((d,), ("embed",), init="zeros"),
        "wq": D((d, nh * hd), ("embed", "heads")),
        "wk": D((d, nkv * hd), ("embed", "kv")),
        "wv": D((d, nkv * hd), ("embed", "kv")),
        "wo": D((nh * hd, d), ("heads", "embed")),
    }
    if cfg.sandwich_norm:
        out["post_norm"] = D((d,), ("embed",), init="zeros")
    if cfg.qk_norm:
        out["q_norm"] = D((hd,), (None,), init="zeros")
        out["k_norm"] = D((hd,), (None,), init="zeros")
    if kind == "cross" and cfg.family == "vlm":
        out["gate_attn"] = D((), (), init="zeros")
        out["gate_mlp"] = D((), (), init="zeros")
    return out


def ffn_defs(cfg) -> dict:
    return moe_defs(cfg) if cfg.n_experts else mlp_defs(cfg)


def layer_defs(cfg, kind: str) -> dict:
    if kind == "ssm":
        return {"ssm": ssm_defs(cfg)}
    if kind == "recurrent":
        return {"rglru": rglru_defs(cfg), "ffn": mlp_defs(cfg)}
    if kind == "encdec":                       # whisper decoder layer
        return {"attn": attn_defs(cfg, "global"),
                "xattn": attn_defs(cfg, "cross"),
                "ffn": ffn_defs(cfg)}
    return {"attn": attn_defs(cfg, kind), "ffn": ffn_defs(cfg)}


# ------------------------------------------------------------ attention op
def attn_apply(p: dict, x: jax.Array, cfg, kind: str, *,
               cache: dict | None = None, pos=0, ctx: jax.Array | None = None,
               causal: bool = True, fill_cross: bool = False):
    """One attention sub-block with residual.  Returns (y, new_cache)."""
    B, S, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["pre_norm"])
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, S, nh, hd)

    cross = kind == "cross"
    new_cache = cache
    if cross:
        if cache is not None and not fill_cross:
            k, v = cache["ck"], cache["cv"]       # decode: precomputed
        else:
            assert ctx is not None, "cross layer needs context"
            k = (ctx @ p["wk"].astype(ctx.dtype)).reshape(
                B, ctx.shape[1], nkv, hd)
            v = (ctx @ p["wv"].astype(ctx.dtype)).reshape(
                B, ctx.shape[1], nkv, hd)
            if cache is not None:                 # prefill: store
                new_cache = {"ck": k.astype(cache["ck"].dtype),
                             "cv": v.astype(cache["cv"].dtype)}
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        attn_fn = plain_attention if cache is None else blocked_attention
        out = attn_fn(q, k, v, causal=False,
                      softcap_val=cfg.attn_softcap)
    else:
        k = (h @ p["wk"].astype(h.dtype)).reshape(B, S, nkv, hd)
        v = (h @ p["wv"].astype(h.dtype)).reshape(B, S, nkv, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
            k = rms_norm(k, p["k_norm"])
        sin, cos = rope_angles(pos + jnp.arange(S), hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        window = cfg.window if kind == "local" else 0
        if cache is None:
            # training path: differentiable, remat-friendly; the fence
            # keeps dq/dk/dv in the activation dtype (see grad_fence)
            out = plain_attention(
                grad_fence(q), grad_fence(k), grad_fence(v),
                q_offset=pos, causal=causal, window=window,
                softcap_val=cfg.attn_softcap)
        else:
            ring = cache["k"].shape[1] < cfg.max_seq
            new_cache = cache_update(cache, k, v, pos, ring=ring)
            if S > 1:
                # Prefill: attend the fresh full K/V (prefill starts at
                # pos 0; the cache is only written for later decode).  For
                # ring caches this is also required for correctness: the
                # trimmed ring has dropped early keys.
                mesh = _CTX.get("mesh")
                use_cp = (_CTX.get("cp") and mesh is not None
                          and _CTX.get("model_axis")
                          and _CTX["model_axis"] not in
                          (_CTX.get("batch_axes") or ())
                          and S % mesh.shape[_CTX["model_axis"]] == 0)
                if use_cp:
                    out = cp_attention(
                        q, k, v, mesh=mesh,
                        batch_axes=tuple(_CTX["batch_axes"]),
                        model_axis=_CTX["model_axis"],
                        causal=True, window=window,
                        softcap_val=cfg.attn_softcap)
                else:
                    out = blocked_attention(
                        q, k, v, q_offset=pos, causal=True, window=window,
                        softcap_val=cfg.attn_softcap)
            elif ring:
                kpos = ring_positions(pos + S, cache["k"].shape[1])
                out = blocked_attention(
                    q, new_cache["k"], new_cache["v"], q_offset=pos,
                    causal=True, window=window,
                    softcap_val=cfg.attn_softcap, k_positions=kpos)
            else:
                # Decode (S == 1): plain attention keeps a
                # sequence-sharded cache distributed (see plain_attention).
                out = plain_attention(
                    q, new_cache["k"], new_cache["v"], q_offset=pos,
                    causal=True, window=window,
                    softcap_val=cfg.attn_softcap, kv_len=pos + S)
    y = (out.reshape(B, S, nh * hd) @ p["wo"].astype(x.dtype))
    if cfg.sandwich_norm:
        y = rms_norm(y, p["post_norm"])
    if cross and "gate_attn" in p:
        y = jnp.tanh(p["gate_attn"].astype(y.dtype)) * y
    return x + y, new_cache


def ffn_apply(p: dict, x: jax.Array, cfg, gate: jax.Array | None = None):
    """Returns (y, aux_loss)."""
    if cfg.n_experts:
        return moe_apply(p, x, cfg)
    y = mlp_apply(p, x, cfg)
    if gate is not None:                      # vlm cross-layer MLP gate
        y = x + jnp.tanh(gate.astype(x.dtype)) * (y - x)
    return y, jnp.float32(0.0)


# -------------------------------------------------------------- one layer
def apply_layer(p: dict, x: jax.Array, cfg, kind: str, *,
                cache=None, pos=0, ctx=None, fill_cross: bool = False):
    """Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    if kind == "ssm":
        x, new_cache = ssm_apply(p["ssm"], x, cfg, state=cache, pos=pos)
        return x, new_cache, aux
    if kind == "recurrent":
        x, new_state = rglru_apply(p["rglru"], x, cfg, state=cache, pos=pos)
        x, aux = ffn_apply(p["ffn"], x, cfg)
        return x, new_state, aux
    if kind == "encdec":
        sc = None if cache is None else cache.get("self")
        xc = None if cache is None else cache.get("crosskv")
        x, new_self = attn_apply(p["attn"], x, cfg, "global",
                                 cache=sc, pos=pos)
        x, new_cross = attn_apply(p["xattn"], x, cfg, "cross",
                                  cache=xc, ctx=ctx, fill_cross=fill_cross)
        x, aux = ffn_apply(p["ffn"], x, cfg)
        new_cache = (None if cache is None
                     else {"self": new_self, "crosskv": new_cross})
        return x, new_cache, aux
    if kind == "enc":
        x, _ = attn_apply(p["attn"], x, cfg, "global", causal=False)
        x, aux = ffn_apply(p["ffn"], x, cfg)
        return x, None, aux
    if kind == "cross":
        x, new_cache = attn_apply(p["attn"], x, cfg, "cross",
                                  cache=cache, ctx=ctx, fill_cross=fill_cross)
        gate = p["attn"].get("gate_mlp")
        x, aux = ffn_apply(p["ffn"], x, cfg, gate=gate)
        return x, new_cache, aux
    # global / local self-attention layer
    x, new_cache = attn_apply(p["attn"], x, cfg, kind, cache=cache, pos=pos)
    x, aux = ffn_apply(p["ffn"], x, cfg)
    return x, new_cache, aux


# ------------------------------------------------------------- caches
def layer_cache(cfg, kind: str, batch: int, max_len: int, dtype=jnp.bfloat16):
    nkv, hd = cfg.n_kv_heads, cfg.hd
    if kind == "ssm":
        return init_ssm_state(cfg, batch)
    if kind == "recurrent":
        return init_rglru_state(cfg, batch)
    if kind == "local":
        return init_kv_cache(batch, min(max_len, cfg.window), nkv, hd, dtype)
    if kind == "encdec":
        return {"self": init_kv_cache(batch, max_len, nkv, hd, dtype),
                "crosskv": {"ck": jnp.zeros((batch, cfg.enc_seq, nkv, hd),
                                            dtype),
                            "cv": jnp.zeros((batch, cfg.enc_seq, nkv, hd),
                                            dtype)}}
    if kind == "cross":
        ctx_len = cfg.vision_seq
        return {"ck": jnp.zeros((batch, ctx_len, nkv, hd), dtype),
                "cv": jnp.zeros((batch, ctx_len, nkv, hd), dtype)}
    return init_kv_cache(batch, max_len, nkv, hd, dtype)


# ------------------------------------------------------ stack construction
def stack_structure(cfg, decoder: bool = True) -> tuple[list[str], int, int]:
    """(pattern kinds, n_groups, n_tail) for the decoder or encoder stack."""
    if cfg.family == "audio" and decoder:
        pattern = ["encdec"]
        n_layers = cfg.n_layers
    elif cfg.family == "audio":
        pattern = ["enc"]
        n_layers = cfg.enc_layers
    elif cfg.family == "ssm":
        pattern = ["ssm"]
        n_layers = cfg.n_layers
    else:
        pattern = list(cfg.pattern)
        n_layers = cfg.n_layers
    n_groups = n_layers // len(pattern)
    n_tail = n_layers - n_groups * len(pattern)
    return pattern, n_groups, n_tail


def stack_defs(cfg, decoder: bool = True) -> dict:
    """ParamDef tree with group params stacked along a leading 'layers'
    axis (added here by re-declaring each leaf with +1 dim)."""
    pattern, n_groups, n_tail = stack_structure(cfg, decoder)

    def stackify(d: ParamDef) -> ParamDef:
        return D((n_groups,) + d.shape, ("layers",) + d.axes,
                 init=d.init, scale=d.scale, dtype=d.dtype)

    group = {f"p{i}": layer_defs(cfg, k) for i, k in enumerate(pattern)}
    stacked = jax.tree.map(stackify, group,
                           is_leaf=lambda x: isinstance(x, ParamDef))
    tail = {f"t{i}": layer_defs(cfg, pattern[i % len(pattern)])
            for i in range(n_tail)}
    out = {"groups": stacked}
    if tail:
        out["tail"] = tail
    return out


def stack_cache(cfg, batch: int, max_len: int, decoder: bool = True,
                dtype=jnp.bfloat16) -> dict:
    pattern, n_groups, n_tail = stack_structure(cfg, decoder)
    group = {f"p{i}": layer_cache(cfg, k, batch, max_len, dtype)
             for i, k in enumerate(pattern)}
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), group)
    out = {"groups": stacked}
    if n_tail:
        out["tail"] = {f"t{i}": layer_cache(cfg, pattern[i % len(pattern)],
                                            batch, max_len, dtype)
                       for i in range(n_tail)}
    return out


def apply_stack(params: dict, x: jax.Array, cfg, *, decoder: bool = True,
                cache: dict | None = None, pos=0, ctx=None,
                remat: str = "full", fill_cross: bool = False):
    """Run the whole layer stack.  Returns (x, new_cache, aux_sum)."""
    pattern, n_groups, n_tail = stack_structure(cfg, decoder)
    has_cache = cache is not None

    def group_step(carry, scanned):
        x, aux = carry
        gp = scanned[0] if has_cache else scanned
        gc = scanned[1] if has_cache else None
        new_gc = {}
        for i, kind in enumerate(pattern):
            lc = gc[f"p{i}"] if has_cache else None
            x, nc, a = apply_layer(gp[f"p{i}"], x, cfg, kind,
                                   cache=lc, pos=pos, ctx=ctx,
                                   fill_cross=fill_cross)
            if has_cache:
                new_gc[f"p{i}"] = nc
            aux = aux + a
        x = shard_hidden(x)
        return (x, aux), (new_gc if has_cache else 0)

    if remat == "full":
        group_step = jax.checkpoint(
            group_step, policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        group_step = jax.checkpoint(
            group_step,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    xs = (params["groups"], cache["groups"]) if has_cache \
        else params["groups"]
    (x, aux), new_groups = jax.lax.scan(group_step, (x, jnp.float32(0.0)), xs)

    new_cache = None
    if has_cache:
        new_cache = {"groups": new_groups}
    if n_tail:
        new_tail = {}
        for i in range(n_tail):
            kind = pattern[i % len(pattern)]
            lc = cache["tail"][f"t{i}"] if has_cache else None
            x, nc, a = apply_layer(params["tail"][f"t{i}"], x, cfg, kind,
                                   cache=lc, pos=pos, ctx=ctx,
                                   fill_cross=fill_cross)
            if has_cache:
                new_tail[f"t{i}"] = nc
            aux = aux + a
        if has_cache:
            new_cache["tail"] = new_tail
    return x, new_cache, aux
