"""Mixture-of-Experts layer: top-k router + capacity-based dispatch with
explicit expert-parallel all-to-all.

Two execution paths:

  * `_moe_shard_map` (production, TP plan): tokens stay on their data
    shard; each shard ranks its assignments locally (one stable argsort
    over T_loc*k), scatters into a local [E, C_loc, d] buffer, and a
    `lax.all_to_all` over the 'model' axis delivers expert slices to their
    owners -- compute runs on [E_loc, 16*C_loc, d], a second a2a returns
    outputs, combine is local.  The only cross-device traffic is the
    physical token<->expert payload (~ cf * tokens * k * d bytes).

  * `_moe_local` (single-device smoke tests / DP plans): same math without
    the mesh choreography.

History (EXPERIMENTS.md §Perf): a pjit-only version with a global argsort
and data-dependent scatter across the expert-sharded buffer made the SPMD
partitioner materialize full [B, E*C, d] gathers -- 205s (global-sort
variant) and 1126s (per-row variant) of collective time per qwen3 train
step vs ~5s of compute.  shard_map pins the schedule to the physical a2a.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import D, act_fn, rms_norm


def moe_defs(cfg) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "pre_norm": D((d,), ("embed",), init="zeros"),
        "router": D((d, e), ("embed", "experts")),
        "w_gate": D((e, d, ff), ("experts", "embed", "ff")),
        "w_up": D((e, d, ff), ("experts", "embed", "ff")),
        "w_down": D((e, ff, d), ("experts", "ff", "embed")),
    }


def _capacity(tokens: int, cfg) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def _rank_and_slot(flat_e: jax.Array, E: int, C: int):
    """flat_e [N] expert ids -> (keep [N], slot [N]) with rank-in-expert
    capacity dropping; one stable argsort, no [N, E] tensors."""
    N = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    ranks_sorted = jnp.arange(N) - starts[flat_e[order]]
    ranks = jnp.zeros((N,), jnp.int32).at[order].set(
        ranks_sorted.astype(jnp.int32))
    keep = ranks < C
    slot = flat_e * C + jnp.where(keep, ranks, 0)
    return keep, slot


def _expert_ffn(buf, p, cfg):
    a = act_fn(cfg.act)(jnp.einsum(
        "ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype),
        preferred_element_type=jnp.float32).astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", a * u,
                      p["w_down"].astype(buf.dtype))


def _route(p, h2, cfg):
    """h2 [T, d] -> (gate_vals [T,K], expert_idx [T,K], aux-loss pieces)."""
    logits = h2.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((cfg.n_experts,), jnp.float32).at[
        expert_idx.reshape(-1)].add(1.0 / expert_idx.size)
    return gate_vals, expert_idx, me, ce


def _dispatch_combine(p, h2, cfg, a2a_axis: str | None):
    """Core dispatch -> expert ffn -> combine on a [T, d] token block.
    With `a2a_axis`, experts are sharded over that mesh axis and the
    buffers ride lax.all_to_all."""
    T, d = h2.shape
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(T, cfg)
    gate_vals, expert_idx, me, ce = _route(p, h2, cfg)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    flat_e = expert_idx.reshape(-1)                       # [T*K]
    keep, slot = _rank_and_slot(flat_e, E, C)
    tok = jnp.repeat(jnp.arange(T), K)
    contrib = jnp.where(keep[:, None], h2[tok], 0)
    buf = jnp.zeros((E * C, d), h2.dtype).at[slot].add(contrib)
    buf = buf.reshape(E, C, d)

    if a2a_axis is not None:
        n = jax.lax.axis_size(a2a_axis)
        # [E, C, d] -> [E/n, n*C, d]: expert slices travel to their owner
        buf = jax.lax.all_to_all(buf, a2a_axis, split_axis=0,
                                 concat_axis=1, tiled=True)
        local_p = {k: v for k, v in p.items()}
        out = _expert_ffn(buf, local_p, cfg)
        out = jax.lax.all_to_all(out, a2a_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
    else:
        out = _expert_ffn(buf, p, cfg)

    out = out.reshape(E * C, d)
    # keep the combine in the activation dtype: a f32 gate weight here
    # promotes y -- and the whole backward collective chain -- to f32
    w = jnp.where(keep, gate_vals.reshape(-1), 0).astype(h2.dtype)
    gathered = out[slot] * w[:, None]
    y = jnp.zeros((T, d), h2.dtype).at[tok].add(gathered)
    return y, aux


def _moe_local(p, x, cfg):
    B, S, d = x.shape
    h = rms_norm(x, p["pre_norm"])
    y, aux = _dispatch_combine(p, h.reshape(B * S, d), cfg, None)
    return x + y.reshape(B, S, d).astype(x.dtype), aux


def _moe_shard_map(p, x, cfg, mesh, batch_axes, model_axis):
    B, S, d = x.shape
    n_model = mesh.shape[model_axis]

    def local_fn(xl, pre_norm, router, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        lp = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        h = rms_norm(xl, pre_norm).reshape(T, d)
        if T % n_model == 0 and T >= n_model * 8:
            # Sequence-parallel dispatch: activations are replicated
            # across the model axis under the TP plan, so each model rank
            # routes only its 1/n token slice (cuts a2a payload n-fold),
            # then the combined outputs are all-gathered back.
            chunk = T // n_model
            mi = jax.lax.axis_index(model_axis)
            h2 = jax.lax.dynamic_slice_in_dim(h, mi * chunk, chunk)
            y_chunk, aux = _dispatch_combine(lp, h2, cfg, model_axis)
            y = jax.lax.all_gather(y_chunk, model_axis, axis=0,
                                   tiled=True)
            aux = jax.lax.pmean(aux, batch_axes + (model_axis,))
        else:
            y, aux = _dispatch_combine(lp, h, cfg, model_axis)
            aux = jax.lax.pmean(aux, batch_axes)
        return (xl + y.reshape(Bl, Sl, d).astype(xl.dtype)), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
              None, None)
    fn = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(bspec, P(None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(bspec, P()),
        check_vma=False)
    return fn(x, p["pre_norm"], p["router"], p["w_gate"], p["w_up"],
              p["w_down"])


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x [B,S,d] -> (y with residual, aux_loss)."""
    from repro.models.transformer import _CTX
    mesh = _CTX.get("mesh")
    batch_axes = _CTX.get("batch_axes")
    model_axis = _CTX.get("model_axis")
    use_sm = (mesh is not None and batch_axes and model_axis
              and model_axis not in batch_axes
              and cfg.n_experts % mesh.shape[model_axis] == 0)
    if use_sm:
        return _moe_shard_map(p, x, cfg, mesh, tuple(batch_axes),
                              model_axis)
    return _moe_local(p, x, cfg)
