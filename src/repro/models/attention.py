"""Blocked (flash-style) attention in pure JAX.

Online-softmax over KV chunks via lax.scan: the full score matrix is never
materialized, so 32k-token prefill lowers with bounded memory on every mesh.
Supports GQA, causal masking, sliding-window (local) masking, gemma-2 logit
soft-capping and offset query positions (decode / chunked prefill).

kernels/flash_attention.py is the Pallas TPU twin of this function and is
validated against it (tests/test_kernels_flash.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_size(t: int) -> int:
    for c in (512, 256, 128, 64, 32, 16, 8):
        if t % c == 0:
            return c
    return t


@partial(jax.jit, static_argnames=("causal", "window", "softcap_val"))
def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      *, q_offset: jax.Array | int = 0,
                      causal: bool = True, window: int = 0,
                      softcap_val: float = 0.0,
                      kv_len: jax.Array | None = None,
                      k_positions: jax.Array | None = None) -> jax.Array:
    """q [B,S,NH,hd]; k,v [B,T,NKV,hd] -> [B,S,NH,hd].

    q_offset: absolute position of q[0] (queries are positions
    q_offset..q_offset+S-1).
    k_positions: absolute position per key slot [T] (ring caches); default
    arange(T).  Invalid slots carry a huge positive position so the causal
    mask drops them.
    window > 0: only keys with 0 <= q_pos - k_pos < window attend.
    kv_len: number of valid cache entries (linear caches).
    """
    B, S, NH, hd = q.shape
    _, T, NKV, _ = k.shape
    G = NH // NKV
    qr = q.reshape(B, S, NKV, G, hd).transpose(0, 2, 3, 1, 4)  # B,NKV,G,S,hd
    kr = k.transpose(0, 2, 1, 3)                                # B,NKV,T,hd
    vr = v.transpose(0, 2, 1, 3)
    scale = hd ** -0.5
    C = _chunk_size(T)
    n_chunks = T // C

    q_pos = q_offset + jnp.arange(S)                            # [S]
    kp_all = (jnp.arange(T) if k_positions is None
              else k_positions)

    def step(carry, chunk_idx):
        m, l, acc = carry
        start = chunk_idx * C
        kc = jax.lax.dynamic_slice_in_dim(kr, start, C, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(vr, start, C, axis=2)
        s = jnp.einsum("bngsh,bnth->bngst", qr, kc,
                       preferred_element_type=jnp.float32) * scale
        if softcap_val:
            s = softcap_val * jnp.tanh(s / softcap_val)
        k_pos = jax.lax.dynamic_slice_in_dim(kp_all, start, C, axis=0)
        delta = q_pos[:, None] - k_pos[None, :]                 # [S,C]
        mask = jnp.ones_like(delta, dtype=bool)
        if causal:
            mask &= delta >= 0
        if window:
            mask &= delta < window
        if kv_len is not None:
            mask &= ((start + jnp.arange(C)) < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bngst,bnth->bngsh", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, NKV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, NKV, G, S), jnp.float32)
    a0 = jnp.zeros((B, NKV, G, S, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, NH, hd).astype(q.dtype)


@partial(jax.jit, static_argnames=("causal", "window", "softcap_val"))
def plain_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, q_offset: jax.Array | int = 0,
                    causal: bool = True, window: int = 0,
                    softcap_val: float = 0.0,
                    kv_len: jax.Array | None = None) -> jax.Array:
    """Reference attention materializing the score matrix.

    Used on (a) the TRAINING path -- under layer-granular remat its
    [B,H,S,S] scores live only inside one layer's recompute, whereas
    differentiating the blocked scan would save O(S^2) carries per chunk
    (flash-style custom VJP is the perf-iteration upgrade) -- and (b) the
    S==1 DECODE path against sequence-sharded caches: the score einsum
    contracts the sharded T dim, so XLA keeps the KV cache distributed and
    reduces [B,H,1] partials instead of gathering the cache (the blocked
    scan's dynamic slices would re-gather it chunk by chunk)."""
    B, S, NH, hd = q.shape
    _, T, NKV, _ = k.shape
    G = NH // NKV
    qr = q.reshape(B, S, NKV, G, hd)
    s = jnp.einsum("bsngh,btnh->bngst", qr, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    q_pos = q_offset + jnp.arange(S)
    k_pos = jnp.arange(T)
    delta = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones_like(delta, dtype=bool)
    if causal:
        mask &= delta >= 0
    if window:
        mask &= delta < window
    if kv_len is not None:
        mask &= (k_pos < kv_len)[None, :]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngst,btnh->bsngh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, NH, hd).astype(q.dtype)


def cp_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 mesh, batch_axes, model_axis: str,
                 causal: bool = True, window: int = 0,
                 softcap_val: float = 0.0) -> jax.Array:
    """Context-parallel full-sequence attention (prefill path).

    Under the DP sharding plan (head count indivisible by the model axis)
    q/k/v are replicated across the model axis.  Each model rank computes
    the blocked attention for its 1/n query slice (k/v already local --
    zero gather), and the outputs are all-gathered back: the model axis
    contributes compute instead of sitting storage-only.
    EXPERIMENTS.md §Perf iteration 2e."""
    from jax.sharding import PartitionSpec as P
    B, S, NH, hd = q.shape
    n = mesh.shape[model_axis]
    chunk = S // n

    def local(ql, kl, vl):
        i = jax.lax.axis_index(model_axis)
        qs = jax.lax.dynamic_slice_in_dim(ql, i * chunk, chunk, 1)
        out = blocked_attention(qs, kl, vl, q_offset=i * chunk,
                                causal=causal, window=window,
                                softcap_val=softcap_val)
        return jax.lax.all_gather(out, model_axis, axis=1, tiled=True)

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
              None, None, None)
    fn = jax.shard_map(local, mesh=mesh, in_specs=(bspec, bspec, bspec),
                       out_specs=bspec, check_vma=False)
    return fn(q, k, v)


# ----------------------------------------------------------------- KV cache
def init_kv_cache(batch: int, max_len: int, n_kv: int, hd: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
    }


def cache_update(cache: dict, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, ring: bool = False) -> dict:
    """Insert S_new entries at position ``pos`` (ring buffer when the cache
    holds only a sliding window).  If more new entries arrive than the ring
    holds, only the trailing window is written (earlier ones would be
    overwritten anyway)."""
    max_len = cache["k"].shape[1]
    s_new = k_new.shape[1]
    if s_new > max_len:                      # static shapes
        k_new = k_new[:, -max_len:]
        v_new = v_new[:, -max_len:]
        pos = pos + (s_new - max_len)
        s_new = max_len
    if ring:
        idx = (pos + jnp.arange(s_new)) % max_len
    else:
        idx = pos + jnp.arange(s_new)
    k = cache["k"].at[:, idx].set(k_new.astype(cache["k"].dtype))
    v = cache["v"].at[:, idx].set(v_new.astype(cache["v"].dtype))
    return {"k": k, "v": v}


def ring_positions(pos: jax.Array, max_len: int) -> jax.Array:
    """Absolute position held by each slot of a ring cache of size
    ``max_len`` after ``pos`` tokens (positions 0..pos-1) were written:
    slot s holds p = (pos-1) - ((pos-1-s) mod max_len); p < 0 means the
    slot is empty and is pushed to +inf so the causal mask drops it."""
    slot = jnp.arange(max_len)
    p = (pos - 1) - ((pos - 1 - slot) % max_len)
    return jnp.where(p >= 0, p, 10**9)
