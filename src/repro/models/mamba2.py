"""Mamba-2 (SSD, state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation inside chunks of ``ssm_chunk`` tokens, linear state passing
between chunks via lax.scan (HLO stays small, memory bounded -- this is what
makes the long_500k cell lowerable).  Decode is the O(1) recurrent update.

kernels/ssd_scan.py is the Pallas twin of the chunked scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import D, rms_norm


def ssm_defs(cfg) -> dict:
    """Input projections are split per component (z / x / BC / dt) so each
    output dimension shards cleanly on the 'ff'->model axis -- a fused
    in_proj would put split boundaries mid-shard and force resharding."""
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    return {
        "pre_norm": D((d,), ("embed",), init="zeros"),
        "in_z": D((d, di), ("embed", "ff")),
        "in_x": D((d, di), ("embed", "ff")),
        "in_bc": D((d, 2 * g * n), ("embed", "ff")),
        "in_dt": D((d, nh), ("embed", "ff")),
        "conv_x_w": D((cfg.conv_width, di), (None, "ff")),
        "conv_x_b": D((di,), ("ff",), init="zeros"),
        "conv_bc_w": D((cfg.conv_width, 2 * g * n), (None, "ff")),
        "conv_bc_b": D((2 * g * n,), ("ff",), init="zeros"),
        "A_log": D((nh,), (None,), init="zeros"),
        "D": D((nh,), (None,), init="ones"),
        "dt_bias": D((nh,), (None,), init="zeros"),
        "gate_norm": D((di,), ("ff",), init="zeros"),
        "out_proj": D((di, d), ("ff", "embed")),
    }


def _dt_activation(dt, dt_bias):
    return jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: jax.Array | None = None):
    """x [B,S,Cd]; w [K,Cd] depthwise causal conv; state [B,K-1,Cd] carries
    the last K-1 inputs for decode.  Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # [B,S+K-1,Cd]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(y), new_state


def ssd_chunked(x, dt, A, Bm, Cm, D_, chunk: int, h0=None):
    """SSD forward.
    x [b,s,h,p]; dt [b,s,h] (post-softplus fp32); A [h] (negative);
    Bm, Cm [b,s,g,n]; D_ [h]; h0 optional initial state [b,h,p,n].
    Returns y [b,s,h,p] and final state [b,h,p,n]."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    pad = (-s) % chunk
    if pad:
        # dt = 0 on padding: no state change, no output contribution.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nc = s_pad // chunk
    hg = h // g                              # heads per B/C group

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, g, n)
    Cc = Cm.reshape(b, nc, chunk, g, n)

    dA = dtc * A                              # [b,nc,l,h], negative
    dA_cum = jnp.cumsum(dA, axis=2)

    def per_chunk(args):
        xk, dtk, Bk, Ck, dAk, dAck = args
        # L[i,j] = exp(sum_{j<m<=i} dA)  for i >= j
        seg = dAck[:, :, None, :] - dAck[:, None, :, :]       # [b,l,l,h]
        ii = jnp.arange(chunk)
        causal = ii[:, None] >= ii[None, :]
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        xdt = xk * dtk[..., None]                             # [b,l,h,p]
        # intra-chunk (quadratic within chunk)
        scores = jnp.einsum("blgn,bmgn->blmg", Ck, Bk,
                            preferred_element_type=jnp.float32)
        scores = jnp.repeat(scores, hg, axis=-1)              # [b,l,m,h]
        y_diag = jnp.einsum("blmh,blmh,bmhp->blhp", scores, L,
                            xdt.astype(jnp.float32))
        # state contribution of this chunk: sum_m exp(dAc_l - dAc_m) B_m xdt_m
        decay = jnp.exp(dAck[:, -1:, :] - dAck)               # [b,l,h]
        Bh = jnp.repeat(Bk, hg, axis=2)                       # [b,l,h,n]
        state = jnp.einsum("blhn,blh,blhp->bhpn",
                           Bh.astype(jnp.float32), decay,
                           xdt.astype(jnp.float32))
        chunk_decay = jnp.exp(dAck[:, -1, :])                 # [b,h]
        return y_diag, state, chunk_decay

    def scan_step(h_prev, inputs):
        xk, dtk, Bk, Ck, dAk, dAck = inputs
        y_diag, state_inc, chunk_decay = per_chunk(inputs)
        # inter-chunk: y_off[l] = C_l . (exp(dAc_l) * h_prev)
        Ch = jnp.repeat(Ck, hg, axis=2)                       # [b,l,h,n]
        in_decay = jnp.exp(dAck)                              # [b,l,h]
        y_off = jnp.einsum("blhn,bhpn->blhp", Ch.astype(jnp.float32),
                           h_prev) * in_decay[..., None]
        h_new = h_prev * chunk_decay[:, :, None, None] + state_inc
        return h_new, y_diag + y_off

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in
                   (xc, dtc, Bc, Cc, dA, dA_cum))
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_final, yc = jax.lax.scan(scan_step, h0.astype(jnp.float32), inputs)
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s_pad, h, p)
    y = y + x.astype(jnp.float32) * D_[None, None, :, None]
    if pad:
        y = y[:, :s]
    return y.astype(x.dtype), h_final


def ssm_apply(p: dict, x: jax.Array, cfg,
              state: dict | None = None, pos=None):
    """Full Mamba-2 block with residual.  state (decode):
      {"conv": [B,K-1,conv_dim], "ssd": [B,h,p,n]}.
    Returns (y, new_state)."""
    cfgd = jnp.dtype(cfg.dtype)
    B_, S, d = x.shape
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    nh, hp = cfg.ssm_nheads, cfg.ssm_headdim

    h = rms_norm(x, p["pre_norm"])
    z = h @ p["in_z"].astype(h.dtype)
    xin = h @ p["in_x"].astype(h.dtype)
    bc = h @ p["in_bc"].astype(h.dtype)
    dt = h @ p["in_dt"].astype(h.dtype)
    cx = None if state is None else state["convx"]
    cbc = None if state is None else state["convbc"]
    xin, new_convx = causal_conv(xin, p["conv_x_w"].astype(cfgd),
                                 p["conv_x_b"].astype(cfgd), cx)
    bc, new_convbc = causal_conv(bc, p["conv_bc_w"].astype(cfgd),
                                 p["conv_bc_b"].astype(cfgd), cbc)
    Bm, Cm = jnp.split(bc, [g * n], axis=-1)
    xh = xin.reshape(B_, S, nh, hp)
    Bm = Bm.reshape(B_, S, g, n)
    Cm = Cm.reshape(B_, S, g, n)
    dtv = _dt_activation(dt, p["dt_bias"])                    # [B,S,nh] fp32
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # [nh]

    if state is None or S > 1:
        # train, or prefill-with-state (chunked path, carries h0)
        h0 = None if state is None else state["ssd"]
        y, ssd_state = ssd_chunked(xh, dtv, A, Bm, Cm,
                                   p["D"].astype(jnp.float32),
                                   cfg.ssm_chunk, h0=h0)
    else:
        # recurrent decode: S == 1
        hg = nh // g
        dA = jnp.exp(dtv[:, 0, :] * A)                        # [B,nh]
        Bh = jnp.repeat(Bm[:, 0], hg, axis=1)                 # [B,nh,n]
        xdt = (xh[:, 0] * dtv[:, 0, :, None]).astype(jnp.float32)
        new_h = (state["ssd"] * dA[:, :, None, None]
                 + jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32), xdt))
        Ch = jnp.repeat(Cm[:, 0], hg, axis=1)                 # [B,nh,n]
        y = jnp.einsum("bhpn,bhn->bhp", new_h, Ch.astype(jnp.float32))
        y = y + xh[:, 0].astype(jnp.float32) * p["D"][None, :, None]
        y = y[:, None].astype(x.dtype)
        ssd_state = new_h

    y = y.reshape(B_, S, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = y @ p["out_proj"].astype(y.dtype)
    new_state = {"convx": new_convx, "convbc": new_convbc, "ssd": ssd_state}
    return x + out, new_state


def init_ssm_state(cfg, batch: int) -> dict:
    di, g, n = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state
    dt = jnp.dtype(cfg.dtype)
    return {
        "convx": jnp.zeros((batch, cfg.conv_width - 1, di), dt),
        "convbc": jnp.zeros((batch, cfg.conv_width - 1, 2 * g * n), dt),
        "ssd": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_headdim, n),
                         jnp.float32),
    }
