"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

recurrence:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
             a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
gates r, i come from block-diagonal projections of the conv'd input.

Prefill/train uses jax.lax.associative_scan (log-depth); decode is the O(1)
update.  The recurrent state is the paper's SE-side-path analogue: pinned
on-chip in resident mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import D, act_fn, rms_norm

C_FACTOR = 8.0
N_DIAG_BLOCKS = 8


def rglru_defs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    bw = w // N_DIAG_BLOCKS
    return {
        "pre_norm": D((d,), ("embed",), init="zeros"),
        "w_x": D((d, w), ("embed", "ff")),          # input branch
        "w_y": D((d, w), ("embed", "ff")),          # gate branch
        "conv_w": D((cfg.conv_width, w), (None, "ff")),
        "conv_b": D((w,), ("ff",), init="zeros"),
        # block-diagonal RG-LRU gate projections
        "gate_a": D((N_DIAG_BLOCKS, bw, bw), (None, "ff", None)),
        "gate_x": D((N_DIAG_BLOCKS, bw, bw), (None, "ff", None)),
        "lam": D((w,), ("ff",), init="ones"),
        "w_out": D((w, d), ("ff", "embed")),
    }


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [..., nb*bw] @ blockdiag(w [nb,bw,bw]) -> [..., nb*bw]."""
    nb, bw, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, bw)
    y = jnp.einsum("...nb,nbc->...nc", xs, w.astype(x.dtype))
    return y.reshape(*x.shape[:-1], nb * bw)


def _gates(p, xc):
    r = jax.nn.sigmoid(_block_diag(xc, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xc, p["gate_x"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * i * xc.astype(jnp.float32)


def rglru_scan(a: jax.Array, b: jax.Array,
               h0: jax.Array | None = None, chunk: int = 256) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t over axis 1; a,b [B,S,W] fp32.

    Chunked: an outer lax.scan carries h across chunks (so the backward
    pass saves only [B,W] per chunk and rematerializes the rest) while a
    log-depth associative scan runs inside each chunk."""
    B, S, W = a.shape
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    if S <= chunk or S % chunk:
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h

    nc = S // chunk
    ac = a.reshape(B, nc, chunk, W).swapaxes(0, 1)
    bc = b.reshape(B, nc, chunk, W).swapaxes(0, 1)

    @jax.checkpoint
    def step(h, ab):
        ak, bk = ab
        bk = bk.at[:, 0].add(ak[:, 0] * h)
        _, hh = jax.lax.associative_scan(combine, (ak, bk), axis=1)
        return hh[:, -1], hh

    _, hs = jax.lax.scan(step, jnp.zeros((B, W), a.dtype), (ac, bc))
    return hs.swapaxes(0, 1).reshape(B, S, W)


def rglru_apply(p: dict, x: jax.Array, cfg,
                state: dict | None = None, pos=None):
    """Griffin recurrent block with residual.  state (decode):
      {"conv": [B,K-1,W], "h": [B,W] fp32}."""
    from repro.models.mamba2 import causal_conv
    B_, S, d = x.shape
    hidden = rms_norm(x, p["pre_norm"])
    gate = act_fn(cfg.act)(hidden @ p["w_y"].astype(hidden.dtype))
    xb = hidden @ p["w_x"].astype(hidden.dtype)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = causal_conv(xb, p["conv_w"].astype(x.dtype),
                               p["conv_b"].astype(x.dtype), conv_state)
    a, b = _gates(p, xc)
    if state is None or S > 1:
        h0 = None if state is None else state["h"]
        h = rglru_scan(a, b, h0=h0)
        h_last = h[:, -1]
    else:
        h = (a[:, 0] * state["h"] + b[:, 0])[:, None]
        h_last = h[:, 0]
    y = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    return x + y, {"conv": new_conv, "h": h_last}


def init_rglru_state(cfg, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
