"""jax version compatibility for the Pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams`` in newer
releases; export whichever this installation provides so every kernel module
imports the alias from one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
