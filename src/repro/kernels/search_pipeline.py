"""Fused on-device sub-space search: enumerate -> replay -> score -> argmin.

The journal/device engines drive the exhaustive cut search from the host:
``branch_bound_subspace`` materializes every candidate tuple in Python,
batches them through ``score_batch``, and keeps the running winner on the
host.  This module fuses that whole loop into one device pipeline behind
``CompileOptions(engine="pipeline")``:

1. **In-kernel enumeration** -- a sub-space is ``prefix`` (fixed cuts for
   the leading runs) x the product order over ``suffix_dims``.  Product
   order over runs *is* lexicographic order of the cut tuples, so every
   candidate has a global linear index ``j in [0, S)`` with the last run
   varying fastest (``stride[q] = prod(dims[q+1:])``).  Kernels decode
   ``j`` straight into the B x G frame-mask matrix (the same three
   gathers as ``CutpointEngine._frame_matrix``); the host never
   materializes the candidate tuple stream.
2. **Allocator replay** -- the decoded masks feed the tensorized
   allocator scan (``kernels/alloc_scan.py``), integer-exact under every
   backend.
3. **Cost reduction** -- the B x G mask-matrix reductions of
   ``timing/dram/sram.*_fast_batch``, evaluated in float64.  Every
   integer quantity is far below 2**53, so the int -> f64 embedding is
   exact and ``<=`` comparisons match the host's integer comparisons
   bit-for-bit.  The latency total is the one order-sensitive float
   reduction: the host uses ``np.cumsum`` (strictly sequential
   left-to-right), so the device path accumulates with a sequential
   ``lax.fori_loop`` over groups -- never ``jnp.sum``, whose pairwise
   re-association would break oracle exactness.
4. **Hierarchical argmin** -- the objective key is the host's
   ``_key``: ``(infeasible, primary, secondary)``, tie-broken by the cut
   tuple, i.e. by the linear index ``j``.  ``argmin_lanes`` reduces it as
   nested masked minima (min infeasibility -> min primary among those ->
   min secondary among those -> min index among those), which equals the
   lexicographic first-minimum exactly; only the winning
   ``(key, index)`` 4-tuple leaves the device per chunk.

Chunk winners are folded on the host by plain tuple comparison and the
final index is decoded back into cuts (mixed radix, last run fastest);
the winner is then re-priced through the engine's exact journal oracle,
so the returned ``CandidateMetrics`` is byte-identical to the journal
path's and the kernels only ever decide *which* candidate wins.
``evaluations`` is credited with the full enumeration count ``S``, which
equals the journal path's ``scored + pruned`` -- the two engines report
identical ``evaluated`` under the default ``count_pruned=True``.

Variants (``engine="pipeline[:variant]"``):

* ``reference`` -- numpy end-to-end (enumeration + ``alloc_scan_ref`` +
  the very ``*_fast_batch`` reductions of the journal scorer).  The
  oracle the other two are tested against.
* ``lax`` -- one jitted fused function per sub-space shape: decode,
  frame masks, ``_scan_impl`` allocator scan, f64 reductions and the
  hierarchical argmin all in a single XLA computation returning four
  scalars.  With more than one visible device the chunk range is
  sharded with ``shard_map`` over contiguous index ranges -- the same
  disjoint partitioning ``search_pool.partition_space`` uses, expressed
  on the linear index -- and the per-device winners are folded with the
  same deterministic tuple comparison, so the merged result is
  bit-identical at any device count.
* ``pallas`` -- the staged TPU composition: an enumeration kernel
  (int32) decodes indices to masks, ``alloc_scan_pallas`` replays them,
  and a cost/argmin kernel reduces each block to one winner row.  The
  cost kernel works in float64 for exactness and therefore always runs
  in interpret mode off-TPU (the CI configuration); the integer
  enumeration and allocator stages compile natively on TPU.

All three variants return the bit-identical winner
(tests/test_search_pipeline.py fuzzes them against the host merge on
batches with duplicated keys).
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.dram import dram_fm_fast_batch
from repro.core.options import DEFAULT_BATCH_SIZE
from repro.core.sram import sram_total_fast_batch
from repro.core.timing import latency_cycles_fast_batch
from repro.kernels.score_batch import (HAVE_JAX, LANES, SUBLANES, _on_tpu,
                                       _pad_up)

if HAVE_JAX:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

VARIANTS = ("reference", "lax", "pallas")
OBJECTIVES = ("latency", "sram", "dram")

# Rank sentinels for padded / out-of-range lanes: a real candidate's
# infeasibility rank is 0.0 or 1.0, so rank 2.0 never wins; the index
# sentinel exceeds any real linear index (spaces are capped at
# EXHAUSTIVE_LIMIT = 8M << 2**62).
_PAD_RANK = 2.0
_HUGE_IDX = float(2 ** 62)


# --------------------------------------------------------------- index math
def _space_strides(dims: tuple[int, ...]) -> tuple[int, ...]:
    """Mixed-radix strides of the product order (last run fastest)."""
    strides = [1] * len(dims)
    for q in range(len(dims) - 2, -1, -1):
        strides[q] = strides[q + 1] * dims[q + 1]
    return tuple(strides)


def _decode_index(idx: int, strides: tuple[int, ...],
                  dims: tuple[int, ...]) -> tuple[int, ...]:
    """Linear index -> suffix cut tuple (inverse of the in-kernel decode)."""
    return tuple((idx // s) % d for s, d in zip(strides, dims))


def _keys_np(objective: str, lat: np.ndarray, dram_total: np.ndarray,
             sram_total: np.ndarray, feasible: np.ndarray):
    """Host objective key columns, mirroring ``cutpoint._key`` exactly:
    ``(not feasible, primary, secondary)`` in float64 (exact embedding:
    every integer magnitude here is far below 2**53)."""
    infeas = (~np.asarray(feasible, dtype=bool)).astype(np.float64)
    lat = np.asarray(lat, dtype=np.float64)
    sram = np.asarray(sram_total, dtype=np.float64)
    if objective == "latency":
        return infeas, lat, sram
    if objective == "sram":
        return infeas, sram, lat
    if objective == "dram":
        return infeas, np.asarray(dram_total, dtype=np.float64), lat
    raise ValueError(f"unknown objective: {objective!r}")


# --------------------------------------------------------- hierarchical argmin
def _argmin_hier(infeas, primary, secondary, idxf, xp):
    """Nested masked minima == lexicographic first-minimum.

    Each level keeps only the lanes that achieved the previous minima,
    then minimizes the next key component over them; the final level
    minimizes the (unique) lane index, so ties on the full key resolve
    to the *first* lane -- exactly the host merge's
    ``(objective key, cut tuple)`` order, since index order is cut-tuple
    order.  Pure elementwise/min ops, so the same code body runs under
    numpy, traced lax, and inside a Pallas kernel."""
    i_min = xp.min(infeas)
    m0 = infeas == i_min
    p = xp.where(m0, primary, xp.inf)
    p_min = xp.min(p)
    m1 = m0 & (p == p_min)
    s = xp.where(m1, secondary, xp.inf)
    s_min = xp.min(s)
    m2 = m1 & (s == s_min)
    i_win = xp.min(xp.where(m2, idxf, _HUGE_IDX))
    return i_min, p_min, s_min, i_win


def argmin_lanes(infeas, primary, secondary, idx,
                 backend: str = "reference") -> tuple:
    """Winner of a batch of objective keys: ``(infeas, primary,
    secondary, idx)`` of the first lane attaining the lexicographic
    minimum key.

    ``backend="reference"`` is the host oracle (a stable ``np.lexsort``,
    so the first minimum wins); ``"lax"`` / ``"pallas"`` run the
    hierarchical masked-minima reduction the fused pipeline uses
    in-kernel.  All three return bit-identical winners -- the fuzzed
    contract of tests/test_search_pipeline.py."""
    infeas = np.asarray(infeas, dtype=np.float64)
    primary = np.asarray(primary, dtype=np.float64)
    secondary = np.asarray(secondary, dtype=np.float64)
    idx = np.asarray(idx, dtype=np.float64)
    if not (infeas.shape == primary.shape == secondary.shape == idx.shape
            and infeas.ndim == 1 and infeas.size):
        raise ValueError("argmin_lanes wants four equal-length 1-D lanes")
    if backend == "reference":
        order = np.lexsort((idx, secondary, primary, infeas))
        j = int(order[0])
        return (float(infeas[j]), float(primary[j]),
                float(secondary[j]), int(idx[j]))
    if backend not in ("lax", "pallas"):
        raise ValueError(f"unknown argmin_lanes backend: {backend!r}")
    if not HAVE_JAX:
        raise RuntimeError(f"argmin_lanes backend {backend!r} requires jax")
    with jax.experimental.enable_x64():
        if backend == "lax":
            w = _argmin_hier(jnp.asarray(infeas), jnp.asarray(primary),
                             jnp.asarray(secondary), jnp.asarray(idx), jnp)
            return (float(w[0]), float(w[1]), float(w[2]), int(w[3]))
        lp = _pad_up(max(infeas.size, 1), LANES)
        x = np.zeros((SUBLANES, lp), dtype=np.float64)
        x[0, :lp] = _PAD_RANK
        x[1, :lp] = np.inf
        x[2, :lp] = np.inf
        x[3, :lp] = _HUGE_IDX
        x[0, :infeas.size] = infeas
        x[1, :infeas.size] = primary
        x[2, :infeas.size] = secondary
        x[3, :infeas.size] = idx
        row = np.asarray(_build_argmin_call(lp)(x))[0]
        return (float(row[0]), float(row[1]), float(row[2]), int(row[3]))


def _fold(best, w):
    """Deterministic host fold of chunk winners: plain tuple comparison
    on ``(infeas, primary, secondary, idx)``.  Chunk index ranges are
    disjoint, so ties through the idx component are impossible and the
    fold order cannot matter."""
    w = (float(w[0]), float(w[1]), float(w[2]), float(w[3]))
    return w if best is None or w < best else best


# ------------------------------------------------------------- shared tables
def _engine_tables(engine) -> dict:
    """Per-engine prepared arrays for the fused variants (built once and
    stashed on the engine, like its ``_at`` alloc tables)."""
    tbl = engine.__dict__.get("_pipeline_tables")
    if tbl is not None:
        return tbl
    at = engine._at
    lt, dt, st = engine._lt, engine._dt, engine._st
    hw = engine.hw
    n = at.n
    i32 = np.int32
    alloc32 = (at.is_side, at.gin.astype(i32), at.src_size.astype(i32),
               at.main.astype(i32), at.sc.astype(i32),
               at.sc_size.astype(i32), at.in_size.astype(i32),
               at.out_size.astype(i32), at.wr_cand.astype(i32),
               at.spill_ok,
               np.minimum(at.rem0, np.int64(2 ** 31 - 1)).astype(i32),
               at.loc0.astype(i32))
    lanes = _pad_up(max(n, 1), LANES)
    # (1, lanes) broadcast rows for the Pallas enumeration kernel;
    # padded lanes get run -1 so their frame bit is always 0.
    runof_row = np.full((1, lanes), -1, dtype=i32)
    runof_row[0, :n] = engine._run_of
    pos_row = np.zeros((1, lanes), dtype=i32)
    pos_row[0, :n] = engine._pos_of
    dirneg_row = np.zeros((1, lanes), dtype=i32)
    dirneg_row[0, :n] = engine._dir_neg
    # static cost-table rows for the Pallas cost kernel, f64 (exact int
    # embedding); padded lanes are all-zero -> they contribute a 0.0
    # row-latency term and are masked out of every max by scomp == 0.
    tab = np.zeros((2 * SUBLANES, lanes), dtype=np.float64)
    tab[0, :n] = lt.comp
    tab[1, :n] = lt.row
    tab[2, :n] = lt.weight
    tab[3, :n] = lt.side
    tab[4, :n] = dt.row_fm
    tab[5, :n] = st.compute
    tab[6, :n] = st.weight
    tab[7, :n] = st.out_frame
    tab[8, :n] = st.out_row
    tab[9, :n] = st.wr_row
    tbl = {
        "n": n, "lanes": lanes, "alloc32": alloc32,
        "run_of": engine._run_of.astype(i32),
        "pos_of": engine._pos_of.astype(i32),
        "dir_neg": engine._dir_neg,
        "runof_row": runof_row, "pos_row": pos_row,
        "dirneg_row": dirneg_row, "tab": tab,
        "lt_comp": lt.comp, "lt_row": lt.row, "lt_weight": lt.weight,
        "lt_side": lt.side, "dt_rowfm": dt.row_fm.astype(np.float64),
        "st_comp": st.compute, "st_weight": st.weight.astype(np.float64),
        "st_outf": st.out_frame.astype(np.float64),
        "st_outr": st.out_row.astype(np.float64),
        "st_wrr": st.wr_row.astype(np.float64),
        "bpc": float(hw.dram_bytes_per_cycle),
        "goc": float(hw.group_overhead_cycles),
        "budget": int(hw.sram_budget),
        "weight_bytes": int(dt.weight_bytes),
        "row_buff": int(st.row_buff),
    }
    engine._pipeline_tables = tbl
    return tbl


# ---------------------------------------------------------- reference variant
def _run_reference(engine, tbl, prefix, dims, strides, S, chunk,
                   objective):
    """Numpy pipeline: the enumeration/decoding is vectorized, the
    allocator replay is ``alloc_scan_ref`` and the reductions are the
    *very same* ``*_fast_batch`` calls the journal scorer uses, so each
    chunk's keys are bit-identical to the host scorer by construction."""
    from repro.kernels.alloc_scan import alloc_scan_ref
    npfx = len(prefix)
    strides_np = np.asarray(strides, dtype=np.int64)
    dims_np = np.asarray(dims, dtype=np.int64)
    budget = tbl["budget"]
    wb = tbl["weight_bytes"]
    best = None
    for lo in range(0, S, chunk):
        j = np.arange(lo, min(lo + chunk, S), dtype=np.int64)
        suf = (j[:, None] // strides_np[None, :]) % dims_np[None, :]
        if npfx:
            pre = np.broadcast_to(np.asarray(prefix, dtype=np.int64),
                                  (len(j), npfx))
            cuts_arr = np.concatenate([pre, suf], axis=1)
        else:
            cuts_arr = suf
        cut = cuts_arr[:, tbl["run_of"]]
        pos = engine._pos_of[None, :]
        frame = np.where(tbl["dir_neg"][None, :], pos >= cut, pos < cut)
        res = alloc_scan_ref(engine._at, frame)
        io = res.io.astype(np.float64)
        lat = latency_cycles_fast_batch(engine._lt, frame, io, engine.hw)
        fm = dram_fm_fast_batch(engine._dt, frame, res.bfm.tolist())
        cand_terms = [(b[0], b[1], b[2], s, w)
                      for b, s, w in zip(res.buff.tolist(),
                                         res.side_buff.tolist(),
                                         res.wrf.tolist())]
        sram, _ = sram_total_fast_batch(engine._st, frame, cand_terms,
                                        engine.hw,
                                        bram_memo=engine._bram_memo)
        sram = np.asarray(sram, dtype=np.int64)
        feasible = (sram <= budget) & res.feasible
        dram_total = np.asarray(fm, dtype=np.float64) + float(wb)
        infeas, primary, secondary = _keys_np(objective, lat, dram_total,
                                              sram, feasible)
        best = _fold(best, argmin_lanes(infeas, primary, secondary,
                                        j.astype(np.float64)))
    return best


# ---------------------------------------------------------------- lax variant
def _make_fused(tbl, C, npfx, dims, strides, S, objective):
    """One fused XLA computation: decode C indices from ``lo``, build
    frame masks, replay the allocator scan, reduce the three cost models
    in f64 and return the chunk's winner 4-tuple.  Static shape/constant
    closure; cached per (chunk size, prefix length, dims, objective)."""
    from repro.kernels.alloc_scan import _scan_impl
    G = tbl["n"]
    bpc, goc = tbl["bpc"], tbl["goc"]
    budget = float(tbl["budget"])
    wb = float(tbl["weight_bytes"])
    row_buff = float(tbl["row_buff"])

    def fused(lo, pref, run_of, pos_of, dir_neg, alloc32,
              lt_comp, lt_row, lt_weight, lt_side, dt_rowfm,
              st_comp, st_weight, st_outf, st_outr, st_wrr):
        j = lo + jnp.arange(C, dtype=jnp.int64)
        parts = []
        if npfx:
            parts.append(jnp.broadcast_to(
                pref[None, :].astype(jnp.int64), (C, npfx)))
        for q in range(len(dims)):
            parts.append(((j // strides[q]) % dims[q])[:, None])
        cuts = jnp.concatenate(parts, axis=1)
        cut = cuts[:, run_of]
        pos = pos_of[None, :].astype(jnp.int64)
        frame = jnp.where(dir_neg[None, :], pos >= cut, pos < cut)
        io, buff, side_buff, wrf, bfm, feas = _scan_impl(frame.T, *alloc32)
        io64 = io[:, :G].astype(jnp.float64)
        mem = (lt_weight[None, :] + io64) / bpc
        frame_lat = jnp.maximum(lt_comp[None, :], mem) + goc
        per = jnp.where(lt_side[None, :], lt_comp[None, :],
                        jnp.where(frame, frame_lat, lt_row[None, :]))
        # det: sequential left-to-right accumulation over groups -- the
        # exact addition order of the host's np.cumsum latency total
        lat = jax.lax.fori_loop(
            0, G, lambda g, acc: acc + per[:, g],
            jnp.zeros((C,), jnp.float64))
        # det: int-exact f64 terms; association-free
        row_terms = jnp.sum(jnp.where(frame, 0.0, dt_rowfm[None, :]),
                            axis=1)
        dram_total = row_terms + bfm.astype(jnp.float64) + wb
        rowm = st_comp[None, :] & ~frame
        frm = st_comp[None, :] & frame
        wbuff = jnp.max(jnp.where(rowm, st_weight[None, :], 0.0), axis=1)
        outf = jnp.max(jnp.where(frm, st_outf[None, :], 0.0), axis=1)
        outr = jnp.max(jnp.where(rowm, st_outr[None, :], 0.0), axis=1)
        wrr = jnp.max(jnp.where(rowm, st_wrr[None, :], 0.0), axis=1)
        b = buff.astype(jnp.float64)
        sram_total = (row_buff + jnp.maximum(outf, outr)
                      + jnp.maximum(wrr, wrf.astype(jnp.float64))
                      + b[:, 0] + jnp.maximum(b[:, 1], wbuff) + b[:, 2]
                      + side_buff.astype(jnp.float64))
        feasible = (sram_total <= budget) & feas
        if objective == "latency":
            primary, secondary = lat, sram_total
        elif objective == "sram":
            primary, secondary = sram_total, lat
        else:
            primary, secondary = dram_total, lat
        valid = j < S
        infeas = jnp.where(feasible, 0.0, 1.0)
        infeas = jnp.where(valid, infeas, _PAD_RANK)
        idxf = jnp.where(valid, j.astype(jnp.float64), _HUGE_IDX)
        return jnp.stack(_argmin_hier(infeas, primary, secondary,
                                      idxf, jnp))

    return fused


def _run_lax(engine, tbl, prefix, dims, strides, S, chunk, objective):
    cache = engine.__dict__.setdefault("_pipeline_calls", {})
    npfx = len(prefix)
    key = ("lax", chunk, npfx, dims, objective)
    calls = cache.get(key)
    ndev = len(jax.devices())
    if calls is None:
        fused = _make_fused(tbl, chunk, npfx, dims, strides, S, objective)
        jfused = jax.jit(fused)
        sharded = None
        if ndev > 1:
            # Contiguous linear ranges per device -- the disjoint
            # partitioning of search_pool.partition_space, expressed on
            # the linear index; winners merge with the same deterministic
            # tuple order, so results are device-count-invariant.
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            mesh = jax.make_mesh((ndev,), ("d",))

            def per_device(los, *args):
                return fused(los[0], *args)[None, :]

            # check_rep=False: the body is embarrassingly parallel (no
            # collectives), but jax's replication checker cannot see
            # through the alloc scan's carry and rejects it.
            sharded = jax.jit(shard_map(
                per_device, mesh=mesh,
                in_specs=(P("d"),) + (P(),) * 15,
                out_specs=P("d"), check_rep=False))
        calls = (jfused, sharded)
        cache[key] = calls
    jfused, sharded = calls
    pref = np.asarray(prefix if npfx else [0], dtype=np.int32)
    args = (pref, tbl["run_of"], tbl["pos_of"], tbl["dir_neg"],
            tbl["alloc32"], tbl["lt_comp"], tbl["lt_row"],
            tbl["lt_weight"], tbl["lt_side"], tbl["dt_rowfm"],
            tbl["st_comp"], tbl["st_weight"], tbl["st_outf"],
            tbl["st_outr"], tbl["st_wrr"])
    best = None
    if sharded is not None:
        step = chunk * ndev
        for base in range(0, S, step):
            los = base + np.arange(ndev, dtype=np.int64) * chunk
            wins = np.asarray(sharded(los, *args))
            for row in wins:
                best = _fold(best, row)
    else:
        for lo in range(0, S, chunk):
            best = _fold(best, np.asarray(jfused(np.int64(lo), *args)))
    return best


# ------------------------------------------------------------- pallas variant
if HAVE_JAX:

    def _enum_kernel(meta_ref, pref_ref, runof_ref, pos_ref, dirneg_ref,
                     out_ref, *, nr, npfx, strides, dims, block_b, lanes):
        """Decode one candidate tile's linear indices into frame masks.

        ``cut[run r]`` is either the fixed prefix cut or the mixed-radix
        digit ``(j // stride) % dim``; the mask is then the same
        position/direction comparison as ``_frame_matrix``.  Padded
        lanes carry run -1 and stay 0."""
        i = pl.program_id(0)
        j = (meta_ref[0] + i * block_b
             + jax.lax.broadcasted_iota(jnp.int32, (block_b, lanes), 0))
        runof = runof_ref[...]
        pos = pos_ref[...]
        dneg = dirneg_ref[...] != 0
        cut = jnp.zeros((block_b, lanes), jnp.int32)
        for r in range(nr):
            if r < npfx:
                val = pref_ref[r] + jnp.zeros((block_b, lanes), jnp.int32)
            else:
                q = r - npfx
                val = (j // strides[q]) % dims[q]
            cut = jnp.where(runof == r, val, cut)
        fr = jnp.where(dneg, pos >= cut, pos < cut) & (runof >= 0)
        out_ref[...] = fr.astype(jnp.int32)

    @functools.lru_cache(maxsize=64)
    def _build_enum_call(nb, block_b, lanes, nr, npfx, strides, dims,
                         interpret):
        kernel = functools.partial(_enum_kernel, nr=nr, npfx=npfx,
                                   strides=strides, dims=dims,
                                   block_b=block_b, lanes=lanes)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(nb,),
            in_specs=[pl.BlockSpec((1, lanes), lambda i, *_: (0, 0))] * 3,
            out_specs=pl.BlockSpec((block_b, lanes),
                                   lambda i, *_: (i, 0)))
        return pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((nb * block_b, lanes),
                                           jnp.int32),
            interpret=interpret)

    def _cost_kernel(meta_ref, frame_ref, io_ref, stats_ref, tab_ref,
                     out_ref, *, block_b, lanes, bpc, goc, budget,
                     wbytes, row_buff, obj):
        """f64 cost reductions + in-block hierarchical argmin.

        One output row per tile: the block winner's
        ``(infeas, primary, secondary, idx)``.  The latency total uses a
        one-hot masked lane sum inside a sequential ``fori_loop`` --
        each step adds exactly one group's term, reproducing the host's
        left-to-right ``np.cumsum`` order bit-for-bit; padded lanes add
        an exact 0.0."""
        i = pl.program_id(0)
        tab = tab_ref[...]
        comp, rowl, wlat = tab[0:1, :], tab[1:2, :], tab[2:3, :]
        sidem = tab[3:4, :] > 0.0
        rowfm = tab[4:5, :]
        scomp = tab[5:6, :] > 0.0
        swt, soutf = tab[6:7, :], tab[7:8, :]
        soutr, swrr = tab[8:9, :], tab[9:10, :]
        frame = frame_ref[...] > 0
        io = io_ref[...]
        mem = (wlat + io) / bpc
        fl = jnp.maximum(comp, mem) + goc
        per = jnp.where(sidem, comp, jnp.where(frame, fl, rowl))
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_b, lanes), 1)

        def body(g, acc):
            # det: one-hot lane mask -> exactly one term per step, added
            # in group order (the host's np.cumsum sequence)
            return acc + jnp.sum(jnp.where(lane == g, per, 0.0),
                                 axis=1, keepdims=True)

        lat = jax.lax.fori_loop(0, lanes, body,
                                jnp.zeros((block_b, 1), jnp.float64))
        # det: int-exact f64 terms; association-free
        rterm = jnp.sum(jnp.where(frame, 0.0, rowfm), axis=1,
                        keepdims=True)
        st = stats_ref[...]
        sl = jax.lax.broadcasted_iota(jnp.int32, (block_b, LANES), 1)

        def col(kk):
            # det: one-hot column extraction, a single nonzero term
            return jnp.sum(jnp.where(sl == kk, st, 0.0), axis=1,
                           keepdims=True)

        b0, b1, b2, side = col(0), col(1), col(2), col(3)
        wrf, bfm = col(4), col(5)
        feas = col(6) > 0.0
        dram = rterm + bfm + wbytes
        wbuff = jnp.max(jnp.where(scomp & ~frame, swt, 0.0), axis=1,
                        keepdims=True)
        outf = jnp.max(jnp.where(scomp & frame, soutf, 0.0), axis=1,
                       keepdims=True)
        outr = jnp.max(jnp.where(scomp & ~frame, soutr, 0.0), axis=1,
                       keepdims=True)
        wrr = jnp.max(jnp.where(scomp & ~frame, swrr, 0.0), axis=1,
                      keepdims=True)
        sram = (row_buff + jnp.maximum(outf, outr)
                + jnp.maximum(wrr, wrf) + b0 + jnp.maximum(b1, wbuff)
                + b2 + side)
        feasible = (sram <= budget) & feas
        j = (meta_ref[0] + i * block_b
             + jax.lax.broadcasted_iota(jnp.int32, (block_b, 1), 0))
        valid = j < meta_ref[1]
        infeas = jnp.where(feasible, 0.0, 1.0)
        infeas = jnp.where(valid, infeas, _PAD_RANK)
        idxf = jnp.where(valid, j.astype(jnp.float64), _HUGE_IDX)
        if obj == "latency":
            primary, secondary = lat, sram
        elif obj == "sram":
            primary, secondary = sram, lat
        else:
            primary, secondary = dram, lat
        w0, w1, w2, w3 = _argmin_hier(infeas, primary, secondary,
                                      idxf, jnp)
        ol = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
        out_ref[...] = jnp.where(
            ol == 0, w0, jnp.where(ol == 1, w1, jnp.where(
                ol == 2, w2, jnp.where(ol == 3, w3, 0.0))))

    @functools.lru_cache(maxsize=64)
    def _build_cost_call(nb, block_b, lanes, bpc, goc, budget, wbytes,
                         row_buff, obj, interpret):
        kernel = functools.partial(_cost_kernel, block_b=block_b,
                                   lanes=lanes, bpc=bpc, goc=goc,
                                   budget=budget, wbytes=wbytes,
                                   row_buff=row_buff, obj=obj)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(nb,),
            in_specs=[
                pl.BlockSpec((block_b, lanes), lambda i, *_: (i, 0)),
                pl.BlockSpec((block_b, lanes), lambda i, *_: (i, 0)),
                pl.BlockSpec((block_b, LANES), lambda i, *_: (i, 0)),
                pl.BlockSpec((2 * SUBLANES, lanes),
                             lambda i, *_: (0, 0)),
            ],
            out_specs=pl.BlockSpec((1, LANES), lambda i, *_: (i, 0)))
        return pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((nb, LANES), jnp.float64),
            interpret=interpret)

    def _argmin_only_kernel(in_ref, out_ref):
        x = in_ref[...]
        w0, w1, w2, w3 = _argmin_hier(x[0:1, :], x[1:2, :], x[2:3, :],
                                      x[3:4, :], jnp)
        ol = jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
        out_ref[...] = jnp.where(
            ol == 0, w0, jnp.where(ol == 1, w1, jnp.where(
                ol == 2, w2, jnp.where(ol == 3, w3, 0.0))))

    @functools.lru_cache(maxsize=16)
    def _build_argmin_call(lp):
        return pl.pallas_call(
            _argmin_only_kernel, grid=(1,),
            in_specs=[pl.BlockSpec((SUBLANES, lp), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((1, LANES), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, LANES), jnp.float64),
            interpret=True)


def _run_pallas(engine, tbl, prefix, dims, strides, S, chunk, objective):
    """Staged Pallas composition: enumeration kernel (i32, compiled on
    TPU) -> ``alloc_scan_pallas`` (i32) -> f64 cost/argmin kernel.  The
    cost stage is float64 for oracle exactness and so always runs in
    interpret mode off-TPU (and on TPU, where the hardware has no f64
    lanes); the masks passed between stages are B x G bitmaps, never
    candidate tuples."""
    from repro.kernels.alloc_scan import alloc_scan_pallas
    G, lanes = tbl["n"], tbl["lanes"]
    nr = len(prefix) + len(dims)
    block_b = max(SUBLANES, min(256, _pad_up(max(chunk, 1), SUBLANES)))
    bp = _pad_up(max(chunk, 1), block_b)
    nb = bp // block_b
    enum_interpret = not _on_tpu()
    enum_call = _build_enum_call(nb, block_b, lanes, nr, len(prefix),
                                 strides, dims, enum_interpret)
    cost_call = _build_cost_call(nb, block_b, lanes, tbl["bpc"],
                                 tbl["goc"], float(tbl["budget"]),
                                 float(tbl["weight_bytes"]),
                                 float(tbl["row_buff"]), objective, True)
    pref = np.asarray(list(prefix) if prefix else [0], dtype=np.int32)
    best = None
    for lo in range(0, S, chunk):
        c = min(chunk, S - lo)
        frame_pad = np.asarray(enum_call(
            np.asarray([lo], dtype=np.int32), pref, tbl["runof_row"],
            tbl["pos_row"], tbl["dirneg_row"]))
        res = alloc_scan_pallas(engine._at,
                                frame_pad[:c, :G].astype(bool))
        io_pad = np.zeros((bp, lanes), dtype=np.float64)
        io_pad[:c, :G] = res.io
        stats = np.zeros((bp, LANES), dtype=np.float64)
        stats[:c, 0:3] = res.buff
        stats[:c, 3] = res.side_buff
        stats[:c, 4] = res.wrf
        stats[:c, 5] = res.bfm
        stats[:c, 6] = res.feasible
        with jax.experimental.enable_x64():
            rows = np.asarray(cost_call(
                np.asarray([lo, S], dtype=np.int32), frame_pad, io_pad,
                stats, tbl["tab"]))
        for row in rows:
            best = _fold(best, row)
    return best


# ------------------------------------------------------------------ entrypoint
def pipeline_subspace(engine, prefix, suffix_dims, objective: str,
                      batch_size: int = DEFAULT_BATCH_SIZE,
                      variant: str = "reference"):
    """Argmin over one sub-space through the fused device pipeline.

    Drop-in for ``branch_bound_subspace``'s return contract:
    ``(CandidateMetrics, pruned)`` with the bit-identical
    ``(key, cuts)``-lexicographic winner.  Every candidate is priced
    in-kernel (no pruning), so ``pruned`` is always 0 and the engine's
    ``evaluations`` is credited with the full enumeration count --
    matching the journal path's ``scored + pruned`` total exactly.  The
    winner itself is re-priced through the engine's exact journal
    scorer, so the returned metrics never depend on kernel arithmetic.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"unknown objective: {objective!r}")
    if variant not in VARIANTS:
        raise ValueError(f"unknown pipeline variant: {variant!r}")
    if variant != "reference" and not HAVE_JAX:
        raise RuntimeError(f"pipeline variant {variant!r} requires jax "
                           f"(use engine='pipeline:reference')")
    prefix = tuple(int(c) for c in prefix)
    dims = tuple(int(d) + 1 for d in suffix_dims)
    nr = len(engine.runs)
    if len(prefix) + len(dims) != nr:
        raise ValueError(f"prefix ({len(prefix)}) + suffix ({len(dims)}) "
                         f"must cover all {nr} runs")
    S = 1
    for d in dims:
        S *= d
    before = engine.evaluations

    def finish(cuts):
        [m] = engine.score_batch([cuts], memoize=False)
        engine.evaluations = before + S
        return m, 0

    if S == 1:
        return finish(prefix + (0,) * len(dims))
    if engine._at is None:
        from repro.kernels.alloc_scan import pack_alloc_tables
        engine._at = pack_alloc_tables(engine.gg, engine.hw)
    tbl = _engine_tables(engine)
    strides = _space_strides(dims)
    chunk = max(1, int(batch_size))
    if variant == "reference":
        best = _run_reference(engine, tbl, prefix, dims, strides, S,
                              chunk, objective)
    elif variant == "lax":
        with jax.experimental.enable_x64():
            best = _run_lax(engine, tbl, prefix, dims, strides, S,
                            chunk, objective)
    else:
        # manages its own x64 scope: the i32 enumeration/allocator
        # stages must trace *without* x64 (weak int literals would
        # promote), only the f64 cost stage runs under it
        best = _run_pallas(engine, tbl, prefix, dims, strides, S,
                           chunk, objective)
    assert best is not None and best[0] < _PAD_RANK
    win = int(best[3])
    return finish(prefix + _decode_index(win, strides, dims))
