"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_block_ref(x, scale, w_gate, w_up, w_down, post_scale=None, *,
                    act: str = "silu", gated: bool = True,
                    sandwich: bool = False, eps: float = 1e-6):
    def norm(v, s):
        v32 = v.astype(jnp.float32)
        var = jnp.mean(jnp.square(v32), axis=-1, keepdims=True)
        return v32 * jax.lax.rsqrt(var + eps) * (1 + s.astype(jnp.float32))

    n = norm(x, scale).astype(x.dtype)
    u = jnp.dot(n, w_up, preferred_element_type=jnp.float32)
    if gated:
        g = jnp.dot(n, w_gate, preferred_element_type=jnp.float32)
        g = g * jax.nn.sigmoid(g) if act == "silu" \
            else jax.nn.gelu(g, approximate=True)
        h = g * u
    else:
        h = u * jax.nn.sigmoid(u) if act == "silu" \
            else jax.nn.gelu(u, approximate=True)
    y = jnp.dot(h.astype(x.dtype), w_down,
                preferred_element_type=jnp.float32)
    if sandwich:
        y = norm(y, post_scale)
    return (x.astype(jnp.float32) + y).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    B, S, NH, hd = q.shape
    _, T, NKV, _ = k.shape
    G = NH // NKV
    qr = q.reshape(B, S, NKV, G, hd)
    s = jnp.einsum("bsngh,btnh->bngst", qr, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    delta = jnp.arange(S)[:, None] - jnp.arange(T)[None, :]
    mask = jnp.ones_like(delta, dtype=bool)
    if causal:
        mask &= delta >= 0
    if window:
        mask &= delta < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnh->bsngh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, NH, hd).astype(q.dtype)


def ssd_scan_ref(x, dt, A, D, Bm, Cm):
    """Sequential (non-chunked) SSD recurrence; fp32.
    x [BH,S,P]; dt [BH,S]; A,D [BH,1]; Bm,Cm [BG,S,N]."""
    BH, S, P = x.shape
    BG, _, N = Bm.shape
    hg = BH // BG
    Bh = jnp.repeat(Bm, hg, axis=0)
    Ch = jnp.repeat(Cm, hg, axis=0)

    def step(state, inp):
        xt, dtt, bt, ct = inp                 # [BH,P], [BH], [BH,N] x2
        dA = jnp.exp(dtt * A[:, 0])           # [BH]
        xdt = xt * dtt[:, None]
        state = state * dA[:, None, None] + \
            jnp.einsum("hp,hn->hpn", xdt, bt)
        y = jnp.einsum("hpn,hn->hp", state, ct) + xt * D
        return state, y

    inputs = (x.swapaxes(0, 1), dt.swapaxes(0, 1),
              Bh.swapaxes(0, 1), Ch.swapaxes(0, 1))
    state0 = jnp.zeros((BH, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, state0,
        jax.tree.map(lambda t: t.astype(jnp.float32), inputs))
    return ys.swapaxes(0, 1).astype(x.dtype)
