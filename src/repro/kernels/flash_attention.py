"""Flash attention -- Pallas TPU kernel (streaming-mode attention).

Online-softmax over KV tiles with (m, l, acc) persisted in VMEM scratch
across the sequential kv grid dimension.  Supports causal masking, local
(sliding-window) masking, gemma-2 logit soft-capping and GQA via a
head->kv-head index map.  Fully-masked KV tiles are skipped with pl.when.

Layout: caller flattens to q [BH, S, hd], k/v [BKV, T, hd]; grid
(BH, S/bq, T/bk) with dimension_semantics (parallel, parallel, arbitrary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, n_k: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    delta = q_pos - k_pos

    # Tile-level skip: the whole tile is masked out iff its minimal delta
    # violates causality or its maximal delta falls outside the window.
    run = jnp.bool_(True)
    if causal:
        run &= (i + 1) * bq - 1 - j * bk >= 0          # max q vs min k
    if window:
        run &= (i * bq - ((j + 1) * bk - 1)) < window  # min q vs max k

    @pl.when(run)
    def _compute():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= delta >= 0
        if window:
            mask &= delta < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256, interpret: bool = False):
    """q [B,S,NH,hd]; k,v [B,T,NKV,hd] -> [B,S,NH,hd]."""
    B, S, NH, hd = q.shape
    _, T, NKV, _ = k.shape
    G = NH // NKV
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    n_q, n_k = S // bq, T // bk

    qf = q.transpose(0, 2, 1, 3).reshape(B * NH, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * NKV, T, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * NKV, T, hd)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, n_k=n_k)

    def kv_map(h, i, j):
        return (h // G, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B * NH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), kv_map),
            pl.BlockSpec((1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * NH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, NH, S, hd).transpose(0, 2, 1, 3)
