"""Scan-style on-device allocator replay (tuples x groups).

The batched candidate scorer (``CutpointEngine.score_batch``) prices B cut
tuples as one set of B x G mask-matrix reductions, but until this module
every batch still paid a *Python* allocator replay per candidate to build
the boundary-I/O matrix and the per-candidate buffer terms.  This module
removes that last serial wall: the sequential allocator of Algorithm 1
(``core/allocator.py::alloc_step``) is re-expressed as a **tensorized
state machine** -- fixed-width integer arrays per candidate, one
data-independent update rule per group -- and the whole replay for a
B-candidate batch runs as a single scan over groups.

State encoding (one row per candidate; ``n`` groups, lane ``n`` is the
``GRAPH_INPUT`` pseudo producer, lane ``n+1`` a write-off sink for padded
fan-in slots -- see ``allocator.state_to_arrays`` for the scalar origin):

* ``rem``  (B, n+2) unmet consumer counts (sink starts huge: never dies)
* ``loc``  (B, n+2) location codes -- buffer id 0..2, ``LOC_SIDE``,
  ``LOC_DRAM`` (graph input and sink are DRAM forever)
* ``live`` (B, 3)   owning gid per physical buffer or ``LIVE_EMPTY``
* ``buff`` (B, 3) / ``side_buff`` (B,)  byte maxima (Algorithm 1)
* ``io``   (B, n+2) per-gid boundary-I/O bytes (reads + boundary writes +
  spill write-outs -- exactly the engine's journal-fed ``_x_io`` rows)
* ``bw``   (B, n+2) boundary-write membership (dedups multi-consumer
  row-side reads of one frame tensor)
* ``bfm`` / ``wrf`` / ``feas`` (B,) running DRAM boundary total, eq. (5)
  frame write-buffer max, and spill feasibility

The per-group update rule computes the side / row / frame branches of
``alloc_step`` as masked vector ops and blends them by the candidate's
frame mask -- no per-candidate control flow, so the same rule runs as

* ``alloc_scan_ref``    -- the numpy reference (exact int64, the oracle
  of record for this module and the production ``engine="device"`` path),
* ``alloc_scan_jax``    -- one ``jax.lax.scan`` over groups (int32),
* ``alloc_scan_pallas`` -- a Pallas TPU kernel, grid = (candidate tiles,
  groups): TPU grids iterate the trailing axis sequentially, so the
  allocator state lives in VMEM scratch across group steps while the
  static per-group tables ride in SMEM via scalar prefetch.  Falls back
  to interpret mode off-TPU, like the other kernels in this package.

All three produce **bit-identical integers** (every quantity is integral
and stays far below 2^31 for the CNN zoo -- int32 is exact, unlike the
float32 scoring kernel in score_batch.py, so the Pallas path here is part
of the exactness contract, enforced by tests/test_alloc_scan.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.allocator import (GRAPH_INPUT, LIVE_EMPTY, LOC_DRAM,
                                  LOC_SIDE, NUM_BUFFERS, graph_steps,
                                  init_alloc_state, spill_is_long_path,
                                  state_to_arrays)
from repro.kernels.score_batch import HAVE_JAX, LANES, SUBLANES, _pad_up

if HAVE_JAX:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

# Sink slot's initial consumer count: decremented once per padded fan-in
# slot per step, must never reach zero.
_SINK_REMAINING = 1 << 40


@dataclass(frozen=True)
class AllocScanTables:
    """Static per-graph tables of the tensorized allocator.

    Per-group rows are indexed by gid; fan-in is padded to width ``k``
    with slots pointing at the sink lane (size 0, location DRAM -- every
    effect of a padded slot is provably a no-op, so the update rule needs
    no validity masks)."""
    n: int                     # real group count
    k: int                     # padded fan-in width (>= 1)
    input_idx: int             # == n: GRAPH_INPUT lane
    sink_idx: int              # == n + 1: padded-slot write-off lane
    is_side: np.ndarray        # (G,) bool
    gin: np.ndarray            # (G, K) int32 producer lanes
    src_size: np.ndarray       # (G, K) int64 producer out bytes (pads: 0)
    main: np.ndarray           # (G,) int32 main-path producer lane
    sc: np.ndarray             # (G,) int32 shortcut lane (sink if none)
    sc_size: np.ndarray        # (G,) int64
    in_size: np.ndarray        # (G,) int64
    out_size: np.ndarray       # (G,) int64
    wr_cand: np.ndarray        # (n+2,) int64 eq. (5) frame write candidates
    spill_ok: np.ndarray       # (G,) bool long-path spill tolerated
    rem0: np.ndarray           # (n+2,) int64 initial consumer counts
    loc0: np.ndarray           # (n+2,) int8 initial location codes


@dataclass(frozen=True)
class AllocScanResult:
    """Per-candidate replay outputs, host-side int64 (B leading axis).

    ``io`` / ``buff`` / ``side_buff`` / ``wrf`` / ``bfm`` / ``feasible``
    are, respectively, the engine's ``_x_io`` rows, the replayed
    ``Allocation.buff`` / ``side_buff``, and its ``_x_wrf`` / ``_x_bfm``
    / ``_x_feas`` accumulators -- everything ``score_batch`` extracts
    from a journal replay, for the whole batch at once."""
    io: np.ndarray             # (B, n)
    buff: np.ndarray           # (B, 3)
    side_buff: np.ndarray      # (B,)
    wrf: np.ndarray            # (B,)
    bfm: np.ndarray            # (B,)
    feasible: np.ndarray       # (B,) bool


def pack_alloc_tables(gg, hw) -> AllocScanTables:
    """Resolve one graph's allocator walk into scan tables.

    ``hw`` feeds the eq. (5) write-buffer candidates (``hw.to`` lane
    count); everything else is pure graph topology from
    ``allocator.graph_steps`` plus the exported ``init_alloc_state``."""
    from repro.core.sram import sram_tables

    steps = graph_steps(gg)
    n = len(steps)
    ni, nd = n, n + 1
    k = max(1, max(len(s.gin) for s in steps))

    def lane(src: int) -> int:
        return ni if src == GRAPH_INPUT else src

    is_side = np.zeros(n, dtype=bool)
    gin = np.full((n, k), nd, dtype=np.int32)
    src_size = np.zeros((n, k), dtype=np.int64)
    main = np.full(n, ni, dtype=np.int32)
    sc = np.full(n, nd, dtype=np.int32)
    sc_size = np.zeros(n, dtype=np.int64)
    in_size = np.zeros(n, dtype=np.int64)
    out_size = np.zeros(n, dtype=np.int64)
    spill_ok = np.zeros(n, dtype=bool)
    for g, s in enumerate(steps):
        is_side[g] = s.is_side
        for j, (src, sz) in enumerate(zip(s.gin, s.src_sizes)):
            gin[g, j] = lane(src)
            src_size[g, j] = sz
        if s.gin:
            main[g] = lane(s.gin[0])
        if s.sc_src is not None:
            sc[g] = lane(s.sc_src)
            sc_size[g] = s.sc_size
        in_size[g] = s.in_size
        out_size[g] = s.out_size
        spill_ok[g] = spill_is_long_path(gg, g)

    st = sram_tables(gg, hw)
    wr_cand = np.zeros(n + 2, dtype=np.int64)
    wr_cand[:n] = np.where(st.compute, np.asarray(st.wr_frame), 0)

    init = state_to_arrays(init_alloc_state(gg, lean=True))
    rem0 = np.empty(n + 2, dtype=np.int64)
    rem0[:n] = init["remaining"][:n]
    rem0[ni] = init["remaining"][n]          # graph input (list slot -1)
    rem0[nd] = _SINK_REMAINING
    loc0 = np.full(n + 2, LOC_DRAM, dtype=np.int8)
    loc0[:n] = init["location"][:n]
    loc0[ni] = init["location"][n]
    return AllocScanTables(n=n, k=k, input_idx=ni, sink_idx=nd,
                           is_side=is_side, gin=gin, src_size=src_size,
                           main=main, sc=sc, sc_size=sc_size,
                           in_size=in_size, out_size=out_size,
                           wr_cand=wr_cand, spill_ok=spill_ok,
                           rem0=rem0, loc0=loc0)


# ------------------------------------------------------------- numpy oracle
def _first_free(mask: np.ndarray) -> np.ndarray:
    """Lowest buffer id whose (B, 3) mask column is True, else -1."""
    return np.where(mask[:, 0], 0,
                    np.where(mask[:, 1], 1,
                             np.where(mask[:, 2], 2, -1)))


def alloc_scan_ref(t: AllocScanTables, frame: np.ndarray) -> AllocScanResult:
    """Numpy reference replay: B candidates through all groups, exact.

    ``frame`` is the (B, G) frame-mask matrix.  The loop is over *groups*
    only; every step is a handful of (B,)-vector ops, so the whole batch
    advances in lock-step -- the same data-independent rule the jax scan
    and the Pallas kernel run, with static fan-in slots unrolled."""
    B = frame.shape[0]
    n, ni = t.n, t.input_idx
    NB = NUM_BUFFERS
    rem = np.broadcast_to(t.rem0, (B, n + 2)).copy()
    loc = np.broadcast_to(t.loc0, (B, n + 2)).copy()
    live = np.full((B, NB), LIVE_EMPTY, dtype=np.int64)
    buff = np.zeros((B, NB), dtype=np.int64)
    side_buff = np.zeros(B, dtype=np.int64)
    io = np.zeros((B, n + 2), dtype=np.int64)
    bw = np.zeros((B, n + 2), dtype=bool)
    bfm = np.zeros(B, dtype=np.int64)
    wrf = np.zeros(B, dtype=np.int64)
    feas = np.ones(B, dtype=bool)
    sink = t.sink_idx

    for g in range(n):
        slots = [(int(t.gin[g, j]), int(t.src_size[g, j]))
                 for j in range(t.k) if t.gin[g, j] != sink]
        outsz = int(t.out_size[g])

        if t.is_side[g]:
            # SE side path: side space regardless of mode, consume, free.
            np.maximum(side_buff, outsz, out=side_buff)
            loc[:, g] = LOC_SIDE
            for src, _ in slots:
                rem[:, src] -= 1
            for src, _ in slots:
                if src == ni:
                    continue
                dead = rem[:, src] <= 0
                sl = loc[:, src]
                for i in range(NB):
                    live[:, i] = np.where(
                        dead & (sl == i) & (live[:, i] == src),
                        LIVE_EMPTY, live[:, i])
            continue

        fr = frame[:, g]
        rw = ~fr

        # ---- frame pre-state: operand locations, DRAM reads, fetch slot
        mloc = loc[:, t.main[g]]
        main_in_buf = mloc < NB
        read_bytes = np.zeros(B, dtype=np.int64)
        in_buf = np.zeros((B, NB), dtype=bool)
        for src, sz in slots:
            sl = loc[:, src]
            read_bytes += np.where(sl == LOC_DRAM, sz, 0)
            for i in range(NB):
                in_buf[:, i] |= sl == i
        fetch_b = _first_free(live == LIVE_EMPTY)
        need_fetch = ~main_in_buf & (fetch_b >= 0)
        insz = int(t.in_size[g])
        for i in range(NB):
            cond = fr & ((main_in_buf & (mloc == i))
                         | (need_fetch & (fetch_b == i)))
            buff[:, i] = np.where(cond, np.maximum(buff[:, i], insz),
                                  buff[:, i])
            in_buf[:, i] |= need_fetch & (fetch_b == i)
        if t.sc[g] != sink:
            sloc = loc[:, t.sc[g]]
            scsz = int(t.sc_size[g])
            for i in range(NB):
                cond = fr & (sloc == i)
                buff[:, i] = np.where(cond, np.maximum(buff[:, i], scsz),
                                      buff[:, i])

        # ---- row branch: frame-produced operands cross the boundary
        for src, sz in slots:
            if src == ni:
                continue                 # graph input is never in a buffer
            add = rw & (loc[:, src] < NB) & ~bw[:, src]
            if add.any():
                bw[:, src] |= add
                delta = np.where(add, sz, 0)
                io[:, src] += delta
                bfm += delta
                wrf = np.where(add, np.maximum(wrf, t.wr_cand[src]), wrf)

        # ---- consume inputs
        for src, _ in slots:
            rem[:, src] -= 1

        # ---- frame branch: boundary reads charged to this group
        rb = np.where(fr, read_bytes, 0)
        io[:, g] += rb
        bfm += rb

        # ---- place this group's output
        final = rem[:, g] == 0
        addf = fr & final & ~bw[:, g]
        bw[:, g] |= addf
        delta = np.where(addf, outsz, 0)
        io[:, g] += delta
        bfm += delta
        wrf = np.where(addf, np.maximum(wrf, t.wr_cand[g]), wrf)

        b_out = _first_free((live == LIVE_EMPTY) & ~in_buf)
        main_live = np.zeros(B, dtype=bool)
        for i in range(NB):
            main_live |= (mloc == i) & (live[:, i] == t.main[g])
        reuse = ((b_out < 0) & main_in_buf
                 & (rem[:, t.main[g]] == 0) & main_live)
        b_out = np.where(reuse, mloc, b_out)
        alloc_out = fr & ~final & (b_out >= 0)
        spill = fr & ~final & (b_out < 0)
        add_sp = spill & ~bw[:, g]
        delta = np.where(add_sp, outsz, 0)
        io[:, g] += delta
        bfm += delta
        if not t.spill_ok[g]:
            feas &= ~spill
        for i in range(NB):
            sel = alloc_out & (b_out == i)
            live[:, i] = np.where(sel, g, live[:, i])
            buff[:, i] = np.where(sel, np.maximum(buff[:, i], outsz),
                                  buff[:, i])
        loc[:, g] = np.where(alloc_out, b_out, LOC_DRAM).astype(np.int8)

        # ---- release dead operands (post output claim, as alloc_step)
        for src, _ in slots:
            if src == ni:
                continue
            dead = rem[:, src] <= 0
            sl = loc[:, src]
            for i in range(NB):
                live[:, i] = np.where(
                    dead & (sl == i) & (live[:, i] == src),
                    LIVE_EMPTY, live[:, i])

    return AllocScanResult(io=io[:, :n], buff=buff, side_buff=side_buff,
                           wrf=wrf, bfm=bfm, feasible=feas)


# ------------------------------------------------------------ jax.lax.scan
if HAVE_JAX:

    @jax.jit
    def _scan_impl(frame_t, is_side, gin, src_size, main, sc, sc_size,
                   in_size, out_size, wr_cand, spill_ok, rem0, loc0):
        """One ``lax.scan`` over groups; all arrays int32 (exact: every
        byte quantity stays far below 2^31 for real CNNs)."""
        G, B = frame_t.shape
        NB = NUM_BUFFERS
        k = gin.shape[1]
        i3 = jnp.arange(NB, dtype=jnp.int32)[None, :]     # (1, 3)

        def first_free(mask):                      # (B, 3) -> (B,)
            return jnp.where(mask[:, 0], 0,
                             jnp.where(mask[:, 1], 1,
                                       jnp.where(mask[:, 2], 2, -1)))

        def step(carry, xs):
            (rem, loc, live, buff, side_buff, io, bw, bfm, wrf, feas) = carry
            (fr_col, side_g, gin_g, sz_g, main_g, sc_g, scsz, insz, outsz,
             wrc_g, sok, g) = xs
            ns = ~side_g

            # side branch: side-space max; row/frame blended below by mask
            side_buff = jnp.where(side_g, jnp.maximum(side_buff, outsz),
                                  side_buff)
            fr = fr_col & ns
            rw = ~fr_col & ns

            # ---- frame pre-state
            mloc = loc[:, main_g]
            main_in_buf = mloc < NB
            read_bytes = jnp.zeros(B, jnp.int32)
            in_buf = jnp.zeros((B, NB), bool)
            for j in range(k):
                sl = loc[:, gin_g[j]]
                read_bytes += jnp.where(sl == LOC_DRAM, sz_g[j], 0)
                in_buf = in_buf | (sl[:, None] == i3)
            fetch_b = first_free(live == LIVE_EMPTY)
            need_fetch = ~main_in_buf & (fetch_b >= 0)
            cond_in = fr[:, None] & (
                (main_in_buf[:, None] & (mloc[:, None] == i3))
                | (need_fetch[:, None] & (fetch_b[:, None] == i3)))
            buff = jnp.where(cond_in, jnp.maximum(buff, insz), buff)
            in_buf = in_buf | (need_fetch[:, None]
                               & (fetch_b[:, None] == i3))
            sloc = loc[:, sc_g]
            cond_sc = fr[:, None] & (sloc[:, None] == i3)
            buff = jnp.where(cond_sc, jnp.maximum(buff, scsz), buff)

            # ---- row branch: frame-produced operands cross the boundary
            for j in range(k):
                src = gin_g[j]
                add = rw & (loc[:, src] < NB) & ~bw[:, src]
                delta = jnp.where(add, sz_g[j], 0)
                bw = bw.at[:, src].set(bw[:, src] | add)
                io = io.at[:, src].add(delta)
                bfm += delta
                wrf = jnp.where(add, jnp.maximum(wrf, wr_cand[src]), wrf)

            # ---- consume inputs
            for j in range(k):
                rem = rem.at[:, gin_g[j]].add(-1)

            # ---- frame boundary reads charged to this group
            rb = jnp.where(fr, read_bytes, 0)
            io = io.at[:, g].add(rb)
            bfm += rb

            # ---- place this group's output
            final = rem[:, g] == 0
            addf = fr & final & ~bw[:, g]
            bw = bw.at[:, g].set(bw[:, g] | addf)
            delta = jnp.where(addf, outsz, 0)
            io = io.at[:, g].add(delta)
            bfm += delta
            wrf = jnp.where(addf, jnp.maximum(wrf, wrc_g), wrf)

            b_out = first_free((live == LIVE_EMPTY) & ~in_buf)
            main_live = jnp.any((mloc[:, None] == i3) & (live == main_g),
                                axis=1)
            reuse = ((b_out < 0) & main_in_buf
                     & (rem[:, main_g] == 0) & main_live)
            b_out = jnp.where(reuse, mloc, b_out)
            alloc_out = fr & ~final & (b_out >= 0)
            spill = fr & ~final & (b_out < 0)
            add_sp = spill & ~bw[:, g]
            delta = jnp.where(add_sp, outsz, 0)
            io = io.at[:, g].add(delta)
            bfm += delta
            feas = feas & (~spill | sok)

            sel = alloc_out[:, None] & (b_out[:, None] == i3)
            live = jnp.where(sel, g, live)
            buff = jnp.where(sel, jnp.maximum(buff, outsz), buff)
            loc = loc.at[:, g].set(
                jnp.where(side_g, LOC_SIDE,
                          jnp.where(alloc_out, b_out, LOC_DRAM)))

            # ---- release dead operands (post output claim)
            for j in range(k):
                src = gin_g[j]
                dead = rem[:, src] <= 0
                sl = loc[:, src]
                freed = (dead[:, None] & (sl[:, None] == i3)
                         & (live == src))
                live = jnp.where(freed, LIVE_EMPTY, live)

            return (rem, loc, live, buff, side_buff, io, bw, bfm, wrf,
                    feas), None

        carry = (
            jnp.broadcast_to(rem0, (B, rem0.shape[0])),
            jnp.broadcast_to(loc0, (B, loc0.shape[0])),
            jnp.full((B, NB), LIVE_EMPTY, jnp.int32),
            jnp.zeros((B, NB), jnp.int32),
            jnp.zeros(B, jnp.int32),
            jnp.zeros((B, rem0.shape[0]), jnp.int32),
            jnp.zeros((B, rem0.shape[0]), bool),
            jnp.zeros(B, jnp.int32),
            jnp.zeros(B, jnp.int32),
            jnp.ones(B, bool),
        )
        xs = (frame_t, is_side, gin, src_size, main, sc, sc_size,
              in_size, out_size, wr_cand[:G], spill_ok,
              jnp.arange(G, dtype=jnp.int32))
        carry, _ = jax.lax.scan(step, carry, xs)
        (rem, loc, live, buff, side_buff, io, bw, bfm, wrf, feas) = carry
        return io, buff, side_buff, wrf, bfm, feas

    def alloc_scan_jax(t: AllocScanTables,
                       frame: np.ndarray) -> AllocScanResult:
        """``jax.lax.scan`` replay; bit-identical integers to the numpy
        reference (int32 internally -- exact for realistic byte counts;
        the sink lane's consumer sentinel is clamped to fit, it only has
        to outlast G x K decrements)."""
        i32 = np.int32
        io, buff, side_buff, wrf, bfm, feas = _scan_impl(
            np.ascontiguousarray(frame.T),
            t.is_side, t.gin.astype(i32), t.src_size.astype(i32),
            t.main.astype(i32), t.sc.astype(i32), t.sc_size.astype(i32),
            t.in_size.astype(i32), t.out_size.astype(i32),
            t.wr_cand.astype(i32), t.spill_ok,
            np.minimum(t.rem0, np.int64(2 ** 31 - 1)).astype(i32),
            t.loc0.astype(i32))
        return AllocScanResult(
            io=np.asarray(io, dtype=np.int64)[:, :t.n],
            buff=np.asarray(buff, dtype=np.int64),
            side_buff=np.asarray(side_buff, dtype=np.int64),
            wrf=np.asarray(wrf, dtype=np.int64),
            bfm=np.asarray(bfm, dtype=np.int64),
            feasible=np.asarray(feas, dtype=bool))

else:                                      # pragma: no cover - jax baked in

    def alloc_scan_jax(t, frame):
        raise RuntimeError("jax is not available: alloc_backend='scan' "
                           "requires jax (use alloc_backend='reference')")


# ------------------------------------------------------------ pallas kernel
# acc scratch lane assignment (per candidate row)
_ACC_SIDE = NUM_BUFFERS          # lanes 0..2: buff maxima
_ACC_WRF = NUM_BUFFERS + 1
_ACC_BFM = NUM_BUFFERS + 2
_ACC_FEAS = NUM_BUFFERS + 3
_N_ACC = NUM_BUFFERS + 4

if HAVE_JAX:

    def _alloc_kernel(is_side_s, gin_s, srcsz_s, main_s, sc_s, scsz_s,
                      insz_s, outsz_s, wrc_s, sok_s,
                      frame_ref, rem0_ref, loc0_ref, io_ref, stats_ref,
                      rem_ref, loc_ref, bw_ref, ios_ref, live_ref, acc_ref,
                      *, k: int, block_b: int, lanes: int):
        """One grid step == one group for one candidate tile.

        TPU grids run the trailing axis sequentially, so the allocator
        state persists in VMEM scratch across the group axis; dynamic
        per-gid lanes are addressed with one-hot iota masks (gather =
        masked row sum, scatter = masked select) and the per-group step
        table rides in SMEM via scalar prefetch."""
        t = pl.program_id(1)
        nt = pl.num_programs(1)
        NB = NUM_BUFFERS
        lane = jax.lax.broadcasted_iota(jnp.int32, (block_b, lanes), 1)
        l3 = jax.lax.broadcasted_iota(jnp.int32, (block_b, LANES), 1)

        @pl.when(t == 0)
        def _init():
            rem_ref[...] = jnp.broadcast_to(rem0_ref[...],
                                            (block_b, lanes))
            loc_ref[...] = jnp.broadcast_to(loc0_ref[...],
                                            (block_b, lanes))
            bw_ref[...] = jnp.zeros((block_b, lanes), jnp.int32)
            ios_ref[...] = jnp.zeros((block_b, lanes), jnp.int32)
            live_ref[...] = jnp.full((block_b, LANES), LIVE_EMPTY,
                                     jnp.int32)
            acc_ref[...] = jnp.where(l3 == _ACC_FEAS, 1, 0)

        rem = rem_ref[...]
        loc = loc_ref[...]
        bw = bw_ref[...]
        io = ios_ref[...]
        live = live_ref[...]                 # lanes 0..2 hold owners
        acc = acc_ref[...]

        def colv(x, j):                      # lane j of x, as (B, 1)
            return jnp.sum(jnp.where(lane == j, x, 0), axis=1,
                           keepdims=True)

        side_g = is_side_s[t] > 0
        main_g = main_s[t]
        sc_g = sc_s[t]
        scsz = scsz_s[t]
        insz = insz_s[t]
        outsz = outsz_s[t]
        wrc_g = wrc_s[t]
        sok = sok_s[t] > 0

        fr_col = colv(frame_ref[...], t) > 0           # (B, 1)
        ns = jnp.logical_not(side_g)
        fr = fr_col & ns
        rw = jnp.logical_not(fr_col) & ns

        # side branch: side-space max
        acc = jnp.where(side_g & (l3 == _ACC_SIDE),
                        jnp.maximum(acc, outsz), acc)

        # ---- frame pre-state
        mloc = colv(loc, main_g)                       # (B, 1)
        main_in_buf = mloc < NB
        read_bytes = jnp.zeros((block_b, 1), jnp.int32)
        in_buf = jnp.zeros((block_b, LANES), bool)     # lanes 0..2 used
        for j in range(k):
            sl = colv(loc, gin_s[t, j])
            read_bytes += jnp.where(sl == LOC_DRAM, srcsz_s[t, j], 0)
            in_buf = in_buf | (sl == l3)
        free = jnp.where(l3 < NB, (live == LIVE_EMPTY), False)
        f0, f1, f2 = colv(free, 0) > 0, colv(free, 1) > 0, colv(free, 2) > 0
        fetch_b = jnp.where(f0, 0, jnp.where(f1, 1, jnp.where(f2, 2, -1)))
        need_fetch = jnp.logical_not(main_in_buf) & (fetch_b >= 0)
        cond_in = fr & ((main_in_buf & (mloc == l3))
                        | (need_fetch & (fetch_b == l3)))
        acc = jnp.where(cond_in & (l3 < NB), jnp.maximum(acc, insz), acc)
        in_buf = in_buf | (need_fetch & (fetch_b == l3))
        sloc = colv(loc, sc_g)
        acc = jnp.where(fr & (sloc == l3) & (l3 < NB),
                        jnp.maximum(acc, scsz), acc)

        # ---- row branch: frame-produced operands cross the boundary
        bfm_add = jnp.zeros((block_b, 1), jnp.int32)
        wrf_new = jnp.zeros((block_b, 1), jnp.int32)
        for j in range(k):
            src = gin_s[t, j]
            sl = colv(loc, src)
            already = colv(bw, src) > 0
            add = rw & (sl < NB) & jnp.logical_not(already)
            delta = jnp.where(add, srcsz_s[t, j], 0)
            bw = jnp.where((lane == src) & add, 1, bw)
            io = jnp.where(lane == src, io + delta, io)
            bfm_add += delta
            wrf_new = jnp.maximum(wrf_new,
                                  jnp.where(add, wrc_s[src], 0))

        # ---- consume inputs
        for j in range(k):
            rem = jnp.where(lane == gin_s[t, j], rem - 1, rem)

        # ---- frame boundary reads charged to this group
        rb = jnp.where(fr, read_bytes, 0)
        io = jnp.where(lane == t, io + rb, io)
        bfm_add += rb

        # ---- place this group's output
        final = colv(rem, t) == 0
        addf = fr & final & jnp.logical_not(colv(bw, t) > 0)
        bw = jnp.where((lane == t) & addf, 1, bw)
        delta = jnp.where(addf, outsz, 0)
        io = jnp.where(lane == t, io + delta, io)
        bfm_add += delta
        wrf_new = jnp.maximum(wrf_new, jnp.where(addf, wrc_g, 0))

        ofree = free & jnp.logical_not(in_buf)
        o0, o1, o2 = colv(ofree, 0) > 0, colv(ofree, 1) > 0, colv(ofree, 2) > 0
        b_out = jnp.where(o0, 0, jnp.where(o1, 1, jnp.where(o2, 2, -1)))
        main_live = jnp.sum(jnp.where((mloc == l3) & (live == main_g),
                                      1, 0), axis=1, keepdims=True) > 0
        reuse = ((b_out < 0) & main_in_buf
                 & (colv(rem, main_g) == 0) & main_live)
        b_out = jnp.where(reuse, mloc, b_out)
        alloc_out = fr & jnp.logical_not(final) & (b_out >= 0)
        spill = fr & jnp.logical_not(final) & (b_out < 0)
        add_sp = spill & jnp.logical_not(colv(bw, t) > 0)
        delta = jnp.where(add_sp, outsz, 0)
        io = jnp.where(lane == t, io + delta, io)
        bfm_add += delta
        feas_kill = spill & jnp.logical_not(sok)

        sel = alloc_out & (b_out == l3) & (l3 < NB)
        live = jnp.where(sel, t, live)
        acc = jnp.where(sel, jnp.maximum(acc, outsz), acc)
        loc_t = jnp.where(side_g, LOC_SIDE,
                          jnp.where(alloc_out, b_out, LOC_DRAM))
        loc = jnp.where(lane == t, loc_t, loc)

        # ---- release dead operands (post output claim)
        for j in range(k):
            src = gin_s[t, j]
            dead = colv(rem, src) <= 0
            sl = colv(loc, src)
            freed = dead & (sl == l3) & (live == src) & (l3 < NB)
            live = jnp.where(freed, LIVE_EMPTY, live)

        # fold the scalar accumulators into their acc lanes
        acc = jnp.where(l3 == _ACC_WRF, jnp.maximum(acc, wrf_new), acc)
        acc = jnp.where(l3 == _ACC_BFM, acc + bfm_add, acc)
        acc = jnp.where((l3 == _ACC_FEAS) & feas_kill, 0, acc)

        rem_ref[...] = rem
        loc_ref[...] = loc
        bw_ref[...] = bw
        ios_ref[...] = io
        live_ref[...] = live
        acc_ref[...] = acc

        @pl.when(t == nt - 1)
        def _emit():
            io_ref[...] = io
            stats_ref[...] = acc

    _ALLOC_CALL_CACHE: dict = {}

    def _build_alloc_call(nb: int, G: int, k: int, block_b: int,
                          lanes: int, interpret: bool):
        from functools import partial
        key = (nb, G, k, block_b, lanes, interpret)
        fn = _ALLOC_CALL_CACHE.get(key)
        if fn is not None:
            return fn
        bp = nb * block_b
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=10,
            grid=(nb, G),
            in_specs=[
                pl.BlockSpec((block_b, lanes), lambda i, t, *_: (i, 0)),
                pl.BlockSpec((1, lanes), lambda i, t, *_: (0, 0)),
                pl.BlockSpec((1, lanes), lambda i, t, *_: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_b, lanes), lambda i, t, *_: (i, 0)),
                pl.BlockSpec((block_b, LANES), lambda i, t, *_: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_b, lanes), jnp.int32),   # rem
                pltpu.VMEM((block_b, lanes), jnp.int32),   # loc
                pltpu.VMEM((block_b, lanes), jnp.int32),   # bw
                pltpu.VMEM((block_b, lanes), jnp.int32),   # io
                pltpu.VMEM((block_b, LANES), jnp.int32),   # live
                pltpu.VMEM((block_b, LANES), jnp.int32),   # acc
            ],
        )
        call = pl.pallas_call(
            partial(_alloc_kernel, k=k, block_b=block_b, lanes=lanes),
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((bp, lanes), jnp.int32),
                jax.ShapeDtypeStruct((bp, LANES), jnp.int32),
            ],
            interpret=interpret,
        )
        fn = _ALLOC_CALL_CACHE[key] = jax.jit(call)
        return fn

    def alloc_scan_pallas(t: AllocScanTables, frame: np.ndarray,
                          interpret: bool | None = None,
                          block_b: int = 256) -> AllocScanResult:
        """Pallas replay; bit-identical integers to the numpy reference.

        ``interpret=None`` auto-selects: compiled on TPU hosts, Pallas
        interpret mode elsewhere (same kernel body, jax-evaluated)."""
        from repro.kernels.score_batch import _on_tpu
        if interpret is None:
            interpret = not _on_tpu()
        b = frame.shape[0]
        n = t.n
        lanes = _pad_up(n + 2, LANES)
        block_b = max(SUBLANES, min(block_b, _pad_up(max(b, 1), SUBLANES)))
        bp = _pad_up(max(b, 1), block_b)
        fp = np.zeros((bp, lanes), np.int32)
        fp[:b, :n] = frame
        rem0 = np.zeros((1, lanes), np.int32)
        rem0[0, :n + 2] = np.minimum(t.rem0, np.int64(2 ** 31 - 1))
        loc0 = np.full((1, lanes), LOC_DRAM, np.int32)
        loc0[0, :n + 2] = t.loc0
        i32 = np.int32
        scalars = (t.is_side.astype(i32), t.gin.astype(i32),
                   t.src_size.astype(i32), t.main.astype(i32),
                   t.sc.astype(i32), t.sc_size.astype(i32),
                   t.in_size.astype(i32), t.out_size.astype(i32),
                   np.pad(t.wr_cand, (0, lanes - (n + 2))).astype(i32),
                   t.spill_ok.astype(i32))
        fn = _build_alloc_call(bp // block_b, n, t.k, block_b, lanes,
                               interpret)
        io, stats = fn(*scalars, fp, rem0, loc0)
        io = np.asarray(io, dtype=np.int64)
        stats = np.asarray(stats, dtype=np.int64)
        return AllocScanResult(
            io=io[:b, :n],
            buff=stats[:b, :NUM_BUFFERS],
            side_buff=stats[:b, _ACC_SIDE],
            wrf=stats[:b, _ACC_WRF],
            bfm=stats[:b, _ACC_BFM],
            feasible=stats[:b, _ACC_FEAS] > 0)

else:                                      # pragma: no cover - jax baked in

    def alloc_scan_pallas(t, frame, interpret=None, block_b=256):
        raise RuntimeError("jax is not available: alloc_backend='pallas' "
                           "requires jax (use alloc_backend='reference')")


def alloc_scan(t: AllocScanTables, frame: np.ndarray,
               backend: str = "reference",
               interpret: bool | None = None,
               skip: np.ndarray | None = None) -> AllocScanResult:
    """Run the tensorized allocator replay for a B x G frame-mask batch.

    ``backend`` selects the implementation -- ``"reference"`` (numpy,
    default), ``"scan"`` (``jax.lax.scan``) or ``"pallas"`` -- all three
    bit-identical on integer outputs (tests/test_alloc_scan.py).

    ``skip`` (optional, bool (B,)) masks out batch lanes pruned by the
    branch-and-bound search before any replay work: skipped rows are
    compressed away, the surviving sub-batch runs through the selected
    backend unchanged, and the outputs are scattered back into
    zero-filled full-width arrays (``feasible`` defaults ``True`` on
    skipped lanes so downstream masking stays inert).  The surviving
    rows are bit-identical to an unskipped call on the same sub-batch."""
    if skip is not None:
        skip = np.asarray(skip, dtype=bool)
        b = frame.shape[0]
        if skip.shape != (b,):
            raise ValueError(
                f"skip mask shape {skip.shape} != batch ({b},)")
        n = t.n
        if skip.all():
            return AllocScanResult(
                io=np.zeros((b, n), np.int64),
                buff=np.zeros((b, NUM_BUFFERS), np.int64),
                side_buff=np.zeros(b, np.int64),
                wrf=np.zeros(b, np.int64),
                bfm=np.zeros(b, np.int64),
                feasible=np.ones(b, bool))
        keep = ~skip
        sub = alloc_scan(t, frame[keep], backend=backend,
                         interpret=interpret)
        io = np.zeros((b, n), np.int64)
        buff = np.zeros((b, NUM_BUFFERS), np.int64)
        side_buff = np.zeros(b, np.int64)
        wrf = np.zeros(b, np.int64)
        bfm = np.zeros(b, np.int64)
        feasible = np.ones(b, bool)
        io[keep] = sub.io
        buff[keep] = sub.buff
        side_buff[keep] = sub.side_buff
        wrf[keep] = sub.wrf
        bfm[keep] = sub.bfm
        feasible[keep] = sub.feasible
        return AllocScanResult(io=io, buff=buff, side_buff=side_buff,
                               wrf=wrf, bfm=bfm, feasible=feasible)
    if backend == "reference":
        return alloc_scan_ref(t, frame)
    if backend == "scan":
        return alloc_scan_jax(t, frame)
    if backend == "pallas":
        return alloc_scan_pallas(t, frame, interpret=interpret)
    raise ValueError(f"unknown alloc_scan backend: {backend!r}")
