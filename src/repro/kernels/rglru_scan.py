"""RG-LRU gated linear recurrence -- Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t, with the running state h pinned in VMEM scratch
across the sequential chunk grid dimension (the same on-chip state
residency contract as ssd_scan.py).  Within a chunk the recurrence runs as
an unrolled log-depth inclusive scan over the chunk axis.

Layout: a, b [B, S, W] fp32 (gates precomputed by the XLA prologue);
grid (B, S/Q, W/bw) with dimension_semantics (parallel, arbitrary,
parallel) -- wait, state must persist over the S dim, so the grid is
(B, W/bw, S/Q) with the chunk dim innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(a_ref, b_ref, o_ref, h_ref, *, q: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)          # [Q, bw]
    b = b_ref[0].astype(jnp.float32)

    # inclusive scan h_t = a_t h_{t-1} + b_t via log-depth doubling
    # (Blelloch-style on the linear-recurrence monoid)
    prod = a
    acc = b
    shift = 1
    while shift < q:
        prod_s = jnp.roll(prod, shift, axis=0)
        acc_s = jnp.roll(acc, shift, axis=0)
        mask = (jax.lax.broadcasted_iota(jnp.int32, (q, 1), 0) >= shift)
        acc = jnp.where(mask, prod * acc_s + acc, acc)
        prod = jnp.where(mask, prod * prod_s, prod)
        shift *= 2
    # fold in the carried state: h_t += (prod over [0..t]) * h_in
    h_in = h_ref[...]                          # [1, bw]
    h = acc + prod * h_in
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "block_w",
                                             "interpret"))
def rglru_scan_kernel(a, b, *, chunk: int = 128, block_w: int = 256,
                      interpret: bool = False):
    """a, b [B, S, W] -> h [B, S, W] (fp32 recurrence)."""
    B, S, W = a.shape
    q = min(chunk, S)
    bw = min(block_w, W)
    assert S % q == 0 and W % bw == 0
    kernel = functools.partial(_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(B, W // bw, S // q),
        in_specs=[
            pl.BlockSpec((1, q, bw), lambda i, w, c: (i, c, w)),
            pl.BlockSpec((1, q, bw), lambda i, w, c: (i, c, w)),
        ],
        out_specs=pl.BlockSpec((1, q, bw), lambda i, w, c: (i, c, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
