"""Pallas kernel for batched cut-candidate scoring (tuples x groups).

The cut-point engine's batched scorer (``CutpointEngine.score_batch``)
expands B cut tuples into a B x G frame-mask matrix plus a B x G
boundary-IO matrix and reduces them against the static per-group cost
tables (``latency_tables`` / ``dram_tables`` / ``sram_tables``).  On CPU
those reductions are numpy; this module stages the *same* masked
reduction as a Pallas TPU kernel -- the on-device path the ROADMAP names
for moving the search itself onto the accelerator.  One kernel launch
computes, per candidate:

* ``latency``  -- sum over groups of
  ``where(side, comp, where(frame, max(comp, (weight+io)/bpc) + ovh, row))``
  (the row-major masked latency reduction of ``latency_cycles_fast_batch``)
* ``row_fm``   -- the row-mode DRAM feature-map term,
  ``sum(where(~frame, row_fm, 0))``
* the four SRAM maxima of eqs. (1)/(4)/(5):
  ``weight_buff`` (row-mode weight max), ``out_frame`` / ``out_row``
  (partial-sum buffer candidates) and ``wr_row`` (write-buffer max)

Layout: candidates ride the sublane axis (one candidate per row), groups
ride the lane axis padded to 128; the per-group tables are (1, Gp) rows
broadcast across the candidate tile.  Outputs land in a (B, 128) stats
matrix whose first ``N_STATS`` lanes are the reductions above.

Exactness: the kernel runs in float32 (TPU-native), so it is NOT part of
the engine's bit-exact oracle contract -- the numpy backend stays the
default and the oracle of record.  The kernel's own contract is agreement
with :func:`score_batch_ref` (the float32 numpy reference below), which
tests/test_score_batch.py enforces in interpret mode, exactly like the
other kernels in this package validate against kernels/ref.py.  On hosts
without a TPU the wrapper automatically falls back to interpret mode.

The tiling/layout helpers here (``LANES``/``SUBLANES``/``_pad_up``/
``_on_tpu``) are shared with the allocator-replay scan kernel
(kernels/alloc_scan.py), which uses the same candidates-on-sublanes,
gids-on-lanes layout for its state rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np

try:                                   # optional at runtime, like ops.py
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    HAVE_JAX = True
except Exception:                      # pragma: no cover - jax is baked in
    HAVE_JAX = False

LANES = 128                            # TPU lane width (last axis)
SUBLANES = 8                           # float32 sublane tile
N_STATS = 6                            # stats lanes used per candidate
TABLE_KEYS = ("comp", "row", "weight", "side", "row_fm", "compute",
              "out_frame", "out_row", "wr_row")


def _pad_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _on_tpu() -> bool:
    """Whether the default jax device is a TPU (compiled-vs-interpret
    auto-selection for this kernel and kernels/alloc_scan.py)."""
    if not HAVE_JAX:
        return False
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:                     # pragma: no cover
        return False


def pack_tables(lt, dt, st) -> dict:
    """Pack the engine's static cost tables into (1, Gp) float32 rows.

    ``lt`` / ``dt`` / ``st`` are the ``LatencyTables`` / ``DRAMTables`` /
    ``SRAMTables`` of one graph; Gp pads the group axis to the TPU lane
    width.  Padding lanes hold zeros, which make every reduction a no-op
    there (masks are 0, ``row``/``row_fm`` are 0, maxima are against 0).
    """
    g = lt.comp.shape[0]
    gp = _pad_up(max(g, 1), LANES)

    def pad(a) -> np.ndarray:
        out = np.zeros((1, gp), np.float32)
        out[0, :g] = np.asarray(a, np.float64)[:g]
        return out

    return {
        "g": g, "gp": gp,
        "comp": pad(lt.comp), "row": pad(lt.row), "weight": pad(lt.weight),
        "side": pad(lt.side), "row_fm": pad(dt.row_fm),
        "compute": pad(st.compute), "out_frame": pad(st.out_frame),
        "out_row": pad(st.out_row), "wr_row": pad(st.wr_row),
    }


@dataclass(frozen=True)
class BatchStats:
    """Per-candidate reductions, shaped (B,), host-side."""
    latency: np.ndarray        # float64 (cast from f32)
    row_fm: np.ndarray         # int64: row-mode DRAM fm term
    maxima: tuple              # (weight_buff, out_frame, out_row, wr_row)


def score_batch_ref(tables: dict, frame: np.ndarray, io: np.ndarray,
                    bpc: float, overhead: float) -> np.ndarray:
    """Float32 numpy reference for the kernel (the agreement target).

    Returns the (B, N_STATS) stats matrix
    ``[latency, row_fm, weight_buff, out_frame, out_row, wr_row]``
    computed with the same op structure and dtype as the kernel body.
    """
    g = tables["g"]
    fr = np.asarray(frame, bool)[:, :g]
    iof = np.asarray(io, np.float32)[:, :g]
    comp = tables["comp"][:, :g]
    row = tables["row"][:, :g]
    weight = tables["weight"][:, :g]
    side = tables["side"][:, :g] > 0
    row_fm = tables["row_fm"][:, :g]
    cm = tables["compute"][:, :g] > 0
    out_frame = tables["out_frame"][:, :g]
    out_row = tables["out_row"][:, :g]
    wr_row = tables["wr_row"][:, :g]

    mem = (weight + iof) / np.float32(bpc)
    frame_lat = np.maximum(comp, mem) + np.float32(overhead)
    per = np.where(side, comp, np.where(fr, frame_lat, row))
    lat = per.sum(axis=1, dtype=np.float32)
    rfm = np.where(fr, np.float32(0), row_fm).sum(axis=1, dtype=np.float32)
    rowm = cm & ~fr
    frm = cm & fr
    z = np.float32(0)
    wbuff = np.where(rowm, weight, z).max(axis=1, initial=0)
    outf = np.where(frm, out_frame, z).max(axis=1, initial=0)
    outr = np.where(rowm, out_row, z).max(axis=1, initial=0)
    wrr = np.where(rowm, wr_row, z).max(axis=1, initial=0)
    return np.stack([lat, rfm, wbuff, outf, outr, wrr],
                    axis=1).astype(np.float32)


if HAVE_JAX:

    def _score_kernel(frame_ref, io_ref, comp_ref, row_ref, weight_ref,
                      side_ref, rowfm_ref, computem_ref, outf_ref, outr_ref,
                      wrr_ref, out_ref, *, bpc: float, overhead: float):
        frame = frame_ref[...] > 0           # (TB, Gp) mask
        io = io_ref[...]
        comp = comp_ref[...]                 # (1, Gp), broadcasts over TB
        mem = (weight_ref[...] + io) / bpc
        frame_lat = jnp.maximum(comp, mem) + overhead
        per = jnp.where(side_ref[...] > 0, comp,
                        jnp.where(frame, frame_lat, row_ref[...]))
        lat = jnp.sum(per, axis=1)
        rfm = jnp.sum(jnp.where(frame, 0.0, rowfm_ref[...]), axis=1)
        cm = computem_ref[...] > 0
        rowm = cm & ~frame
        frm = cm & frame
        wbuff = jnp.max(jnp.where(rowm, weight_ref[...], 0.0), axis=1)
        outf = jnp.max(jnp.where(frm, outf_ref[...], 0.0), axis=1)
        outr = jnp.max(jnp.where(rowm, outr_ref[...], 0.0), axis=1)
        wrr = jnp.max(jnp.where(rowm, wrr_ref[...], 0.0), axis=1)
        stats = jnp.stack([lat, rfm, wbuff, outf, outr, wrr], axis=1)
        pad = jnp.zeros((stats.shape[0], out_ref.shape[1] - N_STATS),
                        stats.dtype)
        out_ref[...] = jnp.concatenate([stats, pad], axis=1)

    _CALL_CACHE: dict = {}

    def _build_call(bp: int, gp: int, block_b: int, bpc: float,
                    overhead: float, interpret: bool):
        key = (bp, gp, block_b, bpc, overhead, interpret)
        fn = _CALL_CACHE.get(key)
        if fn is not None:
            return fn
        tab_spec = pl.BlockSpec((1, gp), lambda i: (0, 0))
        call = pl.pallas_call(
            partial(_score_kernel, bpc=bpc, overhead=overhead),
            grid=(bp // block_b,),
            in_specs=[pl.BlockSpec((block_b, gp), lambda i: (i, 0)),
                      pl.BlockSpec((block_b, gp), lambda i: (i, 0))]
            + [tab_spec] * len(TABLE_KEYS),
            out_specs=pl.BlockSpec((block_b, LANES), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, LANES), jnp.float32),
            interpret=interpret,
        )
        fn = _CALL_CACHE[key] = jax.jit(call)
        return fn

    def score_batch_pallas(tables: dict, frame: np.ndarray, io: np.ndarray,
                           bpc: float, overhead: float,
                           interpret: bool | None = None,
                           block_b: int = 256) -> np.ndarray:
        """Run the kernel; returns the (B, N_STATS) float32 stats matrix.

        ``interpret=None`` auto-selects: compiled on TPU hosts, Pallas
        interpret mode elsewhere (same kernel body, jax-evaluated)."""
        if interpret is None:
            interpret = not _on_tpu()
        b, g = frame.shape
        gp = tables["gp"]
        block_b = max(SUBLANES, min(block_b, _pad_up(max(b, 1), SUBLANES)))
        bp = _pad_up(max(b, 1), block_b)
        fp = np.zeros((bp, gp), np.float32)
        fp[:b, :g] = frame
        iop = np.zeros((bp, gp), np.float32)
        iop[:b, :g] = io
        fn = _build_call(bp, gp, block_b, float(bpc), float(overhead),
                         interpret)
        out = fn(fp, iop, *[tables[k] for k in TABLE_KEYS])
        return np.asarray(out)[:b, :N_STATS]

else:                                      # pragma: no cover - jax baked in

    def score_batch_pallas(tables, frame, io, bpc, overhead,
                           interpret=None, block_b=256):
        raise RuntimeError("jax is not available: the pallas score_batch "
                           "backend requires jax (use backend='numpy')")


def score_stats(tables: dict, frame: np.ndarray, io: np.ndarray,
                hw, interpret: bool | None = None) -> BatchStats:
    """Engine adapter: kernel stats for one batch against ``hw``.

    Converts the (B, N_STATS) float32 stats matrix into the shapes the
    batched cost models consume (``row_terms`` / ``maxima`` injection
    points of ``dram_fm_fast_batch`` / ``sram_total_fast_batch``).  The
    int quantities are rounded from float32 -- exact only while the true
    values stay under 2**24, which is why this path is staged behind
    ``backend="pallas"`` rather than replacing the numpy oracle."""
    stats = score_batch_pallas(tables, frame, io,
                               hw.dram_bytes_per_cycle,
                               hw.group_overhead_cycles,
                               interpret=interpret)
    as_int = [np.rint(stats[:, i]).astype(np.int64) for i in range(1, 6)]
    return BatchStats(latency=stats[:, 0].astype(np.float64),
                      row_fm=as_int[0],
                      maxima=(as_int[1], as_int[2], as_int[3], as_int[4]))
