# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Resident kernels for this reproduction's search loop:
#   score_batch.py     -- B x G mask-matrix candidate pricing (float32
#                         Pallas staging of the batched cost-model
#                         reductions, CutpointEngine backend="pallas")
#   alloc_scan.py      -- tensorized allocator replay: Algorithm 1's
#                         sequential state machine as a scan over groups
#                         (numpy reference / jax.lax.scan / Pallas, all
#                         integer-exact; CompileOptions engine="device")
#   search_pipeline.py -- fully fused sub-space search: in-kernel
#                         candidate enumeration -> alloc_scan replay ->
#                         exact cost reductions -> hierarchical argmin,
#                         so only the winning tuple reaches the host
#                         (CompileOptions engine="pipeline")
# All fall back to interpret mode off-TPU and are validated against
# their numpy references (tests/test_score_batch.py,
# tests/test_alloc_scan.py, tests/test_search_pipeline.py) in the
# kernels-interpret CI job.
