"""ShortcutFusion fused residual block -- Pallas TPU kernel.

The paper's frame-reuse mode on the HBM->VMEM hierarchy: the residual
("shortcut") tile x is pinned in VMEM for the whole block

    y = x + [post_norm]( act(n @ Wg) * (n @ Wu) ) @ Wd,   n = rmsnorm(x)

so the stream makes exactly one HBM round-trip per block while the weights
stream through VMEM exactly once (the paper's constraint (10)).  The three
interchangeable buffers of Fig. 6 map to: x tile (shortcut), normalized
tile (input) and the fp32 accumulator (output); weight slabs double-buffer
through the remaining VMEM exactly like the paper's weight blocks.

Grid: (M/bm, F/bf); the ff axis is the sequential 'arbitrary' dimension,
accumulating partial W_down contributions into the VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _act(name: str, x):
    if name == "silu":
        return x * jax.nn.sigmoid(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def _kernel(x_ref, scale_ref, wg_ref, wu_ref, wd_ref, post_ref,
            o_ref, nrm_ref, acc_ref, *, act: str, gated: bool,
            sandwich: bool, eps: float, n_ff: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        x = x_ref[...].astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        n = x * jax.lax.rsqrt(var + eps)
        n = n * (1.0 + scale_ref[...].astype(jnp.float32))
        nrm_ref[...] = n.astype(nrm_ref.dtype)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n = nrm_ref[...]
    u = jnp.dot(n, wu_ref[...], preferred_element_type=jnp.float32)
    if gated:
        g = jnp.dot(n, wg_ref[...], preferred_element_type=jnp.float32)
        h = _act(act, g) * u
    else:
        h = _act(act, u)
    acc_ref[...] += jnp.dot(h.astype(n.dtype), wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_ff - 1)
    def _finish():
        y = acc_ref[...]
        if sandwich:
            var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
            y = y * jax.lax.rsqrt(var + eps)
            y = y * (1.0 + post_ref[...].astype(jnp.float32))
        o_ref[...] = (x_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("act", "gated", "sandwich", "block_m",
                              "block_f", "interpret"))
def fused_block(x, scale, w_gate, w_up, w_down, post_scale=None, *,
                act: str = "silu", gated: bool = True,
                sandwich: bool = False, block_m: int = 256,
                block_f: int = 512, eps: float = 1e-6,
                interpret: bool = False):
    """x [M, d] -> [M, d].  w_gate/w_up [d, F], w_down [F, d], scales [d]."""
    M, d = x.shape
    F = w_up.shape[1]
    bm = min(block_m, M)
    bf = min(block_f, F)
    assert M % bm == 0 and F % bf == 0, (M, bm, F, bf)
    n_m, n_ff = M // bm, F // bf
    if post_scale is None:
        post_scale = jnp.zeros_like(scale)

    kernel = functools.partial(_kernel, act=act, gated=gated,
                               sandwich=sandwich, eps=eps, n_ff=n_ff)
    return pl.pallas_call(
        kernel,
        grid=(n_m, n_ff),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),       # x (shortcut)
            pl.BlockSpec((d,), lambda i, j: (0,)),            # pre-norm
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),       # w_gate slab
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),       # w_up slab
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),       # w_down slab
            pl.BlockSpec((d,), lambda i, j: (0,)),            # post-norm
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, d), x.dtype),                     # normalized x
            pltpu.VMEM((bm, d), jnp.float32),                 # accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, scale, w_gate, w_up, w_down, post_scale)
