"""Public kernel entry points: dispatch Pallas on TPU, interpret elsewhere.

These are what the resident-mode execution path calls; the streaming path
uses the plain XLA implementations in models/*.py.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_block import fused_block as _fused_block
from repro.kernels.ssd_scan import ssd_scan as _ssd

_FORCE_INTERPRET: bool | None = None


def set_interpret(value: bool | None) -> None:
    """Override interpret mode (None = auto by platform)."""
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    return jax.default_backend() != "tpu"


def fused_block(x, scale, w_gate, w_up, w_down, post_scale=None, **kw):
    kw.setdefault("interpret", _interpret())
    return _fused_block(x, scale, w_gate, w_up, w_down, post_scale, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash(q, k, v, **kw)


def ssd_scan(x, dt, A, D, Bm, Cm, **kw):
    kw.setdefault("interpret", _interpret())
    return _ssd(x, dt, A, D, Bm, Cm, **kw)
