"""Mamba-2 SSD chunked scan -- Pallas TPU kernel.

The inter-chunk recurrent state [P, N] lives in VMEM scratch and is carried
across the sequential chunk grid dimension -- the on-chip state residency
that core/residency.py plans for SSM blocks (the paper's SE-side-path
analogue).  Within a chunk the quadratic SSD form runs on the MXU.

Layout: per (batch*head) row; B/C are shared across heads within a group
(g groups), mapped via head -> group index maps.
  x  [BH, S, P]   dt [BH, S]   A [BH, 1]   D [BH, 1]
  Bm [BG, S, N]   Cm [BG, S, N]
Grid (BH, S/Q) with dimension_semantics (parallel, arbitrary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(x_ref, dt_ref, a_ref, d_ref, b_ref, c_ref, o_ref,
            state_ref, *, q: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)                   # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)                 # [Q]
    A = a_ref[0, 0].astype(jnp.float32)                # scalar (negative)
    D = d_ref[0, 0].astype(jnp.float32)
    Bm = b_ref[0].astype(jnp.float32)                  # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                  # [Q, N]

    dA = dt * A                                        # [Q]
    cum = jnp.cumsum(dA)                               # [Q]
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]                              # [Q, P]
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general((scores * L), xdt,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    # inter-chunk contribution: y_off = (C * exp(cum)) @ state^T
    state = state_ref[...]                             # [P, N]
    Cdec = Cm * jnp.exp(cum)[:, None]
    y_off = jax.lax.dot_general(Cdec, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0] = (y_diag + y_off + x * D).astype(o_ref.dtype)

    # state' = state * exp(cum[-1]) + sum_q decay_q * xdt_q (x) B_q
    decay = jnp.exp(cum[-1] - cum)                     # [Q]
    state_ref[...] = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xdt * decay[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "nheads", "interpret"))
def ssd_scan(x, dt, A, D, Bm, Cm, *, chunk: int = 256, nheads: int,
             interpret: bool = False):
    """x [BH,S,P]; dt [BH,S]; A,D [BH,1]; Bm,Cm [BG,S,N] with
    BG = BH/ (heads per group).  Returns y [BH,S,P] (fp32-accurate)."""
    BH, S, P = x.shape
    BG, _, N = Bm.shape
    hg = BH // BG                     # heads per (batch x group) row
    q = min(chunk, S)
    assert S % q == 0
    n_c = S // q

    kernel = functools.partial(_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_c),
        in_specs=[
            pl.BlockSpec((1, q, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, q), lambda h, c: (h, c)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, c: (h, 0)),
            pl.BlockSpec((1, q, N), lambda h, c: (h // hg, c, 0)),
            pl.BlockSpec((1, q, N), lambda h, c: (h // hg, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, D, Bm, Cm)
