"""Deterministic fault injection for the search runtime.

The search pool's resilience features (task retry, pool healing, journal
resume, deadlines, device-replay fallback -- see core/search_pool.py) are
only trustworthy if every failure path can be exercised *reproducibly*:
a chaos test that kills a worker "sometimes" proves nothing.  This module
is the one injector behind all of them, replacing the ad-hoc
``_TEST_FAIL_HOOK`` string flag the pool tests used before.

Design
------
* **Events are keyed by task identity, not call order.**  Worker/task
  scheduling is nondeterministic, so an injector that fires "on the 3rd
  call" would fire on a different task every run.  Instead every
  injection site passes a stable key (the sub-space prefix tuple, the
  descent start, ...) and the event for ``(site, key)`` is a pure
  function of the seed: ``sha256(seed | site | key)`` drawn against the
  configured probabilities.  The same seed therefore produces the same
  faults on the same search regardless of worker count or scheduling.
* **Faults fire on bounded attempts.**  A killed task is re-dispatched
  by the driver with an incremented attempt number; by default an event
  fires only while ``attempt < max_attempt`` (default 1), so the retry
  succeeds and bit-identity of the final result can be asserted.  Tests
  of the exhausted-retries path set ``max_attempt`` high enough that
  every retry dies too.
* **Composable and fork-inherited.**  ``install()`` puts an injector in
  a module global; ``fork``-started pool workers inherit it, which is
  how parent-configured schedules reach worker processes (the same
  mechanism the old ``_TEST_FAIL_HOOK`` relied on).  Explicit
  ``events={(site, key): ChaosEvent(...)}`` entries override the seeded
  draw, so tests can pin one surgical fault while fuzz runs stay fully
  seeded.

Actions
-------
``"raise"``  raises :class:`ChaosError` (marked ``transient=True`` --
the driver retries it with bounded attempts, unlike real worker
exceptions which propagate unchanged); ``"kill"`` hard-exits the worker
process (``os._exit``), which breaks the whole ``ProcessPoolExecutor``
and exercises pool healing; ``"delay"`` sleeps ``delay_s`` before the
task body, which exercises deadlines and straggler re-dispatch;
``"hold"`` blocks on a fork-inherited gate until the test releases it
(:meth:`ChaosInjector.hold`), which exercises the same straggler paths
*deterministically* -- a wall-clock ``delay`` races the deadline timer
under load, a held gate cannot.
"""
from __future__ import annotations

import hashlib
import multiprocessing as _mp
import os
import time
from dataclasses import dataclass, field

ACTIONS = ("raise", "kill", "delay", "hold")


class ChaosError(RuntimeError):
    """Injected worker failure.  ``transient = True`` marks it as
    retryable to the dispatch loop -- the one exception class the driver
    re-dispatches instead of propagating (real worker exceptions are
    deterministic and would fail identically on retry)."""

    transient = True


@dataclass(frozen=True)
class ChaosEvent:
    """One planned fault: what to do, and until which attempt."""

    action: str                 # "raise" | "kill" | "delay" | "hold"
    delay_s: float = 0.05      # sleep length for "delay"
    max_attempt: int = 1       # fire while attempt < max_attempt
    gate: object = None        # mp.Event for "hold" (fork-inherited)

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown chaos action {self.action!r}")
        if self.action == "hold" and self.gate is None:
            raise ValueError("hold events need a gate "
                             "(use ChaosInjector.hold)")


def _unit(seed: int, site: str, key) -> float:
    """Deterministic draw in [0, 1) from (seed, site, key)."""
    h = hashlib.sha256(f"{seed}|{site}|{key!r}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


@dataclass
class ChaosInjector:
    """Seeded, composable fault schedule.

    ``p_kill`` / ``p_raise`` / ``p_delay`` are per-(site, key) fault
    probabilities drawn deterministically from ``seed``; ``events`` pins
    explicit faults that take precedence over the seeded draw.  The
    injector only decides and acts -- it never tracks state, so it is
    safe to inherit across ``fork`` and to consult concurrently.
    """

    seed: int = 0
    p_kill: float = 0.0
    p_raise: float = 0.0
    p_delay: float = 0.0
    delay_s: float = 0.05
    max_attempt: int = 1
    events: dict = field(default_factory=dict)   # (site, key) -> ChaosEvent
    fired: list = field(default_factory=list)    # log, per process

    def event_for(self, site: str, key) -> ChaosEvent | None:
        """The fault planned for this (site, key), or None.  Pure."""
        ev = self.events.get((site, key))
        if ev is not None:
            return ev
        u = _unit(self.seed, site, key)
        if u < self.p_kill:
            return ChaosEvent("kill", max_attempt=self.max_attempt)
        if u < self.p_kill + self.p_raise:
            return ChaosEvent("raise", max_attempt=self.max_attempt)
        if u < self.p_kill + self.p_raise + self.p_delay:
            return ChaosEvent("delay", delay_s=self.delay_s,
                              max_attempt=self.max_attempt)
        return None

    def hold(self, site: str, key, max_attempt: int = 1):
        """Pin a ``"hold"`` fault at (site, key) and return its release.

        The first ``max_attempt`` attempts of that task block on a
        fork-inherited :class:`multiprocessing.Event` until the returned
        zero-argument callable is invoked, giving tests a *deterministic*
        straggler: the held attempt provably overruns any deadline while
        the duplicate (attempt >= max_attempt) runs unimpeded.  Call the
        release before the pool shuts down, or ``close()`` will join the
        blocked worker forever.
        """
        gate = _mp.get_context("fork" if "fork" in
                               _mp.get_all_start_methods()
                               else None).Event()
        self.events[(site, key)] = ChaosEvent("hold", gate=gate,
                                              max_attempt=max_attempt)
        return gate.set

    def fire(self, site: str, key, attempt: int = 0) -> None:
        """Act on the planned fault for (site, key), if any is due."""
        ev = self.event_for(site, key)
        if ev is None or attempt >= ev.max_attempt:
            return
        self.fired.append((site, key, attempt, ev.action))
        if ev.action == "hold":
            ev.gate.wait()
        elif ev.action == "delay":
            time.sleep(ev.delay_s)
        elif ev.action == "raise":
            raise ChaosError(
                f"chaos: injected failure at {site}:{key!r} "
                f"(attempt {attempt})")
        elif ev.action == "kill":
            os._exit(3)


# ------------------------------------------------------- process-global hook
# The installed injector; fork-started pool workers inherit it from the
# parent, which is how a test/benchmark schedule reaches worker processes.
_INJECTOR: ChaosInjector | None = None


def install(injector: ChaosInjector) -> ChaosInjector:
    global _INJECTOR
    _INJECTOR = injector
    return injector


def uninstall() -> None:
    global _INJECTOR
    _INJECTOR = None


def active() -> ChaosInjector | None:
    return _INJECTOR


def maybe_fire(site: str, key, attempt: int = 0) -> None:
    """Injection-site entry point: a no-op unless an injector is
    installed (the production fast path is one global read)."""
    if _INJECTOR is not None:
        _INJECTOR.fire(site, key, attempt)
