"""Fault tolerance: preemption handling, restart-from-latest, straggler
mitigation hooks.

Designed for 1000+ node fleets where *something* is always failing:
  * PreemptionGuard -- SIGTERM/SIGINT flips a flag; the train loop
    checkpoints at the next step boundary and exits cleanly (atomic commit
    is checkpoint/checkpoint.py's job).
  * resume_or_init -- restart-from-latest: restores params/opt/data-step
    from the newest COMMITTED checkpoint, fast-forwards the deterministic
    data pipeline, and re-shards onto the *current* mesh (elastic: a
    restarted job may come back with a different pod count).
  * StragglerMonitor -- per-step wall-time EWMA; steps slower than
    `threshold x` median flag the host; the documented mitigation at scale
    is (1) hot-spare replacement via elastic restore, (2) within-job, the
    synchronous collectives make per-host skipping unsound, so mitigation
    is node replacement, not step skipping.
"""
from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field

from repro.checkpoint.checkpoint import latest_step, restore


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._installed = False
        self._signals = signals

    def install(self) -> "PreemptionGuard":
        for s in self._signals:
            try:
                signal.signal(s, self._handler)
            except ValueError:
                pass                        # non-main thread (tests)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:              # for tests / manual drain
        self._requested = True


def resume_or_init(ckpt_dir, abstract_state, shardings, init_fn,
                   pipeline=None):
    """Returns (state, start_step).  `abstract_state` is the eval_shape of
    the full train state; `init_fn()` builds it fresh when no checkpoint
    exists."""
    step = latest_step(ckpt_dir)
    if step is None:
        return init_fn(), 0
    state = restore(abstract_state, ckpt_dir, step, shardings)
    if pipeline is not None:
        pipeline.fast_forward(step)
    return state, step


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 2.0
    times: deque = field(default_factory=lambda: deque(maxlen=256))
    flagged_steps: list = field(default_factory=list)
    _t0: float | None = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        """Returns True if this step was a straggler."""
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        if len(self.times) < 10:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        if dt > self.threshold * med:
            self.flagged_steps.append((step, dt, med))
            return True
        return False

    @property
    def median_s(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]
