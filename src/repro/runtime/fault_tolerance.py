"""Fault tolerance: preemption handling, restart-from-latest, straggler
mitigation hooks.

Designed for 1000+ node fleets where *something* is always failing:
  * PreemptionGuard -- SIGTERM/SIGINT flips a flag; the train loop
    checkpoints at the next step boundary and exits cleanly (atomic commit
    is checkpoint/checkpoint.py's job).  The compiler's search pool uses
    the same guard for clean drain of in-flight sub-space tasks
    (core/search_pool.py): completed tasks are journaled, the pool stops
    dispatching, and the compile resumes from the task journal.
  * resume_or_init -- restart-from-latest: restores params/opt/data-step
    from the newest COMMITTED checkpoint, fast-forwards the deterministic
    data pipeline, and re-shards onto the *current* mesh (elastic: a
    restarted job may come back with a different pod count).
  * StragglerMonitor -- per-step wall-time statistics at two grains: the
    windowed median (train-loop steps: steps slower than `threshold x`
    median flag the host) and an EWMA (`observe`/`straggler_after`), which
    the search pool uses at *task* grain to derive speculative re-dispatch
    deadlines.  The documented mitigation at scale is (1) hot-spare
    replacement via elastic restore, (2) within-job, the synchronous
    collectives make per-host skipping unsound, so mitigation is node
    replacement, not step skipping -- except for the search pool's pure
    tasks, where duplicating a straggler is always sound.
"""
from __future__ import annotations

import signal
import time
from collections import deque
from dataclasses import dataclass, field


class PreemptionGuard:
    """Latches SIGTERM/SIGINT into a ``preempted`` flag.

    ``install()`` saves the previous handlers so ``uninstall()`` can put
    them back -- a guard created for one search/train loop must not leak
    into test processes or forked pool workers for the rest of their
    lives.  Usable as a context manager for exactly that pairing.
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._requested = False
        self._installed = False
        self._signals = signals
        self._previous: dict = {}

    def install(self) -> "PreemptionGuard":
        for s in self._signals:
            try:
                self._previous[s] = signal.signal(s, self._handler)
            except ValueError:
                pass                        # non-main thread (tests)
        self._installed = True
        return self

    def uninstall(self) -> None:
        """Restore the signal handlers ``install()`` displaced."""
        for s, prev in self._previous.items():
            try:
                signal.signal(s, prev)
            except ValueError:
                pass                        # non-main thread (tests)
        self._previous.clear()
        self._installed = False

    def __enter__(self) -> "PreemptionGuard":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def _handler(self, signum, frame):
        self._requested = True

    @property
    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:              # for tests / manual drain
        self._requested = True


def resume_or_init(ckpt_dir, abstract_state, shardings, init_fn,
                   pipeline=None):
    """Returns (state, start_step).  `abstract_state` is the eval_shape of
    the full train state; `init_fn()` builds it fresh when no checkpoint
    exists."""
    # lazy: checkpoint.py pulls in jax/msgpack, which PreemptionGuard and
    # StragglerMonitor users (e.g. the compiler's search pool) don't need
    from repro.checkpoint.checkpoint import latest_step, restore

    step = latest_step(ckpt_dir)
    if step is None:
        return init_fn(), 0
    state = restore(abstract_state, ckpt_dir, step, shardings)
    if pipeline is not None:
        pipeline.fast_forward(step)
    return state, step


@dataclass
class StragglerMonitor:
    """Wall-time statistics with two consumers:

    * train loops call ``step_start``/``step_end`` and get the windowed
      median-based straggler flag (``threshold x`` median);
    * the search pool calls ``observe(dt)`` per completed task and
      ``straggler_after()`` for an EWMA-based speculative-dispatch
      deadline (None until ``min_samples`` tasks have been observed).
    """

    window: int = 50
    threshold: float = 2.0
    alpha: float = 0.2            # EWMA smoothing factor for task grain
    min_samples: int = 5          # EWMA warm-up before deadlines are drawn
    times: deque = field(default_factory=deque)
    flagged_steps: list = field(default_factory=list)
    _t0: float | None = None
    _ewma: float | None = None
    _observed: int = 0

    def __post_init__(self):
        # honor the window field: the deque really is the window
        self.times = deque(self.times, maxlen=self.window)

    def observe(self, dt: float) -> None:
        """Record one duration (a step or a task wall time)."""
        self.times.append(dt)
        self._observed += 1
        self._ewma = dt if self._ewma is None \
            else self.alpha * dt + (1 - self.alpha) * self._ewma

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        """Returns True if this step was a straggler.  A ``step_end``
        without a matching ``step_start`` records nothing and returns
        False (it used to crash with TypeError on ``None`` arithmetic)."""
        if self._t0 is None:
            return False
        dt = time.monotonic() - self._t0
        self._t0 = None
        self.observe(dt)
        if len(self.times) < 10:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        if dt > self.threshold * med:
            self.flagged_steps.append((step, dt, med))
            return True
        return False

    def straggler_after(self) -> float | None:
        """Duration beyond which a task counts as a straggler (EWMA x
        threshold), or None while the EWMA is still warming up."""
        if self._observed < self.min_samples or self._ewma is None:
            return None
        return self.threshold * self._ewma

    @property
    def median_s(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]
