"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].

Deviation noted in DESIGN.md: Moonlight's first layer is dense and it adds
shared experts (DeepSeek-V3 lineage); we model a uniform 64e top-6 stack as
the assignment specifies.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, head_dim=128,
    pattern=("global",), act="silu", tie_embeddings=True,
    n_experts=64, top_k=6,
    source="hf:moonshotai/Moonlight-16B-A3B")
