"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer;
vision tower is a STUB: input_specs() provides precomputed patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, head_dim=128,
    # 4 self-attention layers then 1 cross-attention (image) layer.
    pattern=("global", "global", "global", "global", "cross"),
    act="silu", tie_embeddings=False, vision_seq=1600,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision")
