"""Architecture registry: the 10 assigned configs + reduced smoke variants
+ the paper's own CNN workloads (see repro.cnn.zoo)."""
from __future__ import annotations

from repro.configs.base import SHAPES, ModelConfig, ShapeCell  # noqa: F401
from repro.configs.gemma2_2b import CONFIG as _gemma2_2b
from repro.configs.gemma2_27b import CONFIG as _gemma2_27b
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.llama_3p2_vision_11b import CONFIG as _llama_vis
from repro.configs.mamba2_2p7b import CONFIG as _mamba2
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.qwen3_moe_235b_a22b import CONFIG as _qwen3
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.whisper_base import CONFIG as _whisper

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _smollm, _gemma2_2b, _gemma2_27b, _granite, _moonshot,
        _qwen3, _mamba2, _whisper, _llama_vis, _rgemma]
}

# Archs whose stacks are fully sub-quadratic (long_500k eligible).
SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-2b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers (one full
    pattern cycle + remainder), narrow width, tiny vocab/experts."""
    c = get_config(name)
    p = c.pattern_len
    kw = dict(
        name=c.name + "-smoke",
        n_layers=max(p + 1, 2) if c.family != "vlm" else 2 * p,
        d_model=64,
        n_heads=4 if c.n_heads else 0,
        n_kv_heads=min(2, c.n_kv_heads) if c.n_kv_heads else 0,
        head_dim=16 if c.n_heads else 0,
        d_ff=128 if c.d_ff else 0,
        vocab=512,
        window=16,
        max_seq=64,
        enc_seq=24 if c.family == "audio" else c.enc_seq,
        vision_seq=8 if c.family == "vlm" else c.vision_seq,
        lru_width=64 if c.lru_width else 0,
        dtype="float32",          # CPU smoke tests check numerics
    )
    if c.n_experts:
        # high capacity factor: no token drops, so prefill-vs-decode
        # consistency tests see identical routing
        kw.update(n_experts=8, top_k=2, capacity_factor=8.0)
    if c.family == "ssm":
        kw.update(ssm_state=16, ssm_headdim=8, ssm_chunk=8)
    if c.family == "audio":
        kw.update(enc_layers=2)
    return c.replace(**kw)


def valid_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch x shape) cells minus documented skips."""
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in SUBQUADRATIC:
                continue        # full attention: documented skip
            cells.append((arch, shape))
    return cells


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCHS for s in SHAPES]
