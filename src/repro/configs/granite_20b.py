"""granite-20b [dense] — llama-arch MQA (kv=1), code model
[arXiv:2405.04324; hf].

Deviation noted in DESIGN.md: the HF checkpoint uses learned absolute
positions (gpt-bigcode lineage); we use RoPE like the rest of the dense
family -- systems behaviour (shapes, traffic, collectives) is identical.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    pattern=("global",), act="gelu", tie_embeddings=True,
    mlp_gated=False,                  # gpt-bigcode 2-matrix MLP
    source="arXiv:2405.04324")
