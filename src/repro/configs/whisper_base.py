"""whisper-base [audio] — enc-dec; conv frontend is a STUB: input_specs()
provides precomputed frame embeddings [B, enc_seq, d] [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    pattern=("global",), act="gelu", tie_embeddings=True,
    enc_layers=6, enc_seq=1500,
    source="arXiv:2212.04356")
