"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-*; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    pattern=("global",), act="silu", tie_embeddings=False,
    qk_norm=True,
    n_experts=128, top_k=8,
    source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)")
