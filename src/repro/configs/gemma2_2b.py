"""gemma2-2b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0, sandwich_norm=True,
    act="gelu", tie_embeddings=True,
    source="arXiv:2408.00118")
