"""Model configuration schema for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- attention structure -------------------------------------------
    # layer-kind pattern cycled over depth:
    #   'global' | 'local' | 'recurrent' | 'cross'
    pattern: tuple[str, ...] = ("global",)
    window: int = 4096                # local-attention window
    attn_softcap: float = 0.0         # 0 disables (gemma2: 50)
    final_softcap: float = 0.0        # gemma2: 30
    sandwich_norm: bool = False       # gemma2 pre+post norm
    act: str = "silu"                 # silu (SwiGLU) | gelu (GeGLU)
    mlp_gated: bool = True            # False: plain 2-matrix MLP (granite)
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    qk_norm: bool = False             # qwen3-style
    # --- MoE ------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (Mamba-2 SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256
    # --- RG-LRU (hybrid) --------------------------------------------------
    lru_width: int = 0                # 0 -> d_model
    # --- encoder-decoder / multimodal stubs -------------------------------
    enc_layers: int = 0               # whisper encoder depth
    enc_seq: int = 1500               # precomputed frame embeddings length
    vision_seq: int = 1600            # precomputed patch embeddings length
    # --- bookkeeping ------------------------------------------------------
    max_seq: int = 8192               # overridden by shape cells
    dtype: str = "bfloat16"
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:         # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def pattern_len(self) -> int:
        return len(self.pattern)

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % len(self.pattern)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------ counting
    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.hd, self.n_heads, self.n_kv_heads
        total = v * d                                   # embeddings
        if not self.tie_embeddings:
            total += v * d
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "recurrent":
                w = self.lru_width or d
                total += 2 * d * w + 2 * w + self.conv_width * w + w * d \
                    + 2 * w * (w // 8)                   # rg-lru gates (block-diag 8)
            elif self.family == "ssm":
                di, g, s = self.d_inner, self.ssm_ngroups, self.ssm_state
                total += d * (2 * di + 2 * g * s + self.ssm_nheads) \
                    + self.conv_width * (di + 2 * g * s) + di * d \
                    + 2 * self.ssm_nheads
            else:
                total += d * hd * (nh + 2 * nkv) + nh * hd * d   # attention
            n_mats = 3 if self.mlp_gated else 2
            if self.family == "ssm" and kind != "recurrent":
                pass                                     # no FFN in mamba2
            elif self.n_experts and kind != "cross":
                total += self.n_experts * n_mats * d * ff  # expert FFNs
                total += d * self.n_experts              # router
            else:
                total += n_mats * d * ff
            total += 2 * d                               # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_gated else 2
        dense = (self.param_count()
                 - self.n_layers * self.n_experts * n_mats * d * ff)
        return dense + self.n_layers * self.top_k * n_mats * d * ff


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    mode: str            # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
