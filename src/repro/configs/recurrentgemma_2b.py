"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1 attn per 2 recurrent
[arXiv:2402.19427]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    pattern=("recurrent", "recurrent", "local"), window=2048,
    act="gelu", tie_embeddings=True, lru_width=2560,
    source="arXiv:2402.19427")
