"""Analytic per-cell FLOPs / HBM-bytes model.

XLA's ``cost_analysis()`` on the CPU backend counts every while-loop body
exactly once (scan-over-layers, blocked-attention KV chunks, loss chunks),
so its raw numbers understate per-step work by ~the trip counts.  The
roofline therefore uses this exact analytic accounting of the einsums the
model code performs (the formulas mirror models/*.py one-to-one), while the
HLO numbers are reported alongside as structural evidence.

All results are GLOBAL per optimizer/serving step; divide by chip count for
per-device roofline terms (valid because batch/heads/experts are sharded).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeCell


@dataclass
class CellCost:
    flops: float               # global FLOPs per step
    weight_bytes: float        # parameter traffic per device-visible step
    act_bytes: float           # activation/KV HBM traffic (global)
    notes: str = ""

    def per_device(self, chips: int) -> tuple[float, float]:
        return self.flops / chips, (self.weight_bytes + self.act_bytes) / chips


def _attn_layer_flops(cfg: ModelConfig, S: int, kv_len: float,
                      window: int = 0) -> float:
    """Per-sequence FLOPs of one self-attention layer over S new tokens."""
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj = 2 * S * d * (nh + 2 * nkv) * hd + 2 * S * nh * hd * d
    eff = min(window, kv_len) if window else kv_len
    attn = 2 * 2 * S * eff * nh * hd        # QK^T + PV
    return proj + attn


def _ffn_flops(cfg: ModelConfig, S: int) -> float:
    n_mats = 3 if cfg.mlp_gated else 2
    if cfg.n_experts:
        return S * (2 * cfg.d_model * cfg.n_experts          # router
                    + cfg.top_k * n_mats * 2 * cfg.d_model * cfg.d_ff
                    * cfg.capacity_factor)
    return S * n_mats * 2 * cfg.d_model * cfg.d_ff


def _ssm_layer_flops(cfg: ModelConfig, S: int, decode: bool) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, nh, p = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    proj = 2 * S * d * (2 * di + 2 * g * n + nh) + 2 * S * di * d
    conv = 2 * S * cfg.conv_width * (di + 2 * g * n)
    if decode:
        scan = S * (4 * nh * p * n)                       # state update + C.h
    else:
        Q = cfg.ssm_chunk
        # intra-chunk scores/apply + state build + inter-chunk apply
        scan = S * (2 * Q * g * n + 2 * Q * nh * p + 8 * nh * p * n)
    return proj + conv + scan


def _rglru_layer_flops(cfg: ModelConfig, S: int) -> float:
    d = cfg.d_model
    w = cfg.lru_width or d
    proj = 2 * S * d * w * 2 + 2 * S * w * d
    gates = 2 * 2 * S * w * (w // 8)
    conv = 2 * S * cfg.conv_width * w
    scan = 8 * S * w
    return proj + gates + conv + scan


def _cross_layer_flops(cfg: ModelConfig, S: int, ctx: int,
                       kv_fresh: bool) -> float:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = 2 * S * d * nh * hd + 2 * S * nh * hd * d
    kv = 2 * ctx * d * 2 * nkv * hd if kv_fresh else 0
    attn = 2 * 2 * S * ctx * nh * hd
    return q + kv + attn


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "audio":
        return ["encdec"] * cfg.n_layers
    return [cfg.layer_kind(i) for i in range(cfg.n_layers)]


def forward_flops(cfg: ModelConfig, S: int, kv_len: float, mode: str) -> float:
    """Global forward FLOPs for ONE sequence processing S new tokens."""
    decode = mode == "decode"
    total = 0.0
    ctx_len = cfg.enc_seq if cfg.family == "audio" else cfg.vision_seq
    for kind in _layer_kinds(cfg):
        if kind == "ssm":
            total += _ssm_layer_flops(cfg, S, decode)
        elif kind == "recurrent":
            total += _rglru_layer_flops(cfg, S) + _ffn_flops(cfg, S)
        elif kind == "cross":
            total += _cross_layer_flops(cfg, S, ctx_len, mode != "decode")
            total += _ffn_flops(cfg, S)
        elif kind == "encdec":
            total += _attn_layer_flops(cfg, S, kv_len)
            total += _cross_layer_flops(cfg, S, ctx_len, mode != "decode")
            total += _ffn_flops(cfg, S)
        else:
            win = cfg.window if kind == "local" else 0
            total += _attn_layer_flops(cfg, S, kv_len, win) \
                + _ffn_flops(cfg, S)
    # whisper encoder
    if cfg.family == "audio" and mode != "decode":
        for _ in range(cfg.enc_layers):
            total += _attn_layer_flops(cfg, ctx_len, ctx_len / 2) \
                + _ffn_flops(cfg, ctx_len)
    return total


def unembed_flops(cfg: ModelConfig, tokens: float) -> float:
    return 2 * tokens * cfg.d_model * cfg.vocab


def attention_fraction(cfg: ModelConfig, S: int, kv_len: float,
                       mode: str) -> float:
    """Fraction of forward FLOPs in (head-sharded-able) attention --
    used to attribute hybrid-plan compute between the batch-parallel
    attention and the ff-TP MLP."""
    total = forward_flops(cfg, S, kv_len, mode)
    if not total:
        return 0.0
    attn = 0.0
    for kind in _layer_kinds(cfg):
        if kind in ("global", "local", "encdec"):
            win = cfg.window if kind == "local" else 0
            attn += _attn_layer_flops(cfg, S, kv_len, win)
    return attn / total


def cell_cost(cfg: ModelConfig, cell: ShapeCell, chips: int,
              remat: str = "full", dtype_bytes: int = 2) -> CellCost:
    B, S = cell.global_batch, cell.seq_len
    param_bytes_total = cfg.param_count() * dtype_bytes
    d = cfg.d_model

    if cell.mode == "train":
        fwd = B * forward_flops(cfg, S, (S + 1) / 2, "train") \
            + unembed_flops(cfg, B * S) \
            + B * S * 2 * d * cfg.vocab          # gather/grad of embedding
        factor = 4.0 if remat == "full" else 3.0
        flops = fwd * factor
        # traffic: fp32 params read (fwd+bwd) + grads written + AdamW m/v
        # read+write + param write  (per model-replica, i.e. global bytes
        # = per-device bytes * chips when fully sharded)
        p32 = cfg.param_count() * 4
        weight_traffic = p32 * (2 + 1 + 4 + 1) * 1.0
        # layer-boundary activations saved + reread under full remat
        layers = cfg.n_layers
        act = 2 * layers * B * S * d * dtype_bytes * (2 if remat == "full"
                                                      else 3)
        return CellCost(flops, weight_traffic, act,
                        notes=f"remat={remat} factor={factor}")

    if cell.mode == "prefill":
        flops = B * forward_flops(cfg, S, (S + 1) / 2, "prefill") \
            + unembed_flops(cfg, B)              # last-position logits
        act = 2 * cfg.n_layers * B * S * d * dtype_bytes
        kv_write = _kv_bytes(cfg, B, S, dtype_bytes)
        return CellCost(flops, param_bytes_total, act + kv_write)

    # decode: one token per sequence, full KV/state read per layer
    flops = B * forward_flops(cfg, 1, S, "decode") + unembed_flops(cfg, B)
    kv_read = _kv_bytes(cfg, B, S, dtype_bytes)
    act = 4 * cfg.n_layers * B * d * dtype_bytes
    return CellCost(flops, param_bytes_total, kv_read + act)


def _kv_bytes(cfg: ModelConfig, B: int, S: int, dtype_bytes: int) -> float:
    """Total KV-cache / recurrent-state bytes for the whole stack."""
    total = 0.0
    for kind in _layer_kinds(cfg):
        if kind == "ssm":
            total += B * (cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4
                          + (cfg.conv_width - 1)
                          * (cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state)
                          * dtype_bytes)
        elif kind == "recurrent":
            w = cfg.lru_width or cfg.d_model
            total += B * (w * 4 + (cfg.conv_width - 1) * w * dtype_bytes)
        elif kind == "cross":
            total += 2 * B * cfg.vision_seq * cfg.n_kv_heads * cfg.hd \
                * dtype_bytes
        else:
            eff = min(S, cfg.window) if kind == "local" else S
            total += 2 * B * eff * cfg.n_kv_heads * cfg.hd * dtype_bytes
            if kind == "encdec":
                total += 2 * B * cfg.enc_seq * cfg.n_kv_heads * cfg.hd \
                    * dtype_bytes
    return total
