"""HLO-text analysis: collective-traffic accounting for the roofline.

`cost_analysis()` does not expose collective bytes (and counts while-loop
bodies exactly once), so we parse the compiled HLO text ourselves:

 1. split the module into computations;
 2. recover each while loop's trip count from its condition computation
    (jax scans lower to `iter < C` -- we take the max integer constant) or
    from a `known_trip_count={n:N}` annotation when XLA provides one;
 3. propagate execution multipliers through the call graph
    (body/condition/to_apply/calls edges);
 4. sum each collective op's *result-segment* bytes (operands are printed
    as bare %names in optimized HLO; for all-reduce result==operand, for
    all-gather the result size ~= bytes moved through the links) weighted
    by its computation's multiplier.
"""
from __future__ import annotations

import re
from collections import Counter, defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_SHAPE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([0-9,]*)\]")
_OPNAME = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_WHILE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|condition|body|branch_computations)="
                    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 0)


@dataclass
class CollectiveReport:
    bytes_by_kind: Counter = field(default_factory=Counter)
    count_by_kind: Counter = field(default_factory=Counter)
    static_count: Counter = field(default_factory=Counter)
    trip_counts: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.bytes_by_kind.values()))

    def summary(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "bytes": {k: int(v) for k, v in self.bytes_by_kind.items()},
                "dynamic_count": {k: int(v) for k, v
                                  in self.count_by_kind.items()},
                "static_count": dict(self.static_count),
                "while_trip_counts": dict(self.trip_counts)}


def split_computations(text: str) -> dict[str, list[str]]:
    """Computation headers start at column 0 with '%name (' or
    'ENTRY %name (' and end with '{' (params may contain nested parens)."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in text.splitlines():
        if (line.startswith("%") or line.startswith("ENTRY")) \
                and line.rstrip().endswith("{"):
            m = _COMP_HDR.match(line)
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line[len("ENTRY"):].strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _line_result_bytes(line: str, opname_match: re.Match) -> int:
    """Sum shapes between '=' and the op name (the result segment)."""
    eq = line.find("=")
    if eq < 0:
        return 0
    seg = line[eq:opname_match.start() + 1]
    total = sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(seg))
    if "-start(" in line:
        # async start ops carry (operand, result) tuples; halve
        total //= 2
    return total


def _trip_count(cond_lines: list[str], while_line: str) -> int:
    m = _TRIP.search(while_line)
    if m:
        return int(m.group(1))
    best = 1
    for line in cond_lines:
        for c in _CONST_INT.findall(line):
            best = max(best, int(c))
    return best


def parse_collectives(hlo_text: str) -> CollectiveReport:
    comps = split_computations(hlo_text)
    rep = CollectiveReport()

    # ---- call-graph edges with per-edge multipliers
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = _trip_count(comps.get(cond, []), line)
                rep.trip_counts[body] = trip
                edges[name].append((body, trip))
                edges[name].append((cond, trip + 1))
                continue
            for m in _CALLS.finditer(line):
                for callee in re.split(r",\s*", m.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        edges[name].append((callee, 1))

    # ---- multipliers from the entry computation (memoized recursion over
    # the reverse call graph; HLO computations cannot recurse)
    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.replace("ENTRY", "").strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        entry = next(iter(comps), None)
    rev: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for caller, outs in edges.items():
        for callee, k in outs:
            rev[callee].append((caller, k))

    memo: dict[str, int] = {}

    def multiplier(c: str, _depth=0) -> int:
        if c == entry:
            return 1
        if c in memo:
            return memo[c]
        if _depth > 200:
            return 1
        memo[c] = 0                       # cycle guard (shouldn't happen)
        total = sum(multiplier(caller, _depth + 1) * k
                    for caller, k in rev.get(c, []))
        memo[c] = total
        return total

    mult = {name: multiplier(name) for name in comps}

    # ---- collect collective bytes weighted by multiplier.  Physical link
    # traffic: an all-reduce moves ~2x its payload (reduce-scatter +
    # all-gather phases); the others ~1x ((n-1)/n ~= 1).
    phys = {"all-reduce": 2.0}
    for name, lines in comps.items():
        w = max(1, mult.get(name, 1))
        for line in lines:
            if "-done(" in line:
                continue
            m = _OPNAME.search(line)
            if not m:
                continue
            kind = m.group(1)
            b = _line_result_bytes(line, m) * phys.get(kind, 1.0)
            if "_promoted" in line or ("f32[" in line
                                       and "(%convert" in line):
                # XLA-CPU artifact: the CPU float-normalization pass
                # rewrites bf16 compute (and collectives) as
                # convert->f32-op->convert; a TPU moves bf16, so halve.
                # Detected via the promoted reducer name or a convert-
                # producing operand.
                b /= 2
            rep.bytes_by_kind[kind] += int(b) * w
            rep.count_by_kind[kind] += w
            rep.static_count[kind] += 1
    return rep
