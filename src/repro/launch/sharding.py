"""Per-architecture sharding plans: logical-axis -> mesh-axis rules plus
batch placement, derived from divisibility against the production mesh.

Parallelism map (DP/FSDP/TP/EP):
  TP plan (default): heads/kv/ff/vocab -> 'model' where the dimension
    divides the axis; experts -> 'model' (EP); batch -> ('pod','data');
    'embed' -> 'data' (FSDP) when a replicated copy would not fit.
  DP plan (small models / head counts indivisible by the model axis, e.g.
    smollm's 15 heads): batch additionally spreads over 'model', all
    activations replicated nowhere, params FSDP-sharded over 'model'.

The 'pod' axis always carries pure data parallelism: only gradient
all-reduces cross the inter-pod links.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP_THRESHOLD_BYTES = 1.5e9     # replicated fp32 params per model-shard


@dataclass(frozen=True)
class ShardingPlan:
    kind: str                     # 'tp' | 'dp'
    rules: dict
    batch_axis_pref: tuple        # candidate batch axis tuples, best first
    fsdp: bool

    def batch_spec(self, mesh, global_batch: int) -> P:
        avail = set(mesh.axis_names)
        for cand in self.batch_axis_pref:
            axes = tuple(a for a in cand if a in avail)
            if not axes:
                continue
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            if global_batch % n == 0:
                return P(axes if len(axes) > 1 else axes[0])
        return P()


def _div(n: int, k: int) -> bool:
    return n > 0 and n % k == 0


def make_plan(cfg: ModelConfig, mesh) -> ShardingPlan:
    model_n = mesh.shape.get("model", 1)
    heads_ok = _div(cfg.n_heads, model_n)
    attn_free = cfg.family == "ssm"
    param_bytes = cfg.param_count() * 4

    if not heads_ok and not attn_free:
        # HYBRID plan: attention cannot be head-sharded (8/10/15 heads on
        # a 16-way axis), but the MLP can still run Megatron ff-TP (d_ff
        # divides for every assigned arch) and the vocab shards for the
        # chunked cross-entropy.  Attention runs batch-parallel
        # (replicated over 'model'); the opt-in CP path
        # (attention.cp_attention) spreads prefill attention over the
        # model axis too.  An earlier pure-DP variant stored params
        # FSDP-style on the *contracting* dim, which made GSPMD all-reduce
        # the [B,S,d_ff] MLP intermediates (4.6 GiB/layer) instead of
        # gathering 40 MB of weights -- see EXPERIMENTS.md §Perf 2e.
        rules = {"embed": None,
                 "vocab": "model" if _div(cfg.vocab, model_n) else None,
                 "heads": None, "kv": None,
                 "ff": "model" if _div(cfg.d_ff, model_n) else None,
                 "experts": None, "layers": None, None: None}
        return ShardingPlan(
            kind="hybrid", rules=rules,
            batch_axis_pref=(("pod", "data"), ("data",), ()),
            fsdp=False)

    fsdp = param_bytes / model_n > FSDP_THRESHOLD_BYTES
    rules = {
        "vocab": "model" if _div(cfg.vocab, model_n) else None,
        "heads": "model" if heads_ok or attn_free else None,
        "kv": "model" if _div(cfg.n_kv_heads, model_n) else None,
        "ff": "model",
        "experts": "model" if _div(cfg.n_experts, model_n) else None,
        "embed": "data" if fsdp else None,
        "layers": None,
        None: None,
    }
    return ShardingPlan(
        kind="tp", rules=rules,
        batch_axis_pref=(("pod", "data"), ("data",), ()),
        fsdp=fsdp)


def needs_fsdp(cfg: ModelConfig, mesh) -> bool:
    return make_plan(cfg, mesh).fsdp


def param_pspecs(model, mesh, plan: ShardingPlan | None = None):
    plan = plan or make_plan(model.cfg, mesh)
    return model.pspecs(plan.rules)


def batch_pspecs(model, mesh, batch_spec: dict, global_batch: int,
                 plan: ShardingPlan | None = None) -> dict:
    plan = plan or make_plan(model.cfg, mesh)
    bp = plan.batch_spec(mesh, global_batch)
    out = {}
    for k, v in batch_spec.items():
        out[k] = P(*bp, *([None] * (len(v.shape) - 1)))
    return out


def cache_pspecs(cache_tree, mesh, global_batch: int,
                 plan: ShardingPlan) -> dict:
    """PartitionSpecs for a decode cache pytree, keyed by leaf names."""
    bp = plan.batch_spec(mesh, global_batch)
    b = tuple(bp)[0] if len(bp) else None
    kv_ax = plan.rules.get("kv")
    ff_ax = plan.rules.get("ff")
    head_ax = plan.rules.get("heads")

    def one(path, leaf):
        name = None
        stacked = False
        for p in path:
            if hasattr(p, "key"):
                if p.key == "groups":
                    stacked = True          # leading n_groups 'layers' dim
                name = p.key
        nd = len(leaf.shape)
        # When kv heads cannot shard on the model axis, shard the cache's
        # sequence dim instead (context-parallel cache): qwen3's 48 GiB/dev
        # decode cache drops to 3 GiB (EXPERIMENTS.md §Perf).  Skip when
        # the batch spec already consumes the model axis (dp plan with
        # batch spread over data x model) or the seq length does not
        # divide (whisper's 1500-frame cross KV).
        b_axes = set(b) if isinstance(b, tuple) else ({b} if b else set())
        model_n = mesh.shape.get("model", 1)
        seq_len = leaf.shape[2] if nd >= 4 and name in (
            "k", "v", "ck", "cv") and nd == 5 else (
            leaf.shape[1] if nd >= 2 else 0)
        seq_ax = "model" if (kv_ax is None and "model" not in b_axes
                             and seq_len % model_n == 0) else None
        base = {"k": (b, seq_ax, kv_ax, None),
                "v": (b, seq_ax, kv_ax, None),
                "ck": (b, seq_ax, kv_ax, None),
                "cv": (b, seq_ax, kv_ax, None),
                "convx": (b, None, ff_ax),
                "convbc": (b, None, ff_ax),
                "conv": (b, None, ff_ax),
                "ssd": (b, ff_ax, None, None),
                "h": (b, ff_ax)}.get(name)
        if base is None:
            return P(*([None] * nd))              # pos etc.
        if stacked and nd == len(base) + 1:
            return P(None, *base)
        assert nd == len(base), (name, leaf.shape)
        return P(*base)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def to_named(mesh, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def hidden_batch_axes(plan: ShardingPlan, mesh,
                      global_batch: int) -> tuple | None:
    bp = plan.batch_spec(mesh, global_batch)
    if len(bp) == 0:
        return None
    ax = tuple(bp)[0]
    return ax if isinstance(ax, tuple) else (ax,)
