import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers, compiles, and fits -- without hardware.

For each cell it lowers the real train/prefill/decode step with abstract
params/batch under the production mesh, compiles, and records:
  * memory_analysis()    -- per-device argument/output/temp bytes,
  * cost_analysis()      -- HLO FLOPs / bytes for the roofline,
  * collective bytes     -- parsed from the compiled HLO text,
  * analytic per-device shard bytes (params / optimizer / cache / batch).

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_config, valid_cells        # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.launch.sharding import (batch_pspecs, cache_pspecs,    # noqa: E402
                                   hidden_batch_axes, make_plan,
                                   param_pspecs, to_named)
from repro.launch.steps import (AdamWConfig, make_decode_step,    # noqa: E402
                                make_prefill_step, make_train_step)
from repro.models.model import build_model                        # noqa: E402
from repro.models.transformer import set_mesh_axes                # noqa: E402
from repro.utils.costmodel import cell_cost                       # noqa: E402
from repro.utils.hlo import parse_collectives                     # noqa: E402

# v5e constants (roofline terms; see DESIGN.md §8)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_BYTES = 16 * (1 << 30)


def shard_bytes(tree, shardings) -> int:
    """Analytic per-device bytes of a (abstract) array tree."""
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "shard_shape"))):
        shp = sh.shard_shape(leaf.shape) if hasattr(sh, "shard_shape") \
            else leaf.shape
        n = 1
        for d in shp:
            n *= d
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS: 6*N*D train, 2*N*D per generated/processed token."""
    n = cfg.active_param_count()
    if cell.mode == "train":
        tokens = cell.seq_len * cell.global_batch
        return 6.0 * n * tokens
    if cell.mode == "prefill":
        tokens = cell.seq_len * cell.global_batch
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch          # decode: one token/seq


def run_cell(arch: str, shape: str, multi_pod: bool = False,
             plan_override=None, remat: str = "full",
             cfg_override=None, seq_shard: bool = False,
             cp: bool = False) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    cfg = cfg.replace(max_seq=cell.seq_len)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    chips = mesh.size
    plan = plan_override or make_plan(cfg, mesh)

    set_mesh_axes(hidden_batch_axes(plan, mesh, cell.global_batch), "model",
                  mesh=mesh, seq_shard=seq_shard and plan.kind == "tp",
                  cp=cp)
    t0 = time.time()
    with mesh:
        pspecs = param_pspecs(model, mesh, plan)
        pshard = to_named(mesh, pspecs)
        bspec = model.batch_spec(cell.seq_len, cell.global_batch, cell.mode)
        bshard = to_named(mesh, batch_pspecs(model, mesh, bspec,
                                             cell.global_batch, plan))
        arg_bytes = {}

        if cell.mode == "train":
            params = model.abstract_params("float32")
            from repro.optim.adamw import init_opt_state
            opt = jax.eval_shape(init_opt_state, params)
            opt_shard = {"m": pshard, "v": pshard,
                         "step": to_named(mesh, jax.sharding.PartitionSpec())}
            step = make_train_step(model, AdamWConfig(), remat=remat)
            jitted = jax.jit(step,
                             in_shardings=(pshard, opt_shard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params, opt, bspec)
            arg_bytes = {
                "params": shard_bytes(params, pshard),
                "opt": shard_bytes(opt["m"], pshard) * 2,
                "batch": shard_bytes(bspec, bshard),
            }
        elif cell.mode == "prefill":
            params = model.abstract_params("bfloat16")
            cache = model.abstract_cache(cell.global_batch, cell.seq_len)
            cshard = to_named(mesh, cache_pspecs(cache, mesh,
                                                 cell.global_batch, plan))
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                             donate_argnums=(2,))
            lowered = jitted.lower(params, bspec, cache)
            arg_bytes = {
                "params": shard_bytes(params, pshard),
                "cache": shard_bytes(cache, cshard),
                "batch": shard_bytes(bspec, bshard),
            }
        else:                                    # decode
            params = model.abstract_params("bfloat16")
            cache = model.abstract_cache(cell.global_batch, cell.seq_len)
            cshard = to_named(mesh, cache_pspecs(cache, mesh,
                                                 cell.global_batch, plan))
            step = make_decode_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, cshard, bshard["tokens"]),
                donate_argnums=(1,))
            lowered = jitted.lower(params, cache, bspec["tokens"])
            arg_bytes = {
                "params": shard_bytes(params, pshard),
                "cache": shard_bytes(cache, cshard),
                "batch": shard_bytes(bspec, bshard),
            }

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):      # older jax: one dict per program
            cost = cost[0] if cost else {}
        coll = parse_collectives(compiled.as_text())

    flops = float((cost or {}).get("flops", 0.0))
    bytes_acc = float((cost or {}).get("bytes accessed", 0.0))
    mflops = model_flops(cfg, cell)
    # Analytic accounting (utils/costmodel.py): cost_analysis() counts
    # while bodies once, so the roofline terms come from the exact einsum
    # model; raw HLO numbers are reported alongside.
    ac = cell_cost(cfg, cell, chips, remat=remat)
    # traffic attribution: params divide by the number of distinct param
    # shards (model x data-FSDP), activations/KV by the batch shards.
    model_n = mesh.shape.get("model", 1)
    data_n = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    param_shards = model_n * (data_n if (plan.fsdp and plan.kind == "tp")
                              else 1)
    bspec_used = plan.batch_spec(mesh, cell.global_batch)
    batch_shards = 1
    if len(bspec_used):
        ax0 = tuple(bspec_used)[0]
        for a in (ax0 if isinstance(ax0, tuple) else (ax0,)):
            batch_shards *= mesh.shape[a]
    bytes_dev = ac.weight_bytes / param_shards + ac.act_bytes / batch_shards
    # FLOPs spread over the chips that actually compute.  TP plan: the
    # model axis participates everywhere.  Hybrid plan: the ff-TP MLP
    # spreads over all chips, the head-replicated attention only over the
    # batch shards (or all chips with CP prefill attention).
    if plan.kind == "tp":
        flops_dev = ac.flops / chips
    else:
        from repro.utils.costmodel import attention_fraction
        S_eff = 1 if cell.mode == "decode" else cell.seq_len
        af = attention_fraction(cfg, S_eff,
                                cell.seq_len if cell.mode == "decode"
                                else (cell.seq_len + 1) / 2, cell.mode)
        attn_shards = chips if (cp and cell.mode == "prefill") \
            else batch_shards
        flops_dev = ac.flops * (af / attn_shards + (1 - af) / chips)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll.total_bytes / ICI_BW
    step_s = max(compute_s, memory_s, collective_s)
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]

    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            try:
                mem_d[k] = int(getattr(mem, k))
            except Exception:
                pass

    result = {
        "arch": arch, "shape": shape,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "chips": chips, "mode": cell.mode,
        "plan": plan.kind, "fsdp": plan.fsdp, "seq_shard": seq_shard, "cp": cp,
        "remat": remat,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "collectives": coll.summary(),
        "memory_analysis": mem_d,
        "arg_bytes_per_device": arg_bytes,
        "total_arg_bytes_per_device": sum(arg_bytes.values()),
        "fits_hbm": sum(arg_bytes.values()) < HBM_BYTES,
        "model_flops_global": mflops,
        "model_flops_per_device": mflops / chips,
        "analytic_flops_per_device": flops_dev,
        "analytic_bytes_per_device": bytes_dev,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "step_s": step_s,
            "dominant": dominant,
            # MODEL_FLOPS / analytic HLO-equivalent flops: how much of the
            # compiled compute is "useful" (remat/dispatch overhead shows
            # up here)
            "useful_flops_frac": mflops / ac.flops if ac.flops else None,
            "mfu_bound": (mflops / chips / step_s) / PEAK_FLOPS
            if step_s else None,
        },
        "ok": True,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = valid_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'pod2' if mp else 'pod1'}"
            try:
                res = run_cell(arch, shape, multi_pod=mp,
                               remat=args.remat)
                r = res["roofline"]
                print(f"[OK] {tag}: dominant={r['dominant']} "
                      f"compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"collective={r['collective_s']:.4f}s "
                      f"args={res['total_arg_bytes_per_device'] / 2**30:.2f}"
                      f"GiB/dev fits={res['fits_hbm']}")
            except Exception as e:
                failures += 1
                res = {"arch": arch, "shape": shape, "ok": False,
                       "multi_pod": mp, "error": repr(e),
                       "trace": traceback.format_exc()[-2000:]}
                print(f"[FAIL] {tag}: {e!r}")
            (out_dir / f"{tag}.json").write_text(json.dumps(res, indent=1))
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("all requested cells compiled.")


if __name__ == "__main__":
    main()
