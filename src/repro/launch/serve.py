"""Serving driver: batched prefill + greedy decode against a standing KV
cache (continuous batched requests share one cache of max_seq slots)."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.sharding import (batch_pspecs, cache_pspecs,
                                   hidden_batch_axes, make_plan,
                                   param_pspecs, to_named)
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.model import build_model
from repro.models.transformer import set_mesh_axes


@dataclass
class ServeConfig:
    batch: int = 4
    prompt_len: int = 32
    gen_len: int = 32
    seed: int = 0


def serve(cfg: ModelConfig, sc: ServeConfig, mesh=None,
          params=None) -> dict:
    model = build_model(cfg)
    if mesh is None:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    plan = make_plan(cfg, mesh)
    max_len = sc.prompt_len + sc.gen_len
    cfg_run = cfg.replace(max_seq=max_len)
    model = build_model(cfg_run)

    set_mesh_axes(hidden_batch_axes(plan, mesh, sc.batch), "model",
                  mesh=mesh)
    with mesh:
        pshard = to_named(mesh, param_pspecs(model, mesh, plan))
        if params is None:
            params = jax.device_put(
                model.init(jax.random.key(sc.seed), cfg.dtype), pshard)
        prefill = jax.jit(make_prefill_step(model))
        decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

        rng = np.random.default_rng(sc.seed)
        prompts = rng.integers(0, cfg.vocab,
                               (sc.batch, sc.prompt_len)).astype(np.int32)
        cache = model.init_cache(sc.batch, max_len)
        cshard = to_named(mesh, cache_pspecs(cache, mesh, sc.batch, plan))
        cache = jax.device_put(cache, cshard)

        t0 = time.time()
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (sc.batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (sc.batch, cfg.vision_seq, cfg.d_model), jnp.bfloat16)
        logits, cache = prefill(params, batch, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t_prefill = time.time() - t0

        generated = [next_tok]
        t0 = time.time()
        for _ in range(sc.gen_len - 1):
            next_tok, logits, cache = decode(params, cache, next_tok)
            generated.append(next_tok)
        toks = np.concatenate([np.asarray(t) for t in generated], axis=1)
        t_decode = time.time() - t0
        return {
            "tokens": toks,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": sc.batch * (sc.gen_len - 1) / max(t_decode, 1e-9),
        }


def main() -> None:
    import argparse
    from repro.configs import get_config, smoke_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    out = serve(cfg, ServeConfig(batch=args.batch, prompt_len=args.prompt,
                                 gen_len=args.gen))
    print(f"prefill {out['prefill_s']:.2f}s, decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s), sample: {out['tokens'][0, :12]}")


if __name__ == "__main__":
    main()
