"""Training driver: data pipeline -> jitted train step -> checkpoint loop,
with preemption handling, restart-from-latest and straggler monitoring.

CPU-runnable end to end (examples/train_lm.py trains a ~100M model); the
same driver lowers unchanged onto the production mesh (launch/dryrun.py
proves every cell compiles).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch.sharding import (batch_pspecs, hidden_batch_axes,
                                   make_plan, param_pspecs, to_named)
from repro.launch.steps import AdamWConfig, init_opt_state, make_train_step
from repro.models.model import build_model
from repro.models.transformer import set_mesh_axes
from repro.runtime.fault_tolerance import (PreemptionGuard, StragglerMonitor,
                                           resume_or_init)
from repro.checkpoint.checkpoint import AsyncCheckpointer


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    seed: int = 0
    remat: str = "full"
    opt: AdamWConfig = field(default_factory=AdamWConfig)


def train(cfg: ModelConfig, tc: TrainConfig, mesh=None,
          data_cfg: DataConfig | None = None) -> dict:
    model = build_model(cfg)
    if mesh is None:
        mesh = jax.make_mesh((1, 1), ("data", "model")) \
            if jax.device_count() == 1 else \
            jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    plan = make_plan(cfg, mesh)
    data_cfg = data_cfg or DataConfig(
        seq_len=cfg.max_seq, global_batch=8, vocab=cfg.vocab, seed=tc.seed)
    pipeline = Pipeline(data_cfg)
    guard = PreemptionGuard().install()
    monitor = StragglerMonitor()
    ckpt = AsyncCheckpointer(tc.ckpt_dir)

    set_mesh_axes(hidden_batch_axes(plan, mesh, data_cfg.global_batch),
                  "model", mesh=mesh)
    with mesh:
        pspecs = param_pspecs(model, mesh, plan)
        pshard = to_named(mesh, pspecs)
        opt_shard = {"m": pshard, "v": pshard,
                     "step": to_named(mesh, jax.sharding.PartitionSpec())}
        bspec = model.batch_spec(data_cfg.seq_len, data_cfg.global_batch,
                                 "train")
        bshard = to_named(mesh, batch_pspecs(model, mesh, bspec,
                                             data_cfg.global_batch, plan))
        base_step = make_train_step(model, tc.opt, remat=tc.remat)

        def _step(state, batch):
            p, o = state
            return base_step(p, o, batch)

        step_fn = jax.jit(_step,
                          in_shardings=((pshard, opt_shard), bshard),
                          donate_argnums=(0,))

        def init_fn():
            params = model.init(jax.random.key(tc.seed), "float32")
            params = jax.device_put(params, pshard)
            return (params, jax.device_put(init_opt_state(params),
                                           opt_shard))

        abstract_state = jax.eval_shape(init_fn)
        state, start = resume_or_init(
            tc.ckpt_dir, abstract_state, (pshard, opt_shard), init_fn,
            pipeline)

        losses = []
        it = iter(pipeline)
        t_start = time.time()
        step = start
        for step in range(start, tc.steps):
            monitor.step_start()
            host_batch = next(it)
            batch = {k: jax.device_put(v, bshard[k])
                     for k, v in host_batch.items()}
            params, opt_state, metrics = step_fn(state, batch)
            state = (params, opt_state)
            monitor.step_end(step)
            if step % tc.log_every == 0 or step == tc.steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                print(f"step {step}: loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({monitor.median_s * 1e3:.0f} ms/step)")
            if (step + 1) % tc.ckpt_every == 0 or guard.preempted:
                ckpt.save(state, step + 1)
            if guard.preempted:
                print(f"preempted at step {step}; checkpoint committed")
                break
        ckpt.wait()
        pipeline.close()
        return {"losses": losses, "final_step": step,
                "stragglers": monitor.flagged_steps,
                "wall_s": time.time() - t_start,
                "state": state}


def main() -> None:
    import argparse
    from repro.configs import get_config, smoke_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    args = ap.parse_args()
    cfg = (smoke_config(args.arch) if args.smoke
           else get_config(args.arch)).replace(max_seq=args.seq)
    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab=cfg.vocab)
    out = train(cfg, TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir),
                data_cfg=dc)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} over {out['final_step']} steps")


if __name__ == "__main__":
    main()
