"""jit-able train / prefill / decode step builders shared by the drivers
(train.py, serve.py) and the multi-pod dry-run."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig, remat: str = "full"):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_state, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        return new_params, new_state, {"loss": loss, **metrics,
                                       **opt_metrics}
    return train_step


def make_prefill_step(model):
    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)
    return prefill_step


def make_decode_step(model):
    def serve_step(params, cache, tokens):
        """One new token per sequence against the standing KV cache."""
        logits, new_cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache
    return serve_step


__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "AdamWConfig", "init_opt_state"]
