"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model); the 'pod' axis is
pure data parallelism across pods (slow inter-pod links carry only gradient
all-reduces, which the compression substrate shrinks further).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests of the distributed code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
