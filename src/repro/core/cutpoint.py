"""Cut-point optimizer (paper §IV).

A *block* is a residual block or a standalone group (Fig. 10); all groups in
a block share one reuse mode.  Feature-map sizes are monotone within runs of
blocks in modern CNNs, so the search space is restricted to one cut-point
per monotone run (Fig. 11/12): within a decreasing run, blocks after the cut
run frame-reuse (small maps fit on-chip); within an increasing run, blocks
before the cut run frame-reuse.  The optimum is found by exhaustive search
over the cross-product of cut positions, O(N^k) (paper §IV-B); when the
product blows past ``exhaustive_limit`` (many short runs, e.g. per-level
detector heads) we fall back to coordinate descent with restarts, which is
exact in practice because runs interact only through shared buffer maxima.

Search-engine architecture
--------------------------

``evaluate`` is the *oracle*: a from-scratch ``allocate()`` plus whole-graph
SRAM/DRAM/latency reports for one cut tuple.  The inner loop of ``search``
instead uses :class:`CutpointEngine`, which must agree with the oracle
bit-for-bit on every metric and is built from three pieces:

* **Prefix-cached allocation** -- the allocator's sequential state
  (:class:`~repro.core.allocator.AllocState`: buffer liveness, spills,
  boundary sets) is checkpointed at monotone-run boundaries.  Changing the
  cut of run *r* replays ``alloc_step`` only from run *r*'s first group;
  with the odometer enumeration order below, most candidates replay a
  single run.
* **Vectorized cost models** -- per-group static quantities (sizes, MACs,
  weight bytes, row-mode traffic/latency, SRAM candidate terms) are
  tabulated into numpy arrays once per graph (``latency_tables`` /
  ``dram_tables`` / ``sram_tables``); each candidate's reports are masked
  array reductions over the frame/row mask plus the small boundary/spill
  deltas produced by the allocator, instead of per-group Python loops.
  Elementwise IEEE ops and left-to-right summation keep the results
  bit-identical to the scalar reports.
* **Smarter search** -- candidates are memoized by cut tuple, exhaustive
  enumeration walks ``itertools.product`` order (last run varies fastest,
  maximizing prefix reuse), and coordinate descent keeps the seed's move
  order (so its trajectory, and therefore its answer, is unchanged) while
  the memo absorbs re-visited tuples across sweeps and restarts.

Oracle contract: ``CutpointEngine.evaluate(cuts)`` returns the same
``latency_cycles`` / ``dram_total`` / ``dram_fm`` / ``sram_total`` /
``bram18k`` / ``feasible`` as ``evaluate(...)`` for *every* cut tuple
(tests/test_cutpoint_engine.py enforces this on the whole CNN zoo), and
``search`` materializes its winning tuple through the oracle, so the
returned Candidate is byte-identical to what the seed implementation
produced.

``search(workers=N)`` farms disjoint sub-spaces of the cut product to a
process pool (see search_pool.py) with a deterministic merge; the result
is bit-identical to serial for every worker count
(tests/test_search_pool.py), so parallelism is purely a wall-clock knob.
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core.allocator import (Allocation, Policy, allocate, alloc_step,
                                  frame_feasible, graph_steps,
                                  init_alloc_state, spill_is_long_path)
from repro.core.dram import dram_fm_fast, dram_report, dram_tables
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig
from repro.core.sram import sram_report, sram_tables, sram_total_fast
from repro.core.timing import latency_cycles_fast, latency_report, latency_tables


# ------------------------------------------------------------------- blocks
@dataclass
class Block:
    bid: int
    gids: list[int]
    out_size: int                 # feature-map bytes at block output


def split_blocks(gg: GroupedGraph) -> list[Block]:
    """Residual blocks (groups up to and including a fused/standalone add
    whose shortcut source is inside the window) + standalone groups."""
    blocks: list[Block] = []
    current: list[int] = []
    open_shortcuts: set[int] = set()     # gids still awaited as shortcut src

    for g in gg.groups:
        current.append(g.gid)
        # does any later group take this one as a shortcut operand?
        for c in gg.group_consumers(g):
            cg = gg.groups[c]
            if cg.fused_add is not None and gg.shortcut_source_group(cg) == g.gid:
                if c - g.gid <= 8:       # short-path residual
                    open_shortcuts.add(g.gid)
        if g.fused_add is not None:
            src = gg.shortcut_source_group(g)
            open_shortcuts.discard(src)
        if not open_shortcuts:
            blocks.append(Block(bid=len(blocks), gids=current,
                                out_size=g.out_size))
            current = []
    if current:
        blocks.append(Block(bid=len(blocks), gids=current,
                            out_size=gg.groups[current[-1]].out_size))
    return blocks


def monotone_runs(blocks: list[Block]) -> list[list[int]]:
    """Split block indices into monotone runs of out_size (ties extend)."""
    if not blocks:
        return []
    runs: list[list[int]] = [[0]]
    direction = 0
    for i in range(1, len(blocks)):
        prev, cur = blocks[i - 1].out_size, blocks[i].out_size
        d = 0 if cur == prev else (1 if cur > prev else -1)
        if d == 0 or direction == 0 or d == direction:
            runs[-1].append(i)
            if d != 0:
                direction = d
        else:
            runs.append([i])
            direction = d
    return runs


def _run_direction(blocks: list[Block], run: list[int]) -> int:
    return 1 if blocks[run[-1]].out_size >= blocks[run[0]].out_size else -1


def policy_from_cuts(gg: GroupedGraph, blocks: list[Block],
                     runs: list[list[int]], cuts: tuple[int, ...]) -> Policy:
    """cut c in run r: for decreasing runs blocks[run[c:]] are frame-reuse;
    for increasing runs blocks[run[:c]] are frame-reuse."""
    mode_by_block: dict[int, str] = {}
    for run, cut in zip(runs, cuts):
        d = _run_direction(blocks, run)
        for pos, b in enumerate(run):
            if d < 0:
                mode_by_block[b] = "frame" if pos >= cut else "row"
            else:
                mode_by_block[b] = "frame" if pos < cut else "row"
    policy: Policy = {}
    for b, mode in mode_by_block.items():
        for gid in blocks[b].gids:
            policy[gid] = mode
    return policy


# ------------------------------------------------------------------- search
@dataclass
class Candidate:
    cuts: tuple[int, ...]
    policy: Policy
    alloc: Allocation
    latency_cycles: float
    dram_total: int
    dram_fm: int
    sram_total: int
    bram18k: int
    feasible: bool

    def ms(self, hw: FPGAConfig) -> float:
        return 1e3 * self.latency_cycles / hw.freq


@dataclass
class SearchResult:
    best: Candidate
    evaluated: int
    runs: list[list[int]]
    blocks: list[Block] = field(default_factory=list)


def evaluate(gg: GroupedGraph, blocks: list[Block], runs: list[list[int]],
             cuts: tuple[int, ...], hw: FPGAConfig) -> Candidate:
    policy = policy_from_cuts(gg, blocks, runs, cuts)
    alloc = allocate(gg, policy)
    sram = sram_report(gg, alloc, hw)
    dram = dram_report(gg, alloc)
    lat = latency_report(gg, alloc, hw)
    feasible = (sram.sram_total <= hw.sram_budget
                and frame_feasible(gg, policy, alloc))
    return Candidate(cuts=cuts, policy=policy, alloc=alloc,
                     latency_cycles=lat.cycles, dram_total=dram.total,
                     dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
                     bram18k=sram.bram18k, feasible=feasible)


def _key(c, objective: str):
    big = not c.feasible
    if objective == "latency":
        return (big, c.latency_cycles, c.sram_total)
    if objective == "sram":
        return (big, c.sram_total, c.latency_cycles)
    if objective == "dram":
        return (big, c.dram_total, c.latency_cycles)
    raise ValueError(objective)


# ------------------------------------------------------- incremental engine
@dataclass(frozen=True)
class CandidateMetrics:
    """Metrics of one cut tuple, without the policy/alloc payload.

    Attribute names mirror :class:`Candidate` so ``_key`` applies to both;
    ``search`` materializes only the winner into a full Candidate."""
    cuts: tuple[int, ...]
    latency_cycles: float
    dram_total: int
    dram_fm: int
    sram_total: int
    bram18k: int
    feasible: bool


class CutpointEngine:
    """Incremental, oracle-exact evaluator of cut tuples (see module
    docstring).  Build once per (graph, hardware) pair; ``evaluate`` is then
    10-100x cheaper than the direct oracle, and cheapest when successive
    tuples share a long prefix of unchanged runs."""

    def __init__(self, gg: GroupedGraph, hw: FPGAConfig,
                 blocks: list[Block] | None = None,
                 runs: list[list[int]] | None = None):
        self.gg = gg
        self.hw = hw
        self.blocks = blocks if blocks is not None else split_blocks(gg)
        self.runs = runs if runs is not None else monotone_runs(self.blocks)
        self.dirs = [_run_direction(self.blocks, r) for r in self.runs]
        # groups of run r occupy the contiguous gid range run_span[r]
        self.run_span = [(self.blocks[r[0]].gids[0],
                          self.blocks[r[-1]].gids[-1] + 1)
                         for r in self.runs]
        self._lt = latency_tables(gg, hw)
        self._dt = dram_tables(gg)
        self._st = sram_tables(gg, hw)
        self._steps = graph_steps(gg)
        self._spill_ok: dict[int, bool] = {}
        n = len(gg.groups)
        self._frame = np.zeros(n, dtype=bool)
        self._io = np.zeros(n)
        # checkpoint r = allocator state entering run r, valid for the
        # current materialized prefix cuts[:r]
        self._ckpts: list = [init_alloc_state(gg)] + [None] * len(self.runs)
        self._cur: tuple[int, ...] | None = None
        self._cache: dict[tuple[int, ...], CandidateMetrics] = {}
        self.evaluations = 0              # cache misses (actual replays)

    def _apply_run_modes(self, ri: int, cut: int) -> None:
        """Write run ``ri``'s frame/row mask for cut position ``cut``."""
        run, d = self.runs[ri], self.dirs[ri]
        for pos, b in enumerate(run):
            fr = (pos >= cut) if d < 0 else (pos < cut)
            lo, hi = self.blocks[b].gids[0], self.blocks[b].gids[-1] + 1
            self._frame[lo:hi] = fr

    def evaluate(self, cuts: tuple[int, ...],
                 memoize: bool = True) -> CandidateMetrics:
        """Metrics for one cut tuple.  ``memoize=False`` skips storing the
        result -- exhaustive enumeration visits every tuple exactly once,
        so caching there only costs memory (coordinate descent, which
        revisits tuples across sweeps and restarts, keeps the default)."""
        hit = self._cache.get(cuts)
        if hit is not None:
            return hit
        self.evaluations += 1
        gg = self.gg
        steps = self._steps

        # longest prefix of runs whose cuts are unchanged
        rd = 0
        if self._cur is not None:
            rd = len(self.runs)
            for r, (a, b) in enumerate(zip(cuts, self._cur)):
                if a != b:
                    rd = r
                    break
            if rd >= len(self.runs) and self.runs:
                # identical tuple re-evaluated without a cache hit (e.g.
                # memoize=False): replay the last run from its checkpoint
                rd = len(self.runs) - 1
        state = self._ckpts[rd].clone()
        for r in range(rd, len(self.runs)):
            if r > rd:
                self._ckpts[r] = state.clone()
            self._apply_run_modes(r, cuts[r])
            lo, hi = self.run_span[r]
            frame = self._frame
            for step in steps[lo:hi]:
                alloc_step(state, step,
                           "frame" if frame[step.gid] else "row")
        self._cur = cuts
        alloc = state.alloc

        # vectorized cost models over the allocation delta
        frame = self._frame
        io = self._io
        io[:] = 0.0
        for gid, rb in alloc.boundary_reads.items():
            io[gid] = rb
        out = self._dt.out_size
        for gid in alloc.boundary_writes:
            io[gid] += out[gid]
        for gid in alloc.spilled:
            if gid not in alloc.boundary_writes:
                io[gid] += out[gid]
        lat = latency_cycles_fast(self._lt, frame, io, self.hw)
        fm = dram_fm_fast(self._dt, frame, alloc)
        sram_total, bram = sram_total_fast(self._st, frame, alloc, self.hw)

        ok = self._spill_ok
        spills_ok = True
        for gid in alloc.spilled:
            v = ok.get(gid)
            if v is None:
                v = ok[gid] = spill_is_long_path(gg, gid)
            if not v:
                spills_ok = False
                break
        feasible = sram_total <= self.hw.sram_budget and spills_ok

        m = CandidateMetrics(cuts=cuts, latency_cycles=lat,
                             dram_total=fm + self._dt.weight_bytes,
                             dram_fm=fm, sram_total=sram_total,
                             bram18k=bram, feasible=feasible)
        if memoize:
            self._cache[cuts] = m
        return m


# ------------------------------------------------------------------ search
# Largest cut-product space searched exhaustively; larger spaces fall back
# to coordinate descent.  8M covers yolov2's full 7.96M-tuple space: with
# the incremental engine one tuple costs ~100us, so the worst case is
# ~2.5 min at 8 workers via search_pool (and ~15 min serial -- pass
# ``workers`` when compiling detector-scale graphs).
EXHAUSTIVE_LIMIT = 8_000_000


def coordinate_descent(engine: "CutpointEngine", start: tuple[int, ...],
                       objective: str, on_eval=None) -> CandidateMetrics:
    """One coordinate descent from ``start`` to its local optimum.

    The single definition of the descent trajectory -- move order, strict
    ``<`` improvement test, tie behavior -- shared by the serial loop in
    :func:`search` and the parallel per-start tasks in search_pool, whose
    bit-identity contract requires both to move in lock-step.  ``on_eval``
    (if given) observes every requested cut tuple; search_pool uses it to
    collect the visited set that reconstructs ``evaluated``.
    """
    def ev(t: tuple[int, ...]) -> CandidateMetrics:
        if on_eval is not None:
            on_eval(t)
        return engine.evaluate(t)

    cuts = list(start)
    cur = ev(tuple(cuts))
    improved = True
    while improved:
        improved = False
        for ri, run in enumerate(engine.runs):
            for cand_cut in range(len(run) + 1):
                if cand_cut == cuts[ri]:
                    continue
                trial = list(cuts)
                trial[ri] = cand_cut
                c = ev(tuple(trial))
                if _key(c, objective) < _key(cur, objective):
                    cur, cuts, improved = c, trial, True
    return cur


def descent_starts(blocks: list[Block],
                   runs: list[list[int]]) -> list[tuple[int, ...]]:
    """The three deterministic coordinate-descent start points: the exact
    all-row and all-frame policies (whose cut encoding depends on each
    run's direction) plus the run midpoints.  Shared by the serial loop
    below and the parallel per-start tasks in search_pool, which must use
    byte-identical starts."""
    all_row = tuple(len(r) if _run_direction(blocks, r) < 0 else 0
                    for r in runs)
    all_frame = tuple(0 if _run_direction(blocks, r) < 0 else len(r)
                      for r in runs)
    return [all_row, all_frame, tuple(len(r) // 2 for r in runs)]


def search(gg: GroupedGraph, hw: FPGAConfig, objective: str = "latency",
           exhaustive_limit: int = EXHAUSTIVE_LIMIT,
           workers: int | None = 1) -> SearchResult:
    """Find the best cut tuple for ``gg`` on ``hw``.

    Knobs
    -----
    objective:
        What "best" means; feasibility always dominates.  ``"latency"``
        minimizes ``(infeasible, latency_cycles, sram_total)``, ``"sram"``
        minimizes ``(infeasible, sram_total, latency_cycles)`` (paper
        Fig. 16's minimum-SRAM point), ``"dram"`` minimizes ``(infeasible,
        dram_total, latency_cycles)``.
    exhaustive_limit:
        Cut-product spaces up to this size are enumerated exhaustively
        (guaranteed optimum); beyond it, coordinate descent with
        deterministic restarts runs instead (exact in practice, because
        runs interact only through shared buffer maxima).  Default
        ``EXHAUSTIVE_LIMIT`` (8M tuples).
    workers:
        ``1`` (default) searches serially in-process.  ``N > 1`` farms
        disjoint sub-spaces to ``N`` worker processes through
        :class:`repro.core.search_pool.ParallelSearchDriver`; ``None``
        uses ``os.cpu_count()``.  The result is bit-identical to serial
        for every worker count -- parallelism changes wall clock only.

    Returns a :class:`SearchResult` whose ``best`` Candidate is
    materialized through the direct oracle, so it is exactly what the
    seed implementation produced for the same graph.
    """
    if workers is None or workers > 1:
        from repro.core.search_pool import ParallelSearchDriver
        with ParallelSearchDriver(workers=workers) as driver:
            return driver.search(gg, hw, objective=objective,
                                 exhaustive_limit=exhaustive_limit)

    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    engine = CutpointEngine(gg, hw, blocks, runs)

    def materialize(best: CandidateMetrics) -> SearchResult:
        # Re-run the winner through the direct oracle so the returned
        # Candidate (policy, alloc, metrics) is exactly what the direct
        # search would have produced.
        cand = evaluate(gg, blocks, runs, best.cuts, hw)
        return SearchResult(best=cand, evaluated=engine.evaluations,
                            runs=runs, blocks=blocks)

    if space <= exhaustive_limit:
        if space > 1_000_000:
            warnings.warn(
                f"exhaustive cut search over {space} tuples on a single "
                f"core (~{space / 10_000 / 60:.0f} min); pass workers=N to "
                f"search()/compile_graph() for a bit-identical result in "
                f"1/N the time, or lower exhaustive_limit to fall back to "
                f"coordinate descent", RuntimeWarning, stacklevel=2)
        best: CandidateMetrics | None = None
        # product order: the last run varies fastest, so consecutive tuples
        # share the longest possible checkpoint prefix
        for cuts in itertools.product(*[range(len(r) + 1) for r in runs]):
            c = engine.evaluate(cuts, memoize=False)
            if best is None or _key(c, objective) < _key(best, objective):
                best = c
        assert best is not None
        return materialize(best)

    # Coordinate descent with deterministic restarts (descent_starts).
    # Move order matches the seed implementation exactly (same trajectory,
    # same answer); the engine's memo absorbs the tuples revisited across
    # sweeps and restarts, and trials for a given run reuse the shared
    # allocation prefix of all earlier runs.
    best = None
    for start in descent_starts(blocks, runs):
        cur = coordinate_descent(engine, start, objective)
        if best is None or _key(cur, objective) < _key(best, objective):
            best = cur
    assert best is not None
    return materialize(best)


def sweep_single_cut(gg: GroupedGraph, hw: FPGAConfig) -> list[Candidate]:
    """Fig. 16/17: metrics vs the position of a single global cut-point:
    blocks < L row-reuse, >= L frame-reuse."""
    blocks = split_blocks(gg)
    out = []
    for L in range(len(blocks) + 1):
        policy: Policy = {}
        for b in blocks:
            mode = "row" if b.bid < L else "frame"
            for gid in b.gids:
                policy[gid] = mode
        alloc = allocate(gg, policy)
        sram = sram_report(gg, alloc, hw)
        dram = dram_report(gg, alloc)
        lat = latency_report(gg, alloc, hw)
        out.append(Candidate(
            cuts=(L,), policy=policy, alloc=alloc,
            latency_cycles=lat.cycles, dram_total=dram.total,
            dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
            bram18k=sram.bram18k,
            feasible=(sram.sram_total <= hw.sram_budget
                      and frame_feasible(gg, policy, alloc))))
    return out
