"""Cut-point optimizer (paper §IV).

A *block* is a residual block or a standalone group (Fig. 10); all groups in
a block share one reuse mode.  Feature-map sizes are monotone within runs of
blocks in modern CNNs, so the search space is restricted to one cut-point
per monotone run (Fig. 11/12): within a decreasing run, blocks after the cut
run frame-reuse (small maps fit on-chip); within an increasing run, blocks
before the cut run frame-reuse.  The optimum is found by exhaustive search
over the cross-product of cut positions, O(N^k) (paper §IV-B); when the
product blows past ``exhaustive_limit`` (many short runs, e.g. per-level
detector heads) we fall back to coordinate descent with restarts, which is
exact in practice because runs interact only through shared buffer maxima.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.allocator import Allocation, Policy, allocate, frame_feasible
from repro.core.dram import dram_report
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig
from repro.core.sram import sram_report
from repro.core.timing import latency_report


# ------------------------------------------------------------------- blocks
@dataclass
class Block:
    bid: int
    gids: list[int]
    out_size: int                 # feature-map bytes at block output


def split_blocks(gg: GroupedGraph) -> list[Block]:
    """Residual blocks (groups up to and including a fused/standalone add
    whose shortcut source is inside the window) + standalone groups."""
    blocks: list[Block] = []
    current: list[int] = []
    open_shortcuts: set[int] = set()     # gids still awaited as shortcut src

    for g in gg.groups:
        current.append(g.gid)
        # does any later group take this one as a shortcut operand?
        for c in gg.group_consumers(g):
            cg = gg.groups[c]
            if cg.fused_add is not None and gg.shortcut_source_group(cg) == g.gid:
                if c - g.gid <= 8:       # short-path residual
                    open_shortcuts.add(g.gid)
        if g.fused_add is not None:
            src = gg.shortcut_source_group(g)
            open_shortcuts.discard(src)
        if not open_shortcuts:
            blocks.append(Block(bid=len(blocks), gids=current,
                                out_size=g.out_size))
            current = []
    if current:
        blocks.append(Block(bid=len(blocks), gids=current,
                            out_size=gg.groups[current[-1]].out_size))
    return blocks


def monotone_runs(blocks: list[Block]) -> list[list[int]]:
    """Split block indices into monotone runs of out_size (ties extend)."""
    if not blocks:
        return []
    runs: list[list[int]] = [[0]]
    direction = 0
    for i in range(1, len(blocks)):
        prev, cur = blocks[i - 1].out_size, blocks[i].out_size
        d = 0 if cur == prev else (1 if cur > prev else -1)
        if d == 0 or direction == 0 or d == direction:
            runs[-1].append(i)
            if d != 0:
                direction = d
        else:
            runs.append([i])
            direction = d
    return runs


def _run_direction(blocks: list[Block], run: list[int]) -> int:
    return 1 if blocks[run[-1]].out_size >= blocks[run[0]].out_size else -1


def policy_from_cuts(gg: GroupedGraph, blocks: list[Block],
                     runs: list[list[int]], cuts: tuple[int, ...]) -> Policy:
    """cut c in run r: for decreasing runs blocks[run[c:]] are frame-reuse;
    for increasing runs blocks[run[:c]] are frame-reuse."""
    mode_by_block: dict[int, str] = {}
    for run, cut in zip(runs, cuts):
        d = _run_direction(blocks, run)
        for pos, b in enumerate(run):
            if d < 0:
                mode_by_block[b] = "frame" if pos >= cut else "row"
            else:
                mode_by_block[b] = "frame" if pos < cut else "row"
    policy: Policy = {}
    for b, mode in mode_by_block.items():
        for gid in blocks[b].gids:
            policy[gid] = mode
    return policy


# ------------------------------------------------------------------- search
@dataclass
class Candidate:
    cuts: tuple[int, ...]
    policy: Policy
    alloc: Allocation
    latency_cycles: float
    dram_total: int
    dram_fm: int
    sram_total: int
    bram18k: int
    feasible: bool

    def ms(self, hw: FPGAConfig) -> float:
        return 1e3 * self.latency_cycles / hw.freq


@dataclass
class SearchResult:
    best: Candidate
    evaluated: int
    runs: list[list[int]]
    blocks: list[Block] = field(default_factory=list)


def evaluate(gg: GroupedGraph, blocks: list[Block], runs: list[list[int]],
             cuts: tuple[int, ...], hw: FPGAConfig) -> Candidate:
    policy = policy_from_cuts(gg, blocks, runs, cuts)
    alloc = allocate(gg, policy)
    sram = sram_report(gg, alloc, hw)
    dram = dram_report(gg, alloc)
    lat = latency_report(gg, alloc, hw)
    feasible = (sram.sram_total <= hw.sram_budget
                and frame_feasible(gg, policy, alloc))
    return Candidate(cuts=cuts, policy=policy, alloc=alloc,
                     latency_cycles=lat.cycles, dram_total=dram.total,
                     dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
                     bram18k=sram.bram18k, feasible=feasible)


def _key(c: Candidate, objective: str):
    big = not c.feasible
    if objective == "latency":
        return (big, c.latency_cycles, c.sram_total)
    if objective == "sram":
        return (big, c.sram_total, c.latency_cycles)
    if objective == "dram":
        return (big, c.dram_total, c.latency_cycles)
    raise ValueError(objective)


def search(gg: GroupedGraph, hw: FPGAConfig, objective: str = "latency",
           exhaustive_limit: int = 200_000) -> SearchResult:
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    evaluated = 0
    if space <= exhaustive_limit:
        best: Candidate | None = None
        for cuts in itertools.product(*[range(len(r) + 1) for r in runs]):
            c = evaluate(gg, blocks, runs, cuts, hw)
            evaluated += 1
            if best is None or _key(c, objective) < _key(best, objective):
                best = c
        assert best is not None
        return SearchResult(best=best, evaluated=evaluated, runs=runs,
                            blocks=blocks)

    # Coordinate descent with deterministic restarts (incl. the exact
    # all-row and all-frame policies, whose cut encoding depends on the
    # run direction).
    all_row = tuple(len(r) if _run_direction(blocks, r) < 0 else 0
                    for r in runs)
    all_frame = tuple(0 if _run_direction(blocks, r) < 0 else len(r)
                      for r in runs)
    starts = [all_row, all_frame, tuple(len(r) // 2 for r in runs)]
    best = None
    for start in starts:
        cuts = list(start)
        cur = evaluate(gg, blocks, runs, tuple(cuts), hw)
        evaluated += 1
        improved = True
        while improved:
            improved = False
            for ri, run in enumerate(runs):
                for cand_cut in range(len(run) + 1):
                    if cand_cut == cuts[ri]:
                        continue
                    trial = list(cuts)
                    trial[ri] = cand_cut
                    c = evaluate(gg, blocks, runs, tuple(trial), hw)
                    evaluated += 1
                    if _key(c, objective) < _key(cur, objective):
                        cur, cuts, improved = c, trial, True
        if best is None or _key(cur, objective) < _key(best, objective):
            best = cur
    assert best is not None
    return SearchResult(best=best, evaluated=evaluated, runs=runs,
                        blocks=blocks)


def sweep_single_cut(gg: GroupedGraph, hw: FPGAConfig) -> list[Candidate]:
    """Fig. 16/17: metrics vs the position of a single global cut-point:
    blocks < L row-reuse, >= L frame-reuse."""
    blocks = split_blocks(gg)
    out = []
    for L in range(len(blocks) + 1):
        policy: Policy = {}
        for b in blocks:
            mode = "row" if b.bid < L else "frame"
            for gid in b.gids:
                policy[gid] = mode
        alloc = allocate(gg, policy)
        sram = sram_report(gg, alloc, hw)
        dram = dram_report(gg, alloc)
        lat = latency_report(gg, alloc, hw)
        out.append(Candidate(
            cuts=(L,), policy=policy, alloc=alloc,
            latency_cycles=lat.cycles, dram_total=dram.total,
            dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
            bram18k=sram.bram18k,
            feasible=(sram.sram_total <= hw.sram_budget
                      and frame_feasible(gg, policy, alloc))))
    return out
