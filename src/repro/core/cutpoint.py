"""Cut-point optimizer (paper §IV).

A *block* is a residual block or a standalone group (Fig. 10); all groups in
a block share one reuse mode.  Feature-map sizes are monotone within runs of
blocks in modern CNNs, so the search space is restricted to one cut-point
per monotone run (Fig. 11/12): within a decreasing run, blocks after the cut
run frame-reuse (small maps fit on-chip); within an increasing run, blocks
before the cut run frame-reuse.  The optimum is found by exhaustive search
over the cross-product of cut positions, O(N^k) (paper §IV-B); when the
product blows past ``exhaustive_limit`` (many short runs, e.g. per-level
detector heads) we fall back to coordinate descent with restarts, which is
exact in practice because runs interact only through shared buffer maxima.

Search-engine architecture
--------------------------

``evaluate`` is the *oracle*: a from-scratch ``allocate()`` plus whole-graph
SRAM/DRAM/latency reports for one cut tuple.  The inner loop of ``search``
instead uses :class:`CutpointEngine`, which must agree with the oracle
bit-for-bit on every metric and is built from three pieces:

* **Prefix-cached allocation** -- the allocator's sequential state
  (:class:`~repro.core.allocator.AllocState`: buffer liveness, spills,
  boundary sets) is checkpointed at monotone-run boundaries.  Changing the
  cut of run *r* replays ``alloc_step`` only from run *r*'s first group;
  with the odometer enumeration order below, most candidates replay a
  single run.
* **Vectorized cost models** -- per-group static quantities (sizes, MACs,
  weight bytes, row-mode traffic/latency, SRAM candidate terms) are
  tabulated into numpy arrays once per graph (``latency_tables`` /
  ``dram_tables`` / ``sram_tables``); each candidate's reports are masked
  array reductions over the frame/row mask plus the small boundary/spill
  deltas produced by the allocator, instead of per-group Python loops.
  Elementwise IEEE ops and left-to-right summation keep the results
  bit-identical to the scalar reports.
* **Smarter search** -- candidates are memoized by cut tuple, exhaustive
  enumeration walks ``itertools.product`` order (last run varies fastest,
  maximizing prefix reuse), and coordinate descent keeps the seed's move
  order (so its trajectory, and therefore its answer, is unchanged) while
  the memo absorbs re-visited tuples across sweeps and restarts.
* **Batched mask-matrix scoring** -- ``score_batch`` expands B cut tuples
  into a B x G frame-mask matrix plus a B x G boundary-IO matrix and
  prices all B candidates in one set of 2-D reductions
  (``latency_cycles_fast_batch`` / ``dram_fm_fast_batch`` /
  ``sram_total_fast_batch``), amortizing the per-candidate numpy
  dispatch that dominates per-tuple evaluation.  The per-candidate
  inputs come from an *incremental extraction* maintained during the
  checkpointed replays: the allocator journals boundary-set additions
  (``AllocState.j_*``) and the engine folds them into running io/DRAM/
  write-buffer/feasibility accumulators that are checkpointed next to
  the allocator state -- so a batch in product order replays and
  re-extracts only what each tuple changes.  ``search``/
  ``coordinate_descent`` consume this path behind the ``batch_size``
  knob (results and ``evaluated`` counts are identical for every batch
  size), and ``kernels/score_batch.py`` stages the same B x G reduction
  as a Pallas TPU kernel behind ``backend="pallas"``.
* **Device allocator replay** -- behind ``engine="device"`` (with
  ``:reference`` / ``:scan`` / ``:pallas`` variants), ``score_batch``
  skips the Python replay altogether: the frame-mask matrix is computed
  directly from the cut tuples (three gathers) and the whole batch runs
  through the *tensorized allocator state machine* of
  ``kernels/alloc_scan.py`` -- ``alloc_step`` re-expressed as a
  data-independent update rule over fixed-width integer arrays, scanned
  once over groups for all B candidates (numpy reference /
  ``jax.lax.scan`` / Pallas kernel, all integer-exact).  The journal
  path stays the default and the two are bit-identical, including memo
  contents and ``evaluations`` (tests/test_alloc_scan.py), which is
  what makes the whole search loop end-to-end array-programmable
  instead of Python-orchestrated.
* **Fused device search pipeline** -- behind ``engine="pipeline"``,
  exhaustive sub-spaces never materialize their candidate tuples on the
  host at all: ``kernels/search_pipeline.py`` enumerates cut tuples
  in-kernel from the product-order run tables, replays the allocator via
  ``alloc_scan``, reduces the exact costs, and runs a hierarchical
  argmin so only the winning ``(key, cuts, evaluated)`` tuple comes
  back.  Dispatch happens through ``CutpointEngine.run_subspace`` -- the
  resolution point of the ``ReplayEngine`` protocol in
  ``core/options.py``.

Oracle contract: ``CutpointEngine.evaluate(cuts)`` returns the same
``latency_cycles`` / ``dram_total`` / ``dram_fm`` / ``sram_total`` /
``bram18k`` / ``feasible`` as ``evaluate(...)`` for *every* cut tuple
(tests/test_cutpoint_engine.py enforces this on the whole CNN zoo), and
``search`` materializes its winning tuple through the oracle, so the
returned Candidate is byte-identical to what the seed implementation
produced.

``search(workers=N)`` farms disjoint sub-spaces of the cut product to a
process pool (see search_pool.py) with a deterministic merge; the result
is bit-identical to serial for every worker count
(tests/test_search_pool.py), so parallelism is purely a wall-clock knob.
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core.allocator import (Allocation, Policy, alloc_bound_terms,
                                  allocate, alloc_step, frame_feasible,
                                  graph_steps, init_alloc_state,
                                  spill_is_long_path)
from repro.core.dram import (dram_fm_fast, dram_fm_fast_batch, dram_report,
                             dram_tables)
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig
# DEFAULT_BATCH_SIZE / EXHAUSTIVE_LIMIT canonically live with the
# CompileOptions defaults; re-exported here for long-standing import sites.
from repro.core.options import (DEFAULT_BATCH_SIZE,  # noqa: F401
                                EXHAUSTIVE_LIMIT, CompileOptions,
                                resolve_engine, resolve_options)
from repro.core.sram import (sram_report, sram_tables, sram_total_fast,
                             sram_total_fast_batch)
from repro.core.timing import (latency_cycles_fast, latency_cycles_fast_batch,
                               latency_report, latency_tables)


# ------------------------------------------------------------------- blocks
@dataclass
class Block:
    bid: int
    gids: list[int]
    out_size: int                 # feature-map bytes at block output


def split_blocks(gg: GroupedGraph) -> list[Block]:
    """Residual blocks (groups up to and including a fused/standalone add
    whose shortcut source is inside the window) + standalone groups."""
    blocks: list[Block] = []
    current: list[int] = []
    open_shortcuts: set[int] = set()     # gids still awaited as shortcut src

    for g in gg.groups:
        current.append(g.gid)
        # does any later group take this one as a shortcut operand?
        for c in gg.group_consumers(g):
            cg = gg.groups[c]
            if cg.fused_add is not None and gg.shortcut_source_group(cg) == g.gid:
                if c - g.gid <= 8:       # short-path residual
                    open_shortcuts.add(g.gid)
        if g.fused_add is not None:
            src = gg.shortcut_source_group(g)
            open_shortcuts.discard(src)
        if not open_shortcuts:
            blocks.append(Block(bid=len(blocks), gids=current,
                                out_size=g.out_size))
            current = []
    if current:
        blocks.append(Block(bid=len(blocks), gids=current,
                            out_size=gg.groups[current[-1]].out_size))
    return blocks


def monotone_runs(blocks: list[Block]) -> list[list[int]]:
    """Split block indices into monotone runs of out_size (ties extend)."""
    if not blocks:
        return []
    runs: list[list[int]] = [[0]]
    direction = 0
    for i in range(1, len(blocks)):
        prev, cur = blocks[i - 1].out_size, blocks[i].out_size
        d = 0 if cur == prev else (1 if cur > prev else -1)
        if d == 0 or direction == 0 or d == direction:
            runs[-1].append(i)
            if d != 0:
                direction = d
        else:
            runs.append([i])
            direction = d
    return runs


def _run_direction(blocks: list[Block], run: list[int]) -> int:
    return 1 if blocks[run[-1]].out_size >= blocks[run[0]].out_size else -1


def policy_from_cuts(gg: GroupedGraph, blocks: list[Block],
                     runs: list[list[int]], cuts: tuple[int, ...]) -> Policy:
    """cut c in run r: for decreasing runs blocks[run[c:]] are frame-reuse;
    for increasing runs blocks[run[:c]] are frame-reuse."""
    mode_by_block: dict[int, str] = {}
    for run, cut in zip(runs, cuts):
        d = _run_direction(blocks, run)
        for pos, b in enumerate(run):
            if d < 0:
                mode_by_block[b] = "frame" if pos >= cut else "row"
            else:
                mode_by_block[b] = "frame" if pos < cut else "row"
    policy: Policy = {}
    for b, mode in mode_by_block.items():
        for gid in blocks[b].gids:
            policy[gid] = mode
    return policy


# ------------------------------------------------------------------- search
@dataclass
class Candidate:
    cuts: tuple[int, ...]
    policy: Policy
    alloc: Allocation
    latency_cycles: float
    dram_total: int
    dram_fm: int
    sram_total: int
    bram18k: int
    feasible: bool

    def ms(self, hw: FPGAConfig) -> float:
        return 1e3 * self.latency_cycles / hw.freq


@dataclass
class SearchResult:
    best: Candidate
    evaluated: int
    runs: list[list[int]]
    blocks: list[Block] = field(default_factory=list)
    # Fault/recovery events (search_pool.FaultEvent) the parallel runtime
    # took to produce this result -- retries, journal resumes, straggler
    # duplicates, device-replay fallbacks.  Always empty on the serial
    # path and on fault-free parallel runs; deliberately excluded from
    # the bit-identity contract (same cuts/metrics/evaluated regardless
    # of what the run survived).
    events: list = field(default_factory=list)
    # Candidates eliminated by branch-and-bound pruning without being
    # scored (see branch_bound_subspace).  The argmin and its metrics are
    # bit-identical whether or not pruning ran; with the default
    # ``count_pruned=True`` accounting, ``evaluated`` includes these (so
    # it equals the full enumeration count exactly).  The split between
    # scored and pruned -- this field -- legitimately varies with worker
    # count and scheduling (later tasks inherit a better incumbent), so
    # like ``events`` it is excluded from the bit-identity contract.
    pruned: int = 0
    # Which search path produced the result: "exhaustive" (full
    # enumeration of the cut product, the guaranteed optimum) or
    # "descent" (coordinate descent beyond ``exhaustive_limit``).  The
    # compile service records it with each cached plan so warm-start
    # eligibility can be decided per record (service/daemon.py).
    path: str = "exhaustive"


def evaluate(gg: GroupedGraph, blocks: list[Block], runs: list[list[int]],
             cuts: tuple[int, ...], hw: FPGAConfig) -> Candidate:
    policy = policy_from_cuts(gg, blocks, runs, cuts)
    alloc = allocate(gg, policy)
    sram = sram_report(gg, alloc, hw)
    dram = dram_report(gg, alloc)
    lat = latency_report(gg, alloc, hw)
    feasible = (sram.sram_total <= hw.sram_budget
                and frame_feasible(gg, policy, alloc))
    return Candidate(cuts=cuts, policy=policy, alloc=alloc,
                     latency_cycles=lat.cycles, dram_total=dram.total,
                     dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
                     bram18k=sram.bram18k, feasible=feasible)


def _key(c, objective: str):
    big = not c.feasible
    if objective == "latency":
        return (big, c.latency_cycles, c.sram_total)
    if objective == "sram":
        return (big, c.sram_total, c.latency_cycles)
    if objective == "dram":
        return (big, c.dram_total, c.latency_cycles)
    raise ValueError(objective)


# ------------------------------------------------------- incremental engine
@dataclass(slots=True)
class CandidateMetrics:
    """Metrics of one cut tuple, without the policy/alloc payload.

    Attribute names mirror :class:`Candidate` so ``_key`` applies to both;
    ``search`` materializes only the winner into a full Candidate.
    Treated as immutable by convention (millions are constructed per
    exhaustive search, so the class stays a plain slots dataclass rather
    than paying ``frozen=True``'s per-field ``object.__setattr__``)."""
    cuts: tuple[int, ...]
    latency_cycles: float
    dram_total: int
    dram_fm: int
    sram_total: int
    bram18k: int
    feasible: bool


class CutpointEngine:
    """Incremental, oracle-exact evaluator of cut tuples (see module
    docstring).  Build once per (graph, hardware) pair; ``evaluate`` is then
    10-100x cheaper than the direct oracle, and cheapest when successive
    tuples share a long prefix of unchanged runs."""

    def __init__(self, gg: GroupedGraph, hw: FPGAConfig,
                 blocks: list[Block] | None = None,
                 runs: list[list[int]] | None = None,
                 backend: str = "numpy", replay: str = "journal",
                 alloc_backend: str | None = None,
                 engine: str | None = None):
        self.gg = gg
        self.hw = hw
        # "numpy" (oracle-exact, default) or "pallas" (the staged on-device
        # batch reduction, float32 -- see kernels/score_batch.py)
        self.backend = backend
        # ``engine`` (an options.resolve_engine spelling) is the unified
        # execution knob; when given it resolves onto the two internal
        # knobs below (replay mode + alloc_scan implementation) and, for
        # the "pipeline" engine, selects the fused sub-space pipeline in
        # run_subspace.  The loose replay=/alloc_backend= parameters stay
        # for internal callers and tests; engine= wins when both appear.
        self._pipeline: str | None = None
        if engine is not None:
            spec = resolve_engine(engine)
            if spec.name == "device":
                replay, alloc_backend = "device", spec.variant
            elif spec.name == "pipeline":
                # score_batch falls back to the journal replay (the
                # descent path is host-driven either way); run_subspace
                # routes exhaustive sub-spaces through the fused kernel
                replay = "journal"
                self._pipeline = spec.variant
            else:
                replay = "journal"
        # "journal" (per-candidate checkpointed Python replay, default) or
        # "device" (tensorized allocator scan over the whole batch, see
        # kernels/alloc_scan.py) -- the default replay mode of score_batch
        self.replay = replay
        # which alloc_scan implementation the device replay runs:
        # "reference" (numpy) / "scan" (jax.lax.scan) / "pallas"; all three
        # are integer-exact, so any choice preserves bit-identity
        self.alloc_backend = (alloc_backend if alloc_backend is not None
                              else ("pallas" if backend == "pallas"
                                    else "reference"))
        self._kt = None               # packed kernel tables, built lazily
        self._at = None               # packed alloc-scan tables, lazy
        self.blocks = blocks if blocks is not None else split_blocks(gg)
        self.runs = runs if runs is not None else monotone_runs(self.blocks)
        self.dirs = [_run_direction(self.blocks, r) for r in self.runs]
        # groups of run r occupy the contiguous gid range run_span[r]
        self.run_span = [(self.blocks[r[0]].gids[0],
                          self.blocks[r[-1]].gids[-1] + 1)
                         for r in self.runs]
        # groups of block b occupy the contiguous gid range _block_span[b]
        self._block_span = [(b.gids[0], b.gids[-1] + 1) for b in self.blocks]
        self._lt = latency_tables(gg, hw)
        self._dt = dram_tables(gg)
        self._st = sram_tables(gg, hw)
        self._steps = graph_steps(gg)
        self._spill_ok: dict[int, bool] = {}
        n = len(gg.groups)
        self._frame = np.zeros(n, dtype=bool)
        self._io = np.zeros(n)
        # incremental cost extraction, updated run-by-run during replays
        # from the allocator's boundary journals and checkpointed next to
        # the allocator state: per-group frame-mode IO bytes, dram
        # boundary/spill byte total, eq. (5) frame write-buffer max, and
        # spill feasibility
        self._outsz = self._dt.out_size
        comp = self._st.compute.tolist()
        wft = self._st.wr_frame
        self._wr_cand = [wft[g] if comp[g] else 0 for g in range(n)]
        self._x_io: list = [0] * n
        self._x_bfm = 0
        self._x_wrf = 0
        self._x_feas = True
        self._x_cache: list = ([([0] * n, 0, 0, True)]
                               + [None] * len(self.runs))
        # checkpoint r = allocator state entering run r, valid for the
        # current materialized prefix cuts[:r] (lean: replays skip the
        # metrics-irrelevant assignment maps; the winner is materialized
        # through the full oracle)
        self._ckpts: list = ([init_alloc_state(gg, lean=True)]
                             + [None] * len(self.runs))
        # reused working state for replays (reset in place per replay;
        # the checkpoints themselves are real clone() snapshots)
        self._scratch = init_alloc_state(gg, lean=True)
        self._bram_memo: dict = {}
        self._cur: tuple[int, ...] | None = None
        # how many leading runs of _cur are actually materialized in the
        # scratch state / frame mask / extraction accumulators: full
        # replays set len(runs), prefix replays (prefix_bound) set their
        # depth, and checkpoints are only trusted up to this length
        self._cur_len = 0
        self._cache: dict[tuple[int, ...], CandidateMetrics] = {}
        self.evaluations = 0              # cache misses (actual replays)
        # per-group (run index, block position, direction) -- the whole
        # frame-mask matrix of a batch is then three gathers, no replay
        run_of = np.zeros(n, dtype=np.int64)
        pos_of = np.zeros(n, dtype=np.int64)
        dir_neg = np.zeros(n, dtype=bool)
        for r, run in enumerate(self.runs):
            d = self.dirs[r]
            for pos, b in enumerate(run):
                lo, hi = self._block_span[b]
                run_of[lo:hi] = r
                pos_of[lo:hi] = pos
                dir_neg[lo:hi] = d < 0
        self._run_of = run_of
        self._pos_of = pos_of
        self._dir_neg = dir_neg
        # ------------------------------ branch-and-bound floor tables
        # Static per-group completion floors for prefix_bound.  Latency:
        # a free (suffix) group costs at least min(row latency, frame
        # latency at zero boundary IO) -- the very IEEE ops of
        # latency_cycles_fast with io_bytes=0, so elementwise the floor
        # never exceeds the candidate's actual per-group term.  SRAM:
        # every suffix compute group contributes one of its eq. (4)
        # candidates to out_buff, so at least min(out_frame, out_row);
        # _sfx_minout[p] is the max of that floor over gids >= p.
        lt = self._lt
        bpc = hw.dram_bytes_per_cycle
        frame_floor = (np.maximum(lt.comp, lt.weight / bpc)
                       + hw.group_overhead_cycles)
        self._lat_floor = np.where(lt.side, lt.comp,
                                   np.minimum(lt.row, frame_floor))
        self._lat_lb = np.empty(n)        # reused per-bound scratch row
        st = self._st
        minout = np.where(st.compute,
                          np.minimum(st.out_frame, st.out_row), 0)
        sfx = [0] * (n + 1)
        for g in range(n - 1, -1, -1):
            sfx[g] = max(sfx[g + 1], int(minout[g]))
        self._sfx_minout = sfx

    def _replay(self, cuts: tuple[int, ...],
                rd: int | None = None,
                rend: int | None = None) -> Allocation:
        """Materialize the allocation for ``cuts`` (or a prefix of it).

        Finds the longest prefix of runs whose cuts match the engine's
        current tuple (callers that know it -- ``score_batch`` computes
        the whole batch's shared prefixes in one vectorized pass -- pass
        it as ``rd``), resets the reused scratch state to the allocator
        checkpoint at that run boundary (in-place container reuse: two
        C-level list copies plus clear+update on the small sets), and
        replays ``alloc_step`` only over the changed suffix (refreshing
        the downstream checkpoints, as real clones, along the way).  A
        batch walked in product order therefore replays each shared cut
        prefix exactly once.  On return, ``self._frame`` holds the
        candidate's frame mask; the returned Allocation is the scratch
        state's and is only valid until the next replay -- callers must
        extract what they need immediately.

        ``rend`` stops the replay after run ``rend - 1`` (default: all
        runs), leaving the scratch state, frame mask (up to the prefix's
        last gid) and extraction accumulators describing exactly the
        cut prefix ``cuts[:rend]`` -- this is what ``prefix_bound``
        evaluates its completion floors from.  A prefix replay writes
        the entering-run checkpoint at ``rend`` so sibling prefixes and
        surviving completions replay only what they change; when the
        requested prefix is already materialized (checkpoint match) the
        state is reset from the checkpoint with no replay at all."""
        runs = self.runs
        nr = len(runs)
        if rend is None:
            rend = nr
        if rd is None:
            # longest prefix of runs whose cuts are unchanged; only the
            # materialized prefix of _cur (and its checkpoints) may be
            # trusted after a prefix replay
            cur = self._cur
            if cur is None:
                rd = 0
            else:
                limit = self._cur_len
                rd = limit
                for r in range(limit):
                    if cuts[r] != cur[r]:
                        rd = r
                        break
                if rd >= rend:
                    if rend == nr and nr:
                        # identical tuple re-evaluated without a cache hit
                        # (e.g. memoize=False): replay the last run
                        rd = nr - 1
                    else:
                        # prefix already materialized: reset to its
                        # checkpoint, replay nothing
                        rd = rend
        # reset the scratch state to checkpoint rd in place, reusing its
        # containers (lean states: the journals are already drained and
        # the assignment maps stay empty, so neither needs touching)
        state = self._scratch
        ck = self._ckpts[rd]
        cka = ck.alloc
        sa = state.alloc
        sa.buff[:] = cka.buff
        sa.side_buff = cka.side_buff
        sp = sa.spilled
        sp.clear()
        sp.update(cka.spilled)
        bws = sa.boundary_writes
        bws.clear()
        bws.update(cka.boundary_writes)
        brd = sa.boundary_reads
        brd.clear()
        brd.update(cka.boundary_reads)
        state.remaining[:] = ck.remaining
        state.location[:] = ck.location
        lib = state.live_in_buffer
        lib.clear()
        lib.update(ck.live_in_buffer)
        x_io = self._x_io
        cio, bfm, wrf, feas = self._x_cache[rd]
        x_io[:] = cio
        frame = self._frame
        steps = self._steps
        ckpts = self._ckpts
        xcache = self._x_cache
        dirs = self.dirs
        spans = self._block_span
        alloc = state.alloc
        jw, jr, jsp = state.j_writes, state.j_reads, state.j_spills
        outsz = self._outsz
        wr_cand = self._wr_cand
        ok = self._spill_ok
        for r in range(rd, rend):
            if r > rd:
                ckpts[r] = state.clone()
                xcache[r] = (list(x_io), bfm, wrf, feas)
            cut = cuts[r]
            d = dirs[r]
            for pos, b in enumerate(runs[r]):
                fr = (pos >= cut) if d < 0 else (pos < cut)
                lo, hi = spans[b]
                frame[lo:hi] = fr
                mode = "frame" if fr else "row"
                for step in steps[lo:hi]:
                    alloc_step(state, step, mode)
            # drain this run's boundary-journal additions into the
            # incremental extraction (O(additions), not O(|sets|))
            if jr:
                br = alloc.boundary_reads
                for gid in jr:
                    v = br[gid]
                    x_io[gid] += v
                    bfm += v
                del jr[:]
            if jw:
                for gid in jw:
                    v = outsz[gid]
                    x_io[gid] += v
                    bfm += v
                    w = wr_cand[gid]
                    if w > wrf:
                        wrf = w
                del jw[:]
            if jsp:
                bw = alloc.boundary_writes
                for gid in jsp:
                    if gid not in bw:
                        v = outsz[gid]
                        x_io[gid] += v
                        bfm += v
                    sv = ok.get(gid)
                    if sv is None:
                        sv = ok[gid] = spill_is_long_path(self.gg, gid)
                    if not sv:
                        feas = False
                del jsp[:]
        if rend < nr and rd < rend:
            # trailing entering-run checkpoint of a prefix replay, so
            # extensions (deeper bounds, surviving completions) resume
            # here instead of re-walking the prefix
            ckpts[rend] = state.clone()
            xcache[rend] = (list(x_io), bfm, wrf, feas)
        self._cur = cuts
        self._cur_len = rend
        self._x_bfm = bfm
        self._x_wrf = wrf
        self._x_feas = feas
        return alloc

    def evaluate(self, cuts: tuple[int, ...],
                 memoize: bool = True) -> CandidateMetrics:
        """Metrics for one cut tuple.  ``memoize=False`` skips storing the
        result -- exhaustive enumeration visits every tuple exactly once,
        so caching there only costs memory (coordinate descent, which
        revisits tuples across sweeps and restarts, keeps the default)."""
        hit = self._cache.get(cuts)
        if hit is not None:
            return hit
        self.evaluations += 1
        gg = self.gg
        alloc = self._replay(cuts)

        # vectorized cost models over the allocation delta
        frame = self._frame
        io = self._io
        io[:] = 0.0
        for gid, rb in alloc.boundary_reads.items():
            io[gid] = rb
        out = self._dt.out_size
        for gid in alloc.boundary_writes:
            io[gid] += out[gid]
        for gid in alloc.spilled:
            if gid not in alloc.boundary_writes:
                io[gid] += out[gid]
        lat = latency_cycles_fast(self._lt, frame, io, self.hw)
        fm = dram_fm_fast(self._dt, frame, alloc)
        sram_total, bram = sram_total_fast(self._st, frame, alloc, self.hw)

        ok = self._spill_ok
        spills_ok = True
        for gid in alloc.spilled:
            v = ok.get(gid)
            if v is None:
                v = ok[gid] = spill_is_long_path(gg, gid)
            if not v:
                spills_ok = False
                break
        feasible = sram_total <= self.hw.sram_budget and spills_ok

        m = CandidateMetrics(cuts=cuts, latency_cycles=lat,
                             dram_total=fm + self._dt.weight_bytes,
                             dram_fm=fm, sram_total=sram_total,
                             bram18k=bram, feasible=feasible)
        if memoize:
            self._cache[cuts] = m
        return m

    # ------------------------------------------------- branch-and-bound
    def prefix_bound(self, cuts: tuple[int, ...], depth: int,
                     objective: str):
        """Admissible lower bound on the primary objective term over
        *every* completion of the cut prefix ``cuts[:depth]``.

        The bound is the exact prefix cost plus a nonnegative completion
        floor, both read off the checkpointed prefix replay:

        * **latency** -- prefix groups are priced with the exact per-group
          model at the *current* boundary-IO accumulator (``_x_io`` only
          grows as later runs allocate, and the frame-mode term is IEEE-
          monotone in io bytes); suffix groups take the static
          ``_lat_floor`` (min of row latency and zero-IO frame latency).
          The per-group floors are summed left-to-right in gid order --
          the same association as ``latency_cycles_fast`` -- so IEEE
          monotone addition keeps the total a true lower bound.
        * **sram** -- the replayed buffer maxima (monotone, see
          ``allocator.alloc_bound_terms``), the prefix's eq. (1)/(4)/(5)
          masked maxima, the running frame-write max ``_x_wrf``
          (monotone) and the static suffix out-buffer floor
          ``_sfx_minout``.  Integer-exact.
        * **dram** -- the prefix's masked row-traffic sum plus the
          running boundary/spill byte total ``_x_bfm`` (monotone) plus
          the constant weight traffic.  Integer-exact.

        Feasibility is assumed optimistically and the tie-break
        (secondary) term is floored at zero, so the pruner's bound key
        ``(False, lb, 0)`` never exceeds any completion's ``_key``.  At
        ``depth == len(runs)`` the bound equals the candidate's exact
        primary metric (the completion is unique) -- the differential
        gate in analysis/mutate.py kills deflated-bound mutations
        against exactly this property.

        Leaves the engine holding the prefix replay (``_cur_len ==
        depth``); full replays afterwards resume from its checkpoints.
        """
        nr = len(self.runs)
        if not 0 < depth <= nr:
            raise ValueError(f"prefix_bound depth {depth} outside "
                             f"1..{nr}")
        self._replay(cuts, rend=depth)
        pend = self.run_span[depth - 1][1]      # gids < pend are fixed
        frame = self._frame
        if objective == "latency":
            lt = self._lt
            hw = self.hw
            per = self._lat_lb
            per[:] = self._lat_floor
            io = np.asarray(self._x_io[:pend], dtype=np.float64)
            mem = (lt.weight[:pend] + io) / hw.dram_bytes_per_cycle
            frame_lat = (np.maximum(lt.comp[:pend], mem)
                         + hw.group_overhead_cycles)
            per[:pend] = np.where(lt.side[:pend], lt.comp[:pend],
                                  np.where(frame[:pend], frame_lat,
                                           lt.row[:pend]))
            # det: left-to-right association of latency_cycles_fast
            return sum(per.tolist())
        if objective == "dram":
            row_pre = int(np.where(frame[:pend], 0,
                                   self._dt.row_fm[:pend]).sum())
            return row_pre + self._x_bfm + self._dt.weight_bytes
        if objective == "sram":
            st = self._st
            cm = st.compute[:pend]
            frm = cm & frame[:pend]
            rowm = cm & ~frame[:pend]
            wbuff = int(st.weight[:pend].max(where=rowm, initial=0))
            outf = int(st.out_frame[:pend].max(where=frm, initial=0))
            outr = int(st.out_row[:pend].max(where=rowm, initial=0))
            wrr = int(st.wr_row[:pend].max(where=rowm, initial=0))
            b0, b1, b2, side = alloc_bound_terms(self._scratch)
            if wbuff > b1:
                b1 = wbuff
            out_lb = max(outf, outr, self._sfx_minout[pend])
            write_lb = max(wrr, self._x_wrf)
            return (st.row_buff + out_lb + write_lb
                    + b0 + b1 + b2 + side)
        raise ValueError(objective)

    # ------------------------------------------------------- device replay
    def _frame_matrix(self, tuples: list) -> np.ndarray:
        """B x G frame-mask matrix straight from the cut tuples.

        Exactly the masks the checkpointed replay paints block-by-block
        (``policy_from_cuts`` semantics), but as three vectorized gathers
        -- no allocator involved, so the device replay can start from the
        masks alone."""
        nr = len(self.runs)
        b = len(tuples)
        if not nr or not b:
            return np.zeros((b, len(self.gg.groups)), dtype=bool)
        arr = np.fromiter(itertools.chain.from_iterable(tuples),
                          dtype=np.int64, count=b * nr).reshape(b, nr)
        cut = arr[:, self._run_of]
        pos = self._pos_of[None, :]
        return np.where(self._dir_neg[None, :], pos >= cut, pos < cut)

    def _device_replay(self, frame: np.ndarray, skip=None):
        """Tensorized allocator replay of a whole frame-mask batch
        (kernels/alloc_scan.py) under ``self.alloc_backend``.  ``skip``
        masks pruned batch lanes out of the scan (their outputs come
        back zero-filled)."""
        if self._at is None:
            from repro.kernels.alloc_scan import pack_alloc_tables
            self._at = pack_alloc_tables(self.gg, self.hw)
        from repro.kernels.alloc_scan import alloc_scan
        return alloc_scan(self._at, frame, backend=self.alloc_backend,
                          skip=skip)

    # ------------------------------------------------------ batched scoring
    def score_batch(self, cuts_batch, memoize: bool = True,
                    backend: str | None = None,
                    replay: str | None = None,
                    skip=None) -> list:
        """Metrics for a batch of B cut tuples in one set of 2-D reductions.

        The batch is expanded into a B x G frame-mask matrix plus a B x G
        boundary-I/O matrix (one allocator replay per *distinct* miss, in
        batch order, so a batch drawn from one sub-space in product order
        replays each shared cut prefix exactly once through the allocator
        checkpoints), and ``latency_cycles`` / ``dram_total`` / ``dram_fm``
        / ``sram_total`` / ``bram18k`` / ``feasible`` for all B candidates
        fall out of ``latency_cycles_fast_batch`` / ``dram_fm_fast_batch``
        / ``sram_total_fast_batch``.

        Contract: with the default "numpy" backend, element ``i`` of the
        returned list is bit-identical to ``evaluate(cuts_batch[i])`` --
        same IEEE elementwise ops, same left-to-right per-row summation
        order -- and the memo/``evaluations`` bookkeeping matches a
        per-tuple loop exactly: cache hits are returned (not recounted),
        duplicate tuples within a memoized batch are evaluated once, and
        ``memoize=False`` replays every element (as exhaustive enumeration
        wants).  ``backend="pallas"`` routes the three reductions through
        the staged on-device kernel (kernels/score_batch.py, float32 --
        NOT oracle-exact; for on-device search experiments only); its
        results are never written into the memo, so ``evaluate``'s
        bit-exact contract on the same engine instance is preserved
        (cached exact entries are still served to pallas callers).

        ``skip`` (a length-B boolean mask, ``memoize=False`` only) marks
        batch lanes the caller has already pruned: the branch-and-bound
        walk (``branch_bound_subspace``) enqueues leaves batch-by-batch
        and the incumbent may improve before a batch flushes, so lanes
        whose recorded bound now exceeds the incumbent are skipped
        *before* any journal or device replay.  Skipped lanes return
        ``None``, are never replayed, and do not count toward
        ``evaluations``; surviving lanes are bit-identical to an
        unmasked call.

        ``replay`` selects how the per-candidate allocator quantities are
        produced: ``"journal"`` (default) is the checkpointed Python
        replay above; ``"device"`` builds the frame-mask matrix directly
        from the cut tuples and runs the whole batch through the
        tensorized allocator scan (kernels/alloc_scan.py, integer-exact
        under every ``alloc_backend``), leaving the journal checkpoints
        untouched.  Both produce bit-identical CandidateMetrics and the
        same memo/``evaluations`` bookkeeping, so every caller --
        ``search``, ``coordinate_descent``, the pool workers,
        ``compile_graph`` -- inherits the knob with byte-identical
        results.
        """
        if backend is None:
            backend = self.backend
        if replay is None:
            replay = self.replay
        if replay not in ("journal", "device"):
            raise ValueError(f"unknown score_batch replay: {replay!r}")
        if skip is not None and memoize:
            raise ValueError("score_batch: skip requires memoize=False "
                             "(pruned lanes must not poison the memo)")
        cuts_batch = list(cuts_batch)
        out: list[CandidateMetrics | None] = [None] * len(cuts_batch)
        slots: list[tuple[int, int]] = []      # (batch index, miss index)
        if memoize:
            miss: list = []              # distinct tuples needing a replay
            pending: dict[tuple[int, ...], int] = {}
            for i, cuts in enumerate(cuts_batch):
                hit = self._cache.get(cuts)
                if hit is not None:
                    out[i] = hit
                    continue
                j = pending.get(cuts)
                if j is None:
                    j = pending[cuts] = len(miss)
                    miss.append(cuts)
                slots.append((i, j))
            if not miss:
                return out
        else:
            # exhaustive enumeration: every element replays, in order
            miss = cuts_batch
            if not miss:
                return out

        if replay == "device":
            # --- tensorized allocator scan over the whole batch: frame
            # masks straight from the cut tuples, one alloc_scan call for
            # every per-candidate quantity the reductions below need.
            # .tolist() materializes exact Python ints, so the assembled
            # CandidateMetrics (and the memo) are byte-identical to the
            # journal path's.
            frame = self._frame_matrix(miss)
            res = self._device_replay(frame, skip=skip)
            if skip is None:
                self.evaluations += len(miss)
            else:
                self.evaluations += len(miss) - sum(map(bool, skip))
                # pruned lanes must not contribute row-mode DRAM/latency
                # terms in the 2-D reductions below (their metrics are
                # discarded, but keep them finite and cheap)
                frame[np.asarray(skip, dtype=bool)] = True
            io = res.io.astype(np.float64)
            boundary_fm = res.bfm.tolist()
            feas_spills = res.feasible.tolist()
            cand_terms = [(b[0], b[1], b[2], s, w)
                          for b, s, w in zip(res.buff.tolist(),
                                             res.side_buff.tolist(),
                                             res.wrf.tolist())]
        else:
            # --- vectorized shared-prefix lengths: rd[j] = first run
            # whose cut differs from the *previously replayed* miss (the
            # engine replays the batch in order, so the previous replayed
            # miss *is* the engine's current tuple); the first replayed
            # miss compares against the engine's real current tuple
            # inside _replay.  With a skip mask the chain runs over the
            # surviving subsequence only -- a skipped lane never becomes
            # the engine's current tuple, so comparing across it would
            # desynchronize the checkpoints.
            nr = len(self.runs)
            todo = (miss if skip is None
                    else [c for c, s in zip(miss, skip) if not s])
            if len(todo) > 1 and nr:
                arr = np.fromiter(itertools.chain.from_iterable(todo),
                                  dtype=np.int64,
                                  count=len(todo) * nr).reshape(len(todo),
                                                                nr)
                neq = arr[1:] != arr[:-1]
                rds = np.where(neq.any(axis=1), neq.argmax(axis=1),
                               nr - 1).tolist()
            else:
                rds = []

            # --- replay each distinct surviving miss; the incremental
            # extraction state (self._x_*) holds the candidate-dependent
            # scalars afterwards, so the per-candidate work here is four
            # row/scalar copies.  Skipped lanes keep zero rows (their
            # assembled metrics are never read).
            n = len(self.gg.groups)
            frame = np.zeros((len(miss), n), dtype=bool)
            io_rows: list[list] = []             # per-candidate io vectors
            boundary_fm: list[int] = []          # dram boundary/spill bytes
            cand_terms: list[tuple] = []         # sram per-candidate terms
            feas_spills: list[bool] = []         # spill feasibility
            _replay = self._replay
            my_frame = self._frame
            x_io = self._x_io
            zero_row = [0] * n
            zero_terms = (0, 0, 0, 0, 0)
            ti = 0                               # index into todo/rds
            for j, cuts in enumerate(miss):
                if skip is not None and skip[j]:
                    io_rows.append(zero_row)
                    cand_terms.append(zero_terms)
                    boundary_fm.append(0)
                    feas_spills.append(True)
                    continue
                self.evaluations += 1
                alloc = _replay(cuts, rds[ti - 1] if ti else None)
                ti += 1
                frame[j] = my_frame
                io_rows.append(list(x_io))
                b = alloc.buff
                cand_terms.append((b[0], b[1], b[2], alloc.side_buff,
                                   self._x_wrf))
                boundary_fm.append(self._x_bfm)
                feas_spills.append(self._x_feas)
            io = np.asarray(io_rows, dtype=np.float64)

        # --- one set of 2-D reductions across the whole batch
        if backend == "pallas":
            from repro.kernels.score_batch import pack_tables, score_stats
            if self._kt is None:
                self._kt = pack_tables(self._lt, self._dt, self._st)
            stats = score_stats(self._kt, frame, io, self.hw)
            lat = stats.latency
            fm = dram_fm_fast_batch(self._dt, frame, boundary_fm,
                                    row_terms=stats.row_fm)
            sram, bram = sram_total_fast_batch(
                self._st, frame, cand_terms, self.hw, maxima=stats.maxima,
                bram_memo=self._bram_memo)
        elif backend == "numpy":
            lat = latency_cycles_fast_batch(self._lt, frame, io, self.hw)
            fm = dram_fm_fast_batch(self._dt, frame, boundary_fm)
            sram, bram = sram_total_fast_batch(
                self._st, frame, cand_terms, self.hw,
                bram_memo=self._bram_memo)
        else:
            raise ValueError(f"unknown score_batch backend: {backend!r}")

        # --- assemble CandidateMetrics in batch order.  Only oracle-exact
        # (numpy) results may enter the memo: evaluate() serves from it
        # under a bit-exactness contract, and float32 kernel results
        # would silently poison it.
        lat = lat.tolist()
        budget = self.hw.sram_budget
        wb = self._dt.weight_bytes
        store = memoize and backend == "numpy"
        cache = self._cache
        scored: list[CandidateMetrics | None] = []
        for j, cuts in enumerate(miss):
            if skip is not None and skip[j]:
                scored.append(None)
                continue
            fm_j = fm[j]
            sram_j = sram[j]
            m = CandidateMetrics(
                cuts=cuts, latency_cycles=lat[j],
                dram_total=fm_j + wb, dram_fm=fm_j, sram_total=sram_j,
                bram18k=bram[j],
                feasible=sram_j <= budget and feas_spills[j])
            if store:
                cache[cuts] = m
            scored.append(m)
        if not memoize:
            return scored
        for i, j in slots:
            out[i] = scored[j]
        return out

    # ------------------------------------------------- engine dispatch
    def run_subspace(self, prefix, suffix_dims, objective: str,
                     batch_size: int = DEFAULT_BATCH_SIZE,
                     incumbent_key=None, prune: bool = True):
        """Argmin over one sub-space, under this engine's execution mode.

        The single resolution point of the ``options.ReplayEngine``
        protocol: the serial ``search`` loop, every pool worker
        (``search_pool._run_subspace``) and therefore the compile
        service all route exhaustive sub-spaces through here.  Returns
        ``(best, pruned)`` exactly like :func:`branch_bound_subspace`.

        * journal / device engines -> the host-driven branch-and-bound
          walk (``branch_bound_subspace``), scoring through
          ``score_batch`` under the engine's replay mode;
        * the pipeline engine -> ``kernels/search_pipeline.py``'s fused
          enumerate + alloc-scan + reduce + argmin device loop, which
          scores the *whole* sub-space (no pruning -- every candidate is
          priced in-kernel, so ``pruned`` comes back 0 and ``evaluated``
          equals the full enumeration count, i.e. the journal path's
          count under the default ``count_pruned=True`` accounting).

        Both paths return the bit-identical ``(key, cuts)``-lexicographic
        winner (tests/test_search_pipeline.py).
        """
        if self._pipeline is not None:
            from repro.kernels.search_pipeline import pipeline_subspace
            return pipeline_subspace(self, tuple(prefix),
                                     list(suffix_dims), objective,
                                     batch_size=batch_size,
                                     variant=self._pipeline)
        return branch_bound_subspace(self, prefix, suffix_dims, objective,
                                     batch_size=batch_size,
                                     incumbent_key=incumbent_key,
                                     prune=prune)


# ------------------------------------------------------------------ search
# Largest cut-product space searched exhaustively; larger spaces fall back
# to coordinate descent.  8M covers yolov2's full 7.96M-tuple space: with
# the batched scorer one tuple costs ~30us, so the worst case is a few
# minutes serial and scales further with ``workers`` via search_pool --
# pass ``workers`` when compiling detector-scale graphs.
# (EXHAUSTIVE_LIMIT / DEFAULT_BATCH_SIZE are re-exported from
# core/options.py at the top of this module.)

# Smallest subtree (number of completions under a shared cut prefix) worth
# a ``prefix_bound`` call: a bound costs roughly one checkpointed run
# replay plus a handful of masked reductions -- a few candidate scorings
# -- so bounding tiny subtrees loses even when every one of them prunes.
PRUNE_MIN_SUBTREE = 16


def branch_bound_subspace(engine: "CutpointEngine",
                          prefix: tuple[int, ...],
                          suffix_dims,
                          objective: str,
                          batch_size: int = DEFAULT_BATCH_SIZE,
                          incumbent_key=None,
                          prune: bool = True,
                          prune_min_subtree: int = PRUNE_MIN_SUBTREE):
    """Argmin over ``prefix x product(range(d + 1) for d in suffix_dims)``
    with exact branch-and-bound pruning.

    Returns ``(best, pruned)``: ``best`` is the first product-order
    optimum among scored candidates as a :class:`CandidateMetrics`
    (``None`` iff every completion was pruned -- only possible when an
    external ``incumbent_key`` already beats the whole sub-space), and
    ``pruned`` counts candidates eliminated without scoring.

    The walk is depth-first in ``itertools.product`` order.  At each
    internal node (a shared cut prefix) whose subtree holds at least
    ``prune_min_subtree`` completions, ``engine.prefix_bound`` prices the
    prefix; a bound key strictly above the incumbent kills the whole
    subtree, *before* any journal or device replay of its tuples.  The
    incumbent is the min of ``incumbent_key`` (best-so-far inherited from
    the :class:`~repro.core.search_pool.ParallelSearchDriver` result
    stream) and the best candidate scored here.  Leaves are flushed
    through ``score_batch`` in ``batch_size`` chunks; because the
    incumbent can improve between enqueue and flush, each leaf remembers
    its deepest ancestor bound and the flush passes a ``skip`` mask for
    lanes that became prunable late -- so pruning composes with the
    batched scorer and the device replay instead of fighting them.

    Exactness (the repo's standing invariant): the bound is admissible
    (``prefix_bound``) and pruning requires *strictly* exceeding the
    incumbent, while every incumbent is a real candidate's key.  The
    product-order argmin -- the first tuple attaining the optimal key,
    which is also the ``(key, cuts)``-lexicographic optimum the parallel
    merge selects -- therefore can never be pruned: every ancestor bound
    of it is <= its own key <= every incumbent ever formed.  So the
    returned argmin and its metrics are bit-identical to the unpruned
    enumeration, for any incumbent timing, worker count, or resume
    schedule.  With ``prune=False`` the walk degenerates to exactly the
    chunked exhaustive enumeration (same ``score_batch`` calls in the
    same order, same ``engine.evaluations``).
    """
    nr = len(engine.runs)
    nr_pre = len(prefix)
    dims = [d + 1 for d in suffix_dims]
    nd = len(dims)
    ranges = [range(d) for d in dims]
    # subtree[j] = completions below a node with j suffix coords fixed
    subtree = [1] * (nd + 1)
    for j in range(nd - 1, -1, -1):
        subtree[j] = subtree[j + 1] * dims[j]
    # levels at or below which no bound check can fire -- their subtrees
    # enumerate in C through itertools.product instead of recursing
    can_check = [False] * (nd + 1)
    for j in range(nd - 1, -1, -1):
        here = (subtree[j + 1] >= prune_min_subtree
                and nr_pre + j + 1 < nr)
        can_check[j] = here or can_check[j + 1]

    best = None
    best_key = None
    inc = incumbent_key
    pruned = 0
    pend_t: list[tuple[int, ...]] = []
    pend_b: list = []               # deepest ancestor bound key per leaf
    bs = max(1, batch_size)

    def flush() -> None:
        nonlocal best, best_key, inc, pruned
        if not pend_t:
            return
        skip = None
        if prune and inc is not None:
            sk = [b is not None and b > inc for b in pend_b]
            n_skip = sum(sk)
            if n_skip:
                skip = sk
                pruned += n_skip
        for c in engine.score_batch(pend_t, memoize=False, skip=skip):
            if c is None:
                continue
            k = _key(c, objective)
            if best is None or k < best_key:
                best, best_key = c, k
                if inc is None or k < inc:
                    inc = k
        pend_t.clear()
        pend_b.clear()

    def enqueue_all(j: int, node: tuple[int, ...], bkey) -> None:
        # no bound can fire below this node: C-speed product enumeration
        for suffix in itertools.product(*ranges[j:]):
            pend_t.append(node + suffix)
            pend_b.append(bkey)
            if len(pend_t) >= bs:
                flush()

    def walk(j: int, node: tuple[int, ...], bkey) -> None:
        nonlocal pruned
        if j == nd:
            pend_t.append(node)
            pend_b.append(bkey)
            if len(pend_t) >= bs:
                flush()
            return
        if not prune or not can_check[j]:
            enqueue_all(j, node, bkey)
            return
        sub = subtree[j + 1]
        depth = nr_pre + j + 1
        check = sub >= prune_min_subtree and depth < nr
        for v in ranges[j]:
            child = node + (v,)
            ck = bkey
            if check and inc is not None:
                lb = engine.prefix_bound(
                    child + (0,) * (nr - len(child)), depth, objective)
                ck = (False, lb, 0)
                if ck > inc:
                    pruned += sub
                    continue
            walk(j + 1, child, ck)

    walk(0, tuple(prefix), None)
    flush()
    return best, pruned


def coordinate_descent(engine: "CutpointEngine", start: tuple[int, ...],
                       objective: str, on_eval=None,
                       batch_size: int = 1) -> CandidateMetrics:
    """One coordinate descent from ``start`` to its local optimum.

    The single definition of the descent trajectory -- move order, strict
    ``<`` improvement test, tie behavior -- shared by the serial loop in
    :func:`search` and the parallel per-start tasks in search_pool, whose
    bit-identity contract requires both to move in lock-step.  ``on_eval``
    (if given) observes every requested cut tuple; search_pool uses it to
    collect the visited set that reconstructs ``evaluated``.

    ``batch_size > 1`` pre-scores each coordinate sweep's trial tuples
    through ``score_batch`` (memoized) before the decision loop walks
    them.  The trajectory, the memo contents, the ``evaluations`` count
    and the ``on_eval`` sequence are unchanged: a sweep over run ``ri``
    only ever varies coordinate ``ri`` (so the trial set is known up
    front), and the one tuple the serial loop may skip -- the current
    point -- is always already memoized, so pre-scoring it costs no
    evaluation.
    """
    def ev(t: tuple[int, ...]) -> CandidateMetrics:
        if on_eval is not None:
            on_eval(t)
        return engine.evaluate(t)

    cuts = list(start)
    cur = ev(tuple(cuts))
    improved = True
    while improved:
        improved = False
        for ri, run in enumerate(engine.runs):
            scored: dict[tuple[int, ...], CandidateMetrics] | None = None
            if batch_size > 1:
                trials = [tuple(cuts[:ri] + [v] + cuts[ri + 1:])
                          for v in range(len(run) + 1)]
                scored = dict(zip(trials, engine.score_batch(trials)))
            for cand_cut in range(len(run) + 1):
                if cand_cut == cuts[ri]:
                    continue
                trial = list(cuts)
                trial[ri] = cand_cut
                if scored is not None:
                    if on_eval is not None:
                        on_eval(tuple(trial))
                    c = scored[tuple(trial)]
                else:
                    c = ev(tuple(trial))
                if _key(c, objective) < _key(cur, objective):
                    cur, cuts, improved = c, trial, True
    return cur


def descent_starts(blocks: list[Block],
                   runs: list[list[int]]) -> list[tuple[int, ...]]:
    """The three deterministic coordinate-descent start points: the exact
    all-row and all-frame policies (whose cut encoding depends on each
    run's direction) plus the run midpoints.  Shared by the serial loop
    below and the parallel per-start tasks in search_pool, which must use
    byte-identical starts."""
    all_row = tuple(len(r) if _run_direction(blocks, r) < 0 else 0
                    for r in runs)
    all_frame = tuple(0 if _run_direction(blocks, r) < 0 else len(r)
                      for r in runs)
    return [all_row, all_frame, tuple(len(r) // 2 for r in runs)]


def valid_warm_start(cuts, runs: list[list[int]]) -> tuple[int, ...] | None:
    """Validate a warm-start cut tuple against this graph's run structure.

    Warm starts come from the compile service's plan cache (the nearest
    cached plan of the same net family on a different hw config); they
    are best-effort, so an incompatible tuple -- wrong arity, or a cut
    past some run's length -- returns ``None`` instead of raising.
    """
    if cuts is None:
        return None
    cuts = tuple(int(c) for c in cuts)
    if len(cuts) != len(runs):
        return None
    if any(not 0 <= c <= len(r) for c, r in zip(cuts, runs)):
        return None
    return cuts


def search(gg: GroupedGraph, hw: FPGAConfig,
           options: CompileOptions | None = None,
           *, guard=None, warm_start=None, **legacy) -> SearchResult:
    """Find the best cut tuple for ``gg`` on ``hw``.

    All knobs arrive as one :class:`repro.core.options.CompileOptions`
    value -- see that class for the per-field reference (the single
    source of truth).  Loose keyword knobs (``workers=2`` etc.) still
    work through the deprecation shim but emit
    :class:`~repro.core.options.LegacyKnobWarning`.

    ``guard`` (a live :class:`~repro.runtime.fault_tolerance.\
PreemptionGuard` the pool polls for clean SIGTERM drain) and
    ``warm_start`` (a cut tuple from the service's plan cache) are not
    options: the former is a runtime object, the latter is derived
    per-request state.  On the exhaustive path a valid ``warm_start`` is
    scored through the direct oracle and seeds the branch-and-bound
    incumbent -- the result stays bit-identical to a cold search
    (including ``evaluated`` under the default ``count_pruned``
    accounting) because an incumbent that is a real candidate's key can
    never prune the product-order argmin.  On the coordinate-descent
    path it is appended as an extra deterministic start: the result can
    only improve, but ``evaluated`` (and, on ties, the argmin) may
    differ from a cold search -- which is why the service only promises
    hit/cold byte-identity for exhaustively-searched requests.

    Returns a :class:`SearchResult` whose ``best`` Candidate is
    materialized through the direct oracle, so it is exactly what the
    seed implementation produced for the same graph.
    """
    opts = resolve_options(options, legacy, site="search")
    if opts.workers is None or opts.workers > 1 or opts.resume_dir is not None:
        from repro.core.search_pool import ParallelSearchDriver
        with ParallelSearchDriver(workers=opts.workers,
                                  max_retries=opts.max_retries,
                                  task_deadline_s=opts.task_deadline_s,
                                  guard=guard) as driver:
            return driver.search(gg, hw, opts, warm_start=warm_start)

    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    engine = CutpointEngine(gg, hw, blocks, runs, backend=opts.backend,
                            engine=opts.engine)
    spec = opts.engine_spec()
    objective, batch_size = opts.objective, spec.batch_size

    def materialize(best: CandidateMetrics, pruned: int = 0,
                    path: str = "exhaustive") -> SearchResult:
        # Re-run the winner through the direct oracle so the returned
        # Candidate (policy, alloc, metrics) is exactly what the direct
        # search would have produced.
        cand = evaluate(gg, blocks, runs, best.cuts, hw)
        evaluated = engine.evaluations
        if opts.count_pruned:
            evaluated += pruned
        return SearchResult(best=cand, evaluated=evaluated,
                            runs=runs, blocks=blocks, pruned=pruned,
                            path=path)

    ws = valid_warm_start(warm_start, runs)
    if space <= opts.exhaustive_limit:
        if space > 1_000_000 and not opts.prune:
            warnings.warn(
                f"exhaustive cut search over {space} tuples on a single "
                f"core (~{space / 40_000 / 60:.0f} min); pass "
                f"CompileOptions(workers=N) to search()/compile_graph() "
                f"for a bit-identical result in 1/N the time, or lower "
                f"exhaustive_limit to fall back to coordinate descent",
                RuntimeWarning, stacklevel=2)
        # Warm start: price the cached cuts through the direct oracle
        # (not the engine, so ``evaluations`` bookkeeping is untouched)
        # and open branch-and-bound with that real candidate's key as
        # the incumbent.  Admissibility + strict-> pruning guarantee the
        # argmin still survives, so the result is bit-identical to a
        # cold search -- the warm start only prunes more, earlier.
        incumbent = None
        if ws is not None and opts.prune:
            incumbent = _key(evaluate(gg, blocks, runs, ws, hw), objective)
        # product order: the last run varies fastest, so consecutive tuples
        # share the longest possible checkpoint prefix; with prune=True
        # whole sub-spaces fall to the incumbent bound instead of being
        # walked at all.  The pipeline engine instead fuses the whole loop
        # on device (sharded over accelerators when more than one is
        # visible) -- see run_subspace / kernels/search_pipeline.py.
        best, pruned = engine.run_subspace(
            (), [len(r) for r in runs], objective,
            batch_size=batch_size, incumbent_key=incumbent,
            prune=opts.prune)
        # never all-pruned: any external incumbent is a candidate *inside*
        # this space, whose own subtree no admissible bound can eliminate
        assert best is not None
        return materialize(best, pruned)

    # Coordinate descent with deterministic restarts (descent_starts).
    # Move order matches the seed implementation exactly (same trajectory,
    # same answer); the engine's memo absorbs the tuples revisited across
    # sweeps and restarts, and trials for a given run reuse the shared
    # allocation prefix of all earlier runs.
    starts = descent_starts(blocks, runs)
    if ws is not None and ws not in starts:
        starts.append(ws)           # appended: ties still favor the cold
        #                             starts, a warm start only ever wins
        #                             by a strictly better key
    best = None
    for start in starts:
        cur = coordinate_descent(engine, start, objective,
                                 batch_size=batch_size)
        if best is None or _key(cur, objective) < _key(best, objective):
            best = cur
    assert best is not None
    return materialize(best, path="descent")


def sweep_single_cut(gg: GroupedGraph, hw: FPGAConfig) -> list[Candidate]:
    """Fig. 16/17: metrics vs the position of a single global cut-point:
    blocks < L row-reuse, >= L frame-reuse."""
    blocks = split_blocks(gg)
    out = []
    for L in range(len(blocks) + 1):
        policy: Policy = {}
        for b in blocks:
            mode = "row" if b.bid < L else "frame"
            for gid in b.gids:
                policy[gid] = mode
        alloc = allocate(gg, policy)
        sram = sram_report(gg, alloc, hw)
        dram = dram_report(gg, alloc)
        lat = latency_report(gg, alloc, hw)
        out.append(Candidate(
            cuts=(L,), policy=policy, alloc=alloc,
            latency_cycles=lat.cycles, dram_total=dram.total,
            dram_fm=dram.fm_bytes, sram_total=sram.sram_total,
            bram18k=sram.bram18k,
            feasible=(sram.sram_total <= hw.sram_budget
                      and frame_feasible(gg, policy, alloc))))
    return out
