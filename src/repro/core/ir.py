"""Graph IR for the ShortcutFusion compiler.

A :class:`Graph` is a topologically-ordered list of :class:`LayerNode`.
Nodes are deliberately close to the paper's abstraction level (Fig. 5):
convolutions carry their fused BatchNorm/activation; pooling, element-wise
(shortcut) addition, concatenation, up-sampling and SE-scale ops are explicit
nodes that the grouping pass (grouping.py) fuses into instruction groups.

Sizes follow the paper's conventions: 8-bit activations (Q_A = 1 byte),
8-bit weights, 32-bit partial sums (Q_S = 4 bytes) unless overridden.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterator

# Node kinds understood by the compiler.
CONV_KINDS = ("conv", "dwconv", "fc")
MEMORY_KINDS = ("add", "concat", "route", "upsample", "maxpool", "avgpool",
                "globalpool", "scale", "input", "output")
ALL_KINDS = CONV_KINDS + MEMORY_KINDS


@dataclass
class LayerNode:
    idx: int
    kind: str
    name: str = ""
    # Spatial geometry.  For fc layers h = w = 1.
    in_ch: int = 0
    out_ch: int = 0
    in_h: int = 0
    in_w: int = 0
    out_h: int = 0
    out_w: int = 0
    k: int = 1                      # kernel size (k x k)
    stride: int = 1
    groups: int = 1                 # ==in_ch for depthwise
    act: str = "linear"             # relu / leaky / swish / sigmoid / linear
    # Graph edges: indices of producer nodes.  inputs[0] is the main path;
    # for `add` nodes inputs[1] is the shortcut operand.
    inputs: list[int] = field(default_factory=list)
    # Fusion hints (set by zoo builders, consumed by grouping).
    fused_pool: int = 1             # 2 => fused 2x2 maxpool after conv
    # Quantization widths, bytes.
    qa: int = 1                     # activation width
    qw: int = 1                     # weight width
    qs: int = 4                     # partial-sum width

    # ------------------------------------------------------------------ sizes
    @property
    def in_size(self) -> int:
        """Input feature-map bytes (main path)."""
        return self.in_h * self.in_w * self.in_ch * self.qa

    @property
    def out_size(self) -> int:
        return self.out_h * self.out_w * self.out_ch * self.qa

    @property
    def weight_size(self) -> int:
        if self.kind == "conv":
            return self.k * self.k * self.in_ch * self.out_ch * self.qw // self.groups
        if self.kind == "dwconv":
            return self.k * self.k * self.in_ch * self.qw
        if self.kind == "fc":
            return self.in_ch * self.out_ch * self.qw
        if self.kind == "scale":        # SE scale: per-channel weights come
            return 0                    # from the FC side path, counted there
        return 0

    @property
    def macs(self) -> int:
        """Multiply-accumulate count."""
        if self.kind == "conv":
            return (self.k * self.k * self.in_ch * self.out_ch
                    * self.out_h * self.out_w) // self.groups
        if self.kind == "dwconv":
            return self.k * self.k * self.in_ch * self.out_h * self.out_w
        if self.kind == "fc":
            return self.in_ch * self.out_ch
        if self.kind == "scale":
            return self.out_h * self.out_w * self.out_ch
        return 0

    @property
    def is_compute(self) -> bool:
        return self.kind in CONV_KINDS

    def clone(self, **kw) -> "LayerNode":
        return dataclasses.replace(self, **kw)


@dataclass
class Graph:
    name: str
    nodes: list[LayerNode] = field(default_factory=list)

    # ------------------------------------------------------------- building
    def add(self, kind: str, **kw) -> LayerNode:
        if kind not in ALL_KINDS:
            raise ValueError(f"unknown node kind {kind!r}")
        idx = len(self.nodes)
        if "inputs" not in kw and idx > 0:
            kw["inputs"] = [idx - 1]
        node = LayerNode(idx=idx, kind=kind, **kw)
        # Geometry inference from the main producer when not given.
        if node.inputs and node.in_h == 0:
            p = self.nodes[node.inputs[0]]
            node.in_h, node.in_w, node.in_ch = p.out_h, p.out_w, p.out_ch
        if node.out_h == 0:
            node.out_h = max(1, node.in_h // node.stride)
            node.out_w = max(1, node.in_w // node.stride)
        if node.out_ch == 0:
            node.out_ch = node.in_ch
        if node.kind == "dwconv":
            node.groups = node.in_ch
            node.out_ch = node.in_ch
        if node.kind == "globalpool":
            node.out_h = node.out_w = 1
        if node.kind == "concat":
            node.out_ch = sum(self.nodes[i].out_ch for i in node.inputs)
        if node.kind == "add":
            a = self.nodes[node.inputs[0]]
            node.out_h, node.out_w, node.out_ch = a.out_h, a.out_w, a.out_ch
        if node.kind == "upsample":
            node.out_h, node.out_w = node.in_h * node.stride, node.in_w * node.stride
        self.nodes.append(node)
        return node

    # -------------------------------------------------------------- queries
    def __iter__(self) -> Iterator[LayerNode]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)

    def consumers(self, idx: int) -> list[LayerNode]:
        return [n for n in self.nodes if idx in n.inputs]

    def to_residual(self, idx: int) -> bool:
        """True iff node idx's output is the *shortcut* operand of a later add
        (i.e. it is consumed by an `add` node that is not its direct
        successor) -- Algorithm 1's ``to_residual``."""
        for n in self.nodes:
            if n.kind == "add" and len(n.inputs) > 1 and idx in n.inputs[1:]:
                return True
        return False

    def shortcut_span(self, idx: int) -> int:
        """Distance (in nodes) the shortcut produced at idx must stay alive."""
        spans = [n.idx - idx for n in self.nodes
                 if n.kind == "add" and len(n.inputs) > 1 and idx in n.inputs[1:]]
        return max(spans, default=0)

    def total_weight_bytes(self) -> int:
        return sum(n.weight_size for n in self.nodes)

    def total_macs(self) -> int:
        return sum(n.macs for n in self.nodes)

    def conv_layers(self) -> list[LayerNode]:
        return [n for n in self.nodes if n.is_compute]

    def validate(self) -> None:
        for n in self.nodes:
            for i in n.inputs:
                if not (0 <= i < n.idx):
                    raise ValueError(
                        f"node {n.idx} ({n.name}) has non-topological input {i}")
            if n.kind == "add" and len(n.inputs) < 2:
                raise ValueError(f"add node {n.idx} needs >=2 inputs")
        if self.nodes and self.nodes[0].kind != "input":
            raise ValueError("graph must start with an input node")


def make_input(g: Graph, h: int, w: int, ch: int = 3, qa: int = 1) -> LayerNode:
    return g.add("input", inputs=[], in_h=h, in_w=w, in_ch=ch,
                 out_h=h, out_w=w, out_ch=ch, qa=qa)
