"""Hardware descriptions for the two back-ends the compiler targets.

``FPGAConfig`` models the paper's KCU1500 accelerator (§III-B, §V) and is
used for the faithful reproduction of Tables II-VII.  ``TPUConfig`` models a
TPU v5e chip and is used by the LM residency planner (core/residency.py) and
by the roofline harness.
"""
from __future__ import annotations

from dataclasses import dataclass

MB = 1 << 20
GB = 1 << 30


@dataclass(frozen=True)
class FPGAConfig:
    """KCU1500 accelerator parameters (paper §III-B / Table V)."""
    name: str = "kcu1500"
    freq: float = 200e6                  # Hz
    # Shared MAC array: 2048 MACs -> 4096 mult/cycle normal conv (double
    # INT8 per DSP), 2048 mult/cycle depthwise (no input sharing).
    mults_normal: int = 4096
    mults_dw: int = 2048
    ti: int = 64                         # input-channel parallelism
    to: int = 64                         # output-channel parallelism
    # Effective DRAM bandwidth calibrated against Table V latencies (the
    # paper's own numbers imply ~2.7-4 GB/s effective single-bank access).
    dram_bw: float = 4.0e9               # bytes/s effective
    bram18k_total: int = 4320
    sram_budget: int = 9 * MB            # raw SRAM ceiling (~BRAM capacity)
    group_overhead_cycles: int = 256     # per-group instruction dispatch

    @property
    def peak_gops(self) -> float:
        """INT8 ops/s: each mult+add pair = 2 ops."""
        return 2.0 * self.mults_normal * self.freq

    @property
    def dram_bytes_per_cycle(self) -> float:
        return self.dram_bw / self.freq


@dataclass(frozen=True)
class TPUConfig:
    """TPU v5e per-chip constants (roofline + residency planning)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12           # bf16 FLOP/s
    hbm_bw: float = 819e9                # bytes/s
    ici_bw: float = 50e9                 # bytes/s per link
    vmem_bytes: int = 128 * MB
    hbm_bytes: int = 16 * GB
    # MXU tiling granularity.
    lane: int = 128
    sublane: int = 8


V5E = TPUConfig()
KCU1500 = FPGAConfig()
