"""Parallel cut-space search pool (ROADMAP: parallel candidate evaluation).

The cut-point optimizer's exhaustive path walks the cross-product of cut
positions, one per monotone run (see cutpoint.py).  PR 1 made a single
candidate cheap (:class:`~repro.core.cutpoint.CutpointEngine`); the wall
clock is now dominated by the sheer size of the product space -- yolov2
alone is ~7.9M tuples.  :class:`ParallelSearchDriver` farms that space out
to a ``multiprocessing`` worker pool:

* **Partitioning** -- the product space is split into disjoint sub-spaces
  along the *leading* monotone-run axes: the smallest prefix of runs whose
  dimension product reaches ``~8 tasks per worker`` is enumerated in the
  parent, and each resulting prefix tuple becomes one task covering
  ``prefix x product(remaining runs)``.  Every task therefore has exactly
  the same size (uniform load) and walks its suffix in ``itertools.product``
  order, so within a task consecutive tuples still share the longest
  possible allocator-checkpoint prefix.
* **Per-worker engines** -- each worker process builds its own
  ``CutpointEngine`` for the (graph, hardware) pair, once per search, and
  keeps it across all tasks of that search, scoring its sub-space in
  ``score_batch`` chunks (the ``batch_size`` knob; per-tuple at
  ``batch_size=1``) so the mask-matrix batching and the process-level
  parallelism compose.  Engine checkpoints are per-prefix state, so
  workers share nothing and need no synchronisation.
  The graph is *serialized* once per search; the resulting ``bytes`` ride
  along with every task (a per-task pipe copy of tens of KB -- negligible
  next to the sub-space walk), and workers deserialize it only when their
  cached engine token changes, i.e. once per search.
* **Deterministic merge** -- each task returns its sub-space argmin as a
  :class:`~repro.core.cutpoint.CandidateMetrics`.  The parent reduces them
  with the key ``(objective key, cut tuple)``.  Serial ``search`` keeps the
  *first* optimum in product order, and product order over ``range`` axes
  *is* lexicographic order of the tuples, so this merge reproduces the
  serial winner bit-for-bit -- same cuts, same metrics, same
  ``SearchResult.evaluated`` -- regardless of worker count or scheduling.

When the space exceeds ``exhaustive_limit`` the serial fallback is
coordinate descent from three deterministic starts; the pool then runs one
*start* per task.  A start's trajectory depends only on exact candidate
values (never on the shared memo, which only short-circuits re-evaluation),
so per-start results are identical to serial, ties between starts break by
start order exactly as the serial loop's strict ``<`` does, and
``evaluated`` is recovered as the size of the union of the per-start
visited-tuple sets -- the same count the serial shared-memo engine reports.

The pool is generic: :meth:`ParallelSearchDriver.map` exposes it for any
embarrassingly-parallel loop (``benchmarks/residency_lm.py`` uses it for
per-arch/per-shape residency planning).

Failure semantics: an exception raised inside a worker (e.g. an invalid
``objective``) propagates to the caller unchanged, exactly as the serial
path would raise it; a worker process that dies outright surfaces as a
``RuntimeError`` naming the crashed pool rather than a hang.
"""
from __future__ import annotations

import itertools
import multiprocessing as mp
import os
import pickle
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool

from repro.core import cutpoint as _cp

# Sub-space tasks created per worker on the exhaustive path.  More tasks
# than workers smooths the tail (tasks are equal-sized, but workers may not
# be equally fast); the per-task cost is one small pickle round-trip.
TASKS_PER_WORKER = 8

# Below this many tuples the pool's fixed costs (process startup, one
# engine build per worker) exceed the search itself; the driver silently
# runs the serial path, which is bit-identical anyway.
MIN_PARALLEL_SPACE = 4096


# ---------------------------------------------------------- worker globals
# One engine per worker process, rebuilt when the search token changes.  A
# fresh token per `ParallelSearchDriver.search` call keeps the engine's memo
# in the exact state the serial implementation's fresh engine has, which is
# what makes `evaluated` (a cache-miss count) reproducible.
_ENGINE_TOKEN: tuple | None = None
_ENGINE: "_cp.CutpointEngine | None" = None

# Test hook (tests/test_search_pool.py): set to "raise" / "exit" in the
# parent before the pool is created; fork-started workers inherit it.
_TEST_FAIL_HOOK: str | None = None


def _worker_engine(token: tuple, payload: bytes,
                   replay: str = "journal") -> "_cp.CutpointEngine":
    global _ENGINE_TOKEN, _ENGINE
    if token != _ENGINE_TOKEN:
        gg, hw = pickle.loads(payload)
        _ENGINE = _cp.CutpointEngine(gg, hw, replay=replay)
        _ENGINE_TOKEN = token
    return _ENGINE


def _maybe_fail() -> None:
    if _TEST_FAIL_HOOK == "raise":
        raise RuntimeError("search_pool test hook: simulated worker failure")
    if _TEST_FAIL_HOOK == "exit":          # hard crash, no exception
        os._exit(3)


def _run_subspace(task) -> tuple["_cp.CandidateMetrics", int]:
    """Evaluate ``prefix x product(suffix_dims)``; return (argmin, #evals).

    Ties keep the first optimum in product order, as serial search does.
    ``batch_size > 1`` walks the sub-space in ``score_batch`` chunks (the
    production path); the argmin and the evaluation count are identical
    either way.
    """
    token, payload, prefix, suffix_dims, objective, batch_size, replay = task
    _maybe_fail()
    engine = _worker_engine(token, payload, replay)
    before = engine.evaluations
    best = None
    tuples = (prefix + suffix for suffix in
              itertools.product(*[range(d + 1) for d in suffix_dims]))
    if batch_size > 1:
        while True:
            chunk = list(itertools.islice(tuples, batch_size))
            if not chunk:
                break
            for c in engine.score_batch(chunk, memoize=False):
                if best is None or (_cp._key(c, objective)
                                    < _cp._key(best, objective)):
                    best = c
    else:
        for cuts in tuples:
            c = engine.evaluate(cuts, memoize=False)
            if best is None or (_cp._key(c, objective)
                                < _cp._key(best, objective)):
                best = c
    return best, engine.evaluations - before


def _run_descent(task) -> tuple["_cp.CandidateMetrics", frozenset]:
    """One coordinate-descent start; returns (final point, visited tuples).

    Runs ``cutpoint.coordinate_descent`` itself -- the one definition of
    the descent trajectory -- so the returned point is the one the serial
    loop reaches from this start, by construction.
    """
    token, payload, start, objective, batch_size, replay = task
    _maybe_fail()
    engine = _worker_engine(token, payload, replay)
    visited: set[tuple[int, ...]] = set()
    cur = _cp.coordinate_descent(engine, start, objective,
                                 on_eval=visited.add, batch_size=batch_size)
    return cur, frozenset(visited)


def partition_space(runs: list[list[int]],
                    target_tasks: int) -> tuple[list[tuple[int, ...]],
                                                list[int]]:
    """Split the cut product space along the leading monotone-run axes.

    Takes the smallest ``k`` such that the first ``k`` axes enumerate at
    least ``target_tasks`` prefixes (or all axes, for small spaces) and
    returns ``(prefixes, suffix_dims)``: every ``prefix x
    product(range(d+1) for d in suffix_dims)`` is one equal-sized, disjoint
    sub-space, and concatenating them in prefix order reproduces the full
    product enumeration order.
    """
    k, tasks = 0, 1
    while k < len(runs) and tasks < target_tasks:
        tasks *= len(runs[k]) + 1
        k += 1
    prefixes = list(itertools.product(*[range(len(r) + 1)
                                        for r in runs[:k]]))
    suffix_dims = [len(r) for r in runs[k:]]
    return prefixes, suffix_dims


class ParallelSearchDriver:
    """Persistent worker pool for cut-space search and generic fan-out.

    Parameters
    ----------
    workers:
        Worker process count; ``None`` means ``os.cpu_count()``.
    mp_context:
        ``multiprocessing`` start method.  Default: ``"fork"`` where
        available (workers inherit the parent's imports, so startup is
        milliseconds), else the platform default.

    The pool is created lazily on first use and reused across calls; use
    the driver as a context manager (or call :meth:`close`) to reap the
    worker processes deterministically.
    """

    def __init__(self, workers: int | None = None,
                 mp_context: str | None = None):
        self.workers = max(1, workers or os.cpu_count() or 1)
        if mp_context is None and "fork" in mp.get_all_start_methods():
            mp_context = "fork"
        self._ctx = mp.get_context(mp_context) if mp_context else None
        self._pool: ProcessPoolExecutor | None = None
        self._searches = 0

    # ------------------------------------------------------------- plumbing
    def _executor(self) -> ProcessPoolExecutor:
        if self._pool is None:
            self._pool = ProcessPoolExecutor(max_workers=self.workers,
                                             mp_context=self._ctx)
        return self._pool

    def map(self, fn, items, chunksize: int = 1) -> list:
        """Ordered parallel map (the generic face of the pool).

        ``fn`` must be a module-level callable; results come back in input
        order.  Worker exceptions propagate; a dead worker process raises
        ``RuntimeError`` instead of hanging the caller.
        """
        try:
            return list(self._executor().map(fn, items, chunksize=chunksize))
        except BrokenProcessPool as e:
            self._reset()
            raise RuntimeError(
                f"search-pool worker process died (workers={self.workers}); "
                f"the pool has been discarded") from e

    def _reset(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ParallelSearchDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------------- search
    def search(self, gg, hw, objective: str = "latency",
               exhaustive_limit: int | None = None,
               min_parallel_space: int = MIN_PARALLEL_SPACE,
               batch_size: int | None = None,
               replay: str = "journal"):
        """Parallel ``cutpoint.search``, bit-identical to the serial result.

        Same knobs as :func:`repro.core.cutpoint.search` (including
        ``batch_size``, which each worker forwards to
        ``CutpointEngine.score_batch`` over its own sub-space, and
        ``replay``, which selects the journal vs device allocator replay
        inside each worker's engine); additionally ``min_parallel_space``
        sets the space size below which the serial path runs directly
        (the result is identical either way -- this is purely a
        fixed-cost cutoff).
        """
        if exhaustive_limit is None:
            exhaustive_limit = _cp.EXHAUSTIVE_LIMIT
        if batch_size is None:
            batch_size = _cp.DEFAULT_BATCH_SIZE
        blocks = _cp.split_blocks(gg)
        runs = _cp.monotone_runs(blocks)
        space = 1
        for r in runs:
            space *= len(r) + 1
        exhaustive = space <= exhaustive_limit
        if (self.workers <= 1 or not runs
                or (exhaustive and space < min_parallel_space)):
            return _cp.search(gg, hw, objective=objective,
                              exhaustive_limit=exhaustive_limit,
                              batch_size=batch_size, replay=replay)

        self._searches += 1
        token = (os.getpid(), id(self), self._searches, replay)
        payload = pickle.dumps((gg, hw), protocol=pickle.HIGHEST_PROTOCOL)

        if exhaustive:
            prefixes, suffix_dims = partition_space(
                runs, self.workers * TASKS_PER_WORKER)
            tasks = [(token, payload, p, suffix_dims, objective, batch_size,
                      replay) for p in prefixes]
            results = self.map(_run_subspace, tasks)
            evaluated = sum(n for _, n in results)
            # (objective key, cut tuple) == first optimum in product order.
            best = min((m for m, _ in results),
                       key=lambda m: (_cp._key(m, objective), m.cuts))
        else:
            starts = _cp.descent_starts(blocks, runs)
            tasks = [(token, payload, s, objective, batch_size, replay)
                     for s in starts]
            results = self.map(_run_descent, tasks)
            visited: set = set()
            best = None
            for m, seen in results:             # start order; strict < as
                visited |= seen                 # the serial loop over starts
                if best is None or (_cp._key(m, objective)
                                    < _cp._key(best, objective)):
                    best = m
            evaluated = len(visited)

        cand = _cp.evaluate(gg, blocks, runs, best.cuts, hw)
        return _cp.SearchResult(best=cand, evaluated=evaluated,
                                runs=runs, blocks=blocks)
