"""Parallel cut-space search pool with a fault-tolerant runtime.

The cut-point optimizer's exhaustive path walks the cross-product of cut
positions, one per monotone run (see cutpoint.py).  PR 1 made a single
candidate cheap (:class:`~repro.core.cutpoint.CutpointEngine`); the wall
clock is now dominated by the sheer size of the product space -- yolov2
alone is ~7.9M tuples.  :class:`ParallelSearchDriver` farms that space out
to a ``multiprocessing`` worker pool:

* **Partitioning** -- the product space is split into disjoint sub-spaces
  along the *leading* monotone-run axes: the smallest prefix of runs whose
  dimension product reaches ``~8 tasks per worker`` is enumerated in the
  parent, and each resulting prefix tuple becomes one task covering
  ``prefix x product(remaining runs)``.  Every task therefore has exactly
  the same size (uniform load) and walks its suffix in ``itertools.product``
  order, so within a task consecutive tuples still share the longest
  possible allocator-checkpoint prefix.
* **Per-worker engines** -- each worker process builds its own
  ``CutpointEngine`` for the (graph, hardware) pair, once per search, and
  keeps it across all tasks of that search, scoring its sub-space in
  ``score_batch`` chunks (the ``batch_size`` knob; per-tuple at
  ``batch_size=1``) so the mask-matrix batching and the process-level
  parallelism compose.  Engine checkpoints are per-prefix state, so
  workers share nothing and need no synchronisation.
  The graph is *serialized* once per search; the resulting ``bytes`` ride
  along with every task (a per-task pipe copy of tens of KB -- negligible
  next to the sub-space walk), and workers deserialize it only when their
  cached engine token changes, i.e. once per search.
* **Deterministic merge** -- each task returns its sub-space argmin as a
  :class:`~repro.core.cutpoint.CandidateMetrics`.  The parent reduces them
  with the key ``(objective key, cut tuple)``.  Serial ``search`` keeps the
  *first* optimum in product order, and product order over ``range`` axes
  *is* lexicographic order of the tuples, so this merge reproduces the
  serial winner bit-for-bit -- same cuts, same metrics, same
  ``SearchResult.evaluated`` -- regardless of worker count or scheduling.

When the space exceeds ``exhaustive_limit`` the serial fallback is
coordinate descent from three deterministic starts; the pool then runs one
*start* per task.  A start's trajectory depends only on exact candidate
values (never on the shared memo, which only short-circuits re-evaluation),
so per-start results are identical to serial, ties between starts break by
start order exactly as the serial loop's strict ``<`` does, and
``evaluated`` is recovered as the size of the union of the per-start
visited-tuple sets -- the same count the serial shared-memo engine reports.

The pool is generic: :meth:`ParallelSearchDriver.map` exposes it for any
embarrassingly-parallel loop (``benchmarks/residency_lm.py`` uses it for
per-arch/per-shape residency planning).

Failure semantics (the fault-tolerant runtime)
----------------------------------------------

Task results are pure functions of ``(token, sub-space)``, which is what
makes every recovery action below *safe*: re-running a task, racing a
duplicate against a straggler, or replaying a journaled result can never
change the deterministic merge.  The dispatch loop distinguishes four
failure classes:

* **Deterministic worker exceptions** (an invalid ``objective``, a bug)
  propagate to the caller unchanged, exactly as the serial path would
  raise them -- retrying a deterministic error would fail identically.
* **Lost tasks** -- a worker process dying outright (OOM kill, signal,
  ``os._exit``) breaks the whole ``ProcessPoolExecutor``.  The driver
  identifies the in-flight tasks (completed results are kept), discards
  and rebuilds the pool, and re-dispatches only the lost tasks, each with
  bounded attempts (the ``max_retries`` knob, default 2).  A task that
  keeps dying exhausts its attempts and raises ``RuntimeError`` -- never
  a hang, never a silently partial result.  Injected transient failures
  (:class:`repro.runtime.chaos.ChaosError`, ``transient = True``) are
  retried under the same bound without killing the pool.
* **Stragglers / deadlines** -- with ``task_deadline_s`` set, a task
  running past its deadline (tightened by a task-grain EWMA once enough
  tasks have completed -- ``StragglerMonitor.straggler_after``) gets one
  speculative duplicate re-dispatched; first completion wins, and the
  duplicate is degraded through
  :func:`repro.core.options.degrade_engine` to the ``"journal"`` engine
  so a hanging device backend cannot hang its own rescue.
* **Device-engine degradation** -- a worker whose ``engine="device"``
  (or ``"pipeline"``) scoring raises falls back to the journal engine
  *inside the task* and reports a ``device_fallback`` event; results are
  bit-identical by the engine contract, so degradation is logged, never
  silent.

Every recovery is surfaced as a :class:`FaultEvent` on
``SearchResult.events`` (retry / straggler / device_fallback / resume) --
the result says not just *what* won but *what it survived*.

Checkpointed resume: with ``resume_dir`` set, every completed task's
result is committed to a :class:`repro.checkpoint.checkpoint.TaskJournal`
(atomic rename + digest, keyed by a content hash of graph/hw payload +
``CompileOptions.plan_key()`` + partition -- never scheduling-only
knobs), journaled tasks are skipped on the next run with identical
merged results (including ``evaluated``), and a
:class:`~repro.runtime.fault_tolerance.PreemptionGuard` wired into the
driver (the ``guard`` knob) drains in-flight tasks on SIGTERM, journals
them, and raises :class:`SearchPreempted` -- a preempted compile resumes
losing at most the tasks that were still in flight.  A corrupt journal
record raises ``JournalError`` instead of resuming from damaged state.

All failure paths are exercised deterministically by the seeded
fault-injection harness in ``runtime/chaos.py``
(tests/test_fault_tolerance.py, ``compile_throughput.py --chaos``).
"""
from __future__ import annotations

import hashlib
import itertools
import multiprocessing as mp
import os
import pickle
import sys
import time
import warnings
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass

from repro.core import cutpoint as _cp
from repro.core.options import degrade_engine, resolve_engine
from repro.runtime import chaos as _chaos
from repro.runtime.fault_tolerance import PreemptionGuard, StragglerMonitor

# Sub-space tasks created per worker on the exhaustive path.  More tasks
# than workers smooths the tail (tasks are equal-sized, but workers may not
# be equally fast); the per-task cost is one small pickle round-trip.
TASKS_PER_WORKER = 8

# Below this many tuples the pool's fixed costs (process startup, one
# engine build per worker) exceed the search itself; the driver silently
# runs the serial path, which is bit-identical anyway.  (With
# ``resume_dir`` set the partitioned path always runs, so even small
# compiles journal at task granularity.)
MIN_PARALLEL_SPACE = 4096

# Dispatch-loop poll period: the granularity of preemption checks and
# deadline/straggler sweeps while waiting on in-flight futures.
_TICK_S = 0.05


class SearchPreempted(RuntimeError):
    """Raised by the dispatch loop after a clean preemption drain: no new
    tasks were started, in-flight tasks were awaited and journaled (when a
    journal is open), and the compile can resume from ``resume_dir``."""


@dataclass(frozen=True)
class FaultEvent:
    """One recovery action taken by the fault-tolerant dispatch loop,
    surfaced on ``SearchResult.events`` rather than silently absorbed."""

    kind: str            # "retry" | "straggler" | "device_fallback" |
    #                      "resume" | "preempted"
    task: object = None  # task identity (sub-space prefix / descent start)
    attempt: int = 0
    detail: str = ""


# ---------------------------------------------------------- worker globals
# Engines per worker process, keyed by (search token, engine spelling,
# scoring backend) -- rebuilt when the token changes (a fresh token per
# driver search keeps each engine's memo in the exact state the serial
# implementation's fresh engine has, which is what makes `evaluated` -- a
# cache-miss count -- reproducible).  The engine key exists because a
# device/pipeline task that degrades mid-search needs a *separate*
# journal-engine instance.
_ENGINES: dict = {}

# Legacy test hook (predates runtime/chaos.py): set to "raise" / "exit" in
# the parent before the pool is created; fork-started workers inherit it.
# New code should install a seeded ChaosInjector instead.
_TEST_FAIL_HOOK: str | None = None


def _worker_engine(token: tuple, payload: bytes,
                   engine_spec: str = "journal",
                   backend: str = "numpy") -> "_cp.CutpointEngine":
    key = (token, engine_spec, backend)
    engine = _ENGINES.get(key)
    if engine is None:
        # a new search token invalidates engines of previous searches
        for old in [k for k in _ENGINES if k[0] != token]:
            del _ENGINES[old]
        gg, hw = pickle.loads(payload)
        engine = _ENGINES[key] = _cp.CutpointEngine(gg, hw, backend=backend,
                                                    engine=engine_spec)
    return engine


def _engine_needs_jax(spec) -> bool:
    """Whether worker processes will execute jax for this engine spec.

    Exactly the variants whose scoring path jits: the journal engine and
    the numpy reference variants never import jax in the worker."""
    return ((spec.name == "pipeline" and spec.variant in ("lax", "pallas"))
            or (spec.name == "device" and spec.variant in ("scan", "pallas")))


def _spawn_main_viable() -> bool:
    """Whether spawn-started workers can initialize.

    ``multiprocessing``'s spawn path re-imports the parent's ``__main__``
    in the child (unless the parent is ``python -c``/embedded, where it
    skips the step).  A parent fed from stdin records ``<stdin>`` as its
    main path, which the child then fails to open -- every worker dies at
    startup.  Detect that corner so the caller can degrade gracefully."""
    main = sys.modules.get("__main__")
    if main is None or getattr(getattr(main, "__spec__", None),
                               "name", None):
        return True                      # python -m style: import by name
    if sys.argv[0] in ("", "-c"):
        return True                      # spawn skips main re-import
    path = getattr(main, "__file__", None)
    return path is None or os.path.exists(path)


def _maybe_fail(key, attempt: int = 0) -> None:
    """Worker-side injection site at task start: the legacy string hook
    plus the seeded chaos injector (site ``"task"``, keyed by the task's
    identity so faults are scheduling-independent)."""
    if _TEST_FAIL_HOOK == "raise":
        raise RuntimeError("search_pool test hook: simulated worker failure")
    if _TEST_FAIL_HOOK == "exit":          # hard crash, no exception
        os._exit(3)
    _chaos.maybe_fire("task", key, attempt)


def _run_subspace(task, attempt: int = 0):
    """Evaluate ``prefix x product(suffix_dims)``.

    Returns ``(argmin CandidateMetrics, #evals, #pruned, worker
    events)``.  Ties keep the first optimum in product order, as serial
    search does.  ``batch_size > 1`` walks the sub-space in
    ``score_batch`` chunks (the production path); the argmin and the
    evaluation count are identical either way.  With ``prune`` on (task
    field 8) and an inherited incumbent key (field 9), whole sub-trees
    whose admissible bound exceeds the incumbent are skipped before any
    replay; the argmin is ``None`` only when the *entire* task falls to
    the incumbent, which is safe because the global optimum's own task
    can never prune it (its bound never exceeds any incumbent).  A
    failing device/pipeline engine degrades to the journal engine
    in-task (bit-identical by contract) and reports a
    ``device_fallback`` event instead of failing the task.
    """
    (token, payload, prefix, suffix_dims, objective, batch_size, engine_spec,
     backend) = task[:8]
    prune = task[8] if len(task) > 8 else False
    incumbent = task[9] if len(task) > 9 else None
    _maybe_fail(prefix, attempt)
    engine_name = resolve_engine(engine_spec).name

    def score(engine):
        before = engine.evaluations
        best, pruned = engine.run_subspace(
            prefix, list(suffix_dims), objective,
            batch_size=batch_size, incumbent_key=incumbent, prune=prune)
        return best, engine.evaluations - before, pruned

    events: tuple = ()
    try:
        engine = _worker_engine(token, payload, engine_spec, backend)
        if engine_name != "journal":
            # chaos site for injected backend failures (tests/benchmarks)
            _chaos.maybe_fire("device", prefix, attempt)
        best, n, pruned = score(engine)
    except Exception as e:
        if engine_name == "journal":
            raise
        # device/pipeline engine raised: degrade to the journal engine --
        # logged, never silent, and bit-identical by the engine contract
        engine = _worker_engine(token, payload, degrade_engine(engine_spec),
                                backend)
        best, n, pruned = score(engine)
        events = (("device_fallback",
                   f"{engine_name} engine failed ({e!r}); "
                   f"journal engine substituted"),)
    return best, n, pruned, events


def _run_descent(task, attempt: int = 0):
    """One coordinate-descent start.

    Returns ``(final CandidateMetrics, visited frozenset, worker
    events)``.  Runs ``cutpoint.coordinate_descent`` itself -- the one
    definition of the descent trajectory -- so the returned point is the
    one the serial loop reaches from this start, by construction.  Engine
    degradation mirrors ``_run_subspace``.
    """
    token, payload, start, objective, batch_size, engine_spec, backend = task
    _maybe_fail(start, attempt)
    engine_name = resolve_engine(engine_spec).name

    def run(engine):
        visited: set[tuple[int, ...]] = set()
        cur = _cp.coordinate_descent(engine, start, objective,
                                     on_eval=visited.add,
                                     batch_size=batch_size)
        return cur, frozenset(visited)

    events: tuple = ()
    try:
        engine = _worker_engine(token, payload, engine_spec, backend)
        if engine_name != "journal":
            _chaos.maybe_fire("device", start, attempt)
        cur, visited = run(engine)
    except Exception as e:
        if engine_name == "journal":
            raise
        engine = _worker_engine(token, payload, degrade_engine(engine_spec),
                                backend)
        cur, visited = run(engine)
        events = (("device_fallback",
                   f"{engine_name} engine failed ({e!r}); "
                   f"journal engine substituted"),)
    return cur, visited, events


def _degrade_subspace(task):
    """Straggler duplicates always degrade to the journal engine (via
    :func:`repro.core.options.degrade_engine`, which preserves an explicit
    ``@batch`` suffix): if the device or pipeline backend is what's
    hanging, the rescue must not hang with it.  Backend and prune fields
    ride along unchanged."""
    return task[:6] + (degrade_engine(task[6]),) + task[7:]


def _degrade_descent(task):
    return task[:5] + (degrade_engine(task[5]),) + task[6:]


# ----------------------------------------------------- journal record codec
def _encode_subspace(result) -> dict:
    m, n, pruned, _events = result
    rec = {"evals": n, "pruned": pruned}
    if m is not None:                      # task may be pruned away whole
        rec.update({"cuts": list(m.cuts), "lat": m.latency_cycles,
                    "dram_total": m.dram_total, "dram_fm": m.dram_fm,
                    "sram": m.sram_total, "bram": m.bram18k,
                    "feasible": bool(m.feasible)})
    return rec


def _decode_metrics(rec: dict) -> "_cp.CandidateMetrics":
    return _cp.CandidateMetrics(
        cuts=tuple(rec["cuts"]), latency_cycles=rec["lat"],
        dram_total=rec["dram_total"], dram_fm=rec["dram_fm"],
        sram_total=rec["sram"], bram18k=rec["bram"],
        feasible=rec["feasible"])


def _decode_subspace(rec: dict):
    m = _decode_metrics(rec) if rec.get("cuts") is not None else None
    return m, rec["evals"], rec.get("pruned", 0), ()


def _encode_descent(result) -> dict:
    m, visited, _events = result
    rec = _encode_subspace((m, 0, 0, ()))
    del rec["evals"]
    del rec["pruned"]
    rec["visited"] = sorted(list(t) for t in visited)
    return rec


def _decode_descent(rec: dict):
    visited = frozenset(tuple(t) for t in rec["visited"])
    return _decode_metrics(rec), visited, ()


def partition_space(runs: list[list[int]],
                    target_tasks: int) -> tuple[list[tuple[int, ...]],
                                                list[int]]:
    """Split the cut product space along the leading monotone-run axes.

    Takes the smallest ``k`` such that the first ``k`` axes enumerate at
    least ``target_tasks`` prefixes (or all axes, for small spaces) and
    returns ``(prefixes, suffix_dims)``: every ``prefix x
    product(range(d+1) for d in suffix_dims)`` is one equal-sized, disjoint
    sub-space, and concatenating them in prefix order reproduces the full
    product enumeration order.
    """
    k, tasks = 0, 1
    while k < len(runs) and tasks < target_tasks:
        tasks *= len(runs[k]) + 1
        k += 1
    prefixes = list(itertools.product(*[range(len(r) + 1)
                                        for r in runs[:k]]))
    suffix_dims = [len(r) for r in runs[k:]]
    return prefixes, suffix_dims


class ParallelSearchDriver:
    """Persistent worker pool for cut-space search and generic fan-out.

    Parameters
    ----------
    workers:
        Worker process count; ``None`` means ``os.cpu_count()``.
    mp_context:
        ``multiprocessing`` start method.  Default: ``"fork"`` where
        available (workers inherit the parent's imports, so startup is
        milliseconds), else the platform default.  When a search's
        engine runs jax inside the workers (``pipeline:lax``,
        ``pipeline:pallas``, ``device:scan``, ``device:pallas``) the
        defaulted context is ratcheted to ``"spawn"`` before the pool is
        (re)created -- forking a parent that has already run jit'd code
        hands the children XLA's locked mutexes and deadlocks them (see
        :meth:`_ensure_jax_safe_pool`).  Passing ``mp_context``
        explicitly disables the ratchet.
    max_retries:
        Re-dispatch budget per task for *transient* failures (a dead
        worker process breaking the pool, an injected ``ChaosError``, a
        straggler duplicate).  A task still failing after
        ``max_retries`` re-dispatches raises ``RuntimeError``.
        Deterministic worker exceptions are never retried.
    task_deadline_s:
        Per-task wall-clock deadline.  A task running past it (or past
        the task-grain EWMA straggler bound once warmed, whichever is
        sooner) gets one speculative duplicate; first completion wins.
        ``None`` (default) disables deadlines and speculation.
    guard:
        A :class:`~repro.runtime.fault_tolerance.PreemptionGuard` to poll
        in the dispatch loop; when it trips (SIGTERM/SIGINT), the driver
        drains in-flight tasks, journals them (under ``resume_dir``) and
        raises :class:`SearchPreempted`.
    straggler_threshold:
        EWMA multiple beyond which an in-flight task counts as a
        straggler (only with ``task_deadline_s`` set).

    The pool is created lazily on first use and reused across calls; use
    the driver as a context manager (or call :meth:`close`) to reap the
    worker processes deterministically.
    """

    def __init__(self, workers: int | None = None,
                 mp_context: str | None = None,
                 max_retries: int = 2,
                 task_deadline_s: float | None = None,
                 guard: "PreemptionGuard | None" = None,
                 straggler_threshold: float = 4.0):
        self.workers = max(1, workers or os.cpu_count() or 1)
        self._explicit_ctx = mp_context is not None
        if mp_context is None and "fork" in mp.get_all_start_methods():
            mp_context = "fork"
        self._ctx = mp.get_context(mp_context) if mp_context else None
        self._pool: ProcessPoolExecutor | None = None
        self._searches = 0
        self.max_retries = max(0, max_retries)
        self.task_deadline_s = task_deadline_s
        self.guard = guard
        self.straggler_threshold = straggler_threshold

    # ------------------------------------------------------------- plumbing
    def _executor(self) -> ProcessPoolExecutor:
        if self._pool is None:
            self._pool = ProcessPoolExecutor(max_workers=self.workers,
                                             mp_context=self._ctx)
        return self._pool

    def _jax_safe_opts(self, opts):
        """Make a search with jax-in-worker engines fork-safe.

        XLA's runtime is multithreaded the moment the parent evaluates
        anything under jit; fork-started children then inherit its locked
        mutexes and deadlock on first device call.  Engines whose workers
        stay in numpy (journal, device:reference, pipeline:reference) are
        unaffected and keep fork's millisecond startup.  For jax-running
        specs the defaulted fork context is ratcheted to spawn -- one-way
        for the life of the driver, since spawn is safe for every engine
        and flip-flopping would churn worker pools (and their per-process
        engine caches) on mixed-engine drivers.  When spawn cannot
        reconstruct the parent's ``__main__`` (a stdin-fed script), the
        engine degrades to the journal replay instead -- bit-identical by
        the replay contract, so it only costs wall clock -- with a loud
        warning.  An explicit ``mp_context`` from the caller is always
        honored, including its deadlock hazard.
        """
        if self._explicit_ctx or not _engine_needs_jax(opts.engine_spec()):
            return opts
        if self._ctx is not None and self._ctx.get_start_method() != "fork":
            return opts
        if "spawn" not in mp.get_all_start_methods():  # pragma: no cover
            return opts
        if not _spawn_main_viable():
            warnings.warn(
                f"engine={opts.engine!r} runs jax inside worker processes, "
                f"which is unsafe under the fork start method once the "
                f"parent has used jax -- and spawn cannot re-import this "
                f"process's __main__ ({getattr(sys, 'argv', ['?'])[0]!r}). "
                f"Falling back to the (bit-identical) journal engine for "
                f"worker tasks; run from an importable script/module or "
                f"pass mp_context explicitly to silence this.",
                RuntimeWarning, stacklevel=3)
            return opts.replace(engine=degrade_engine(opts.engine))
        self._reset()
        self._ctx = mp.get_context("spawn")
        return opts

    def map(self, fn, items, chunksize: int = 1) -> list:
        """Ordered parallel map (the generic face of the pool).

        ``fn`` must be a module-level callable; results come back in input
        order.  Worker exceptions propagate; a dead worker process raises
        ``RuntimeError`` instead of hanging the caller.  ``map`` does NOT
        retry -- generic callables are not known to be pure; the retrying
        dispatch loop is reserved for the search task functions, whose
        purity makes re-execution safe.
        """
        try:
            return list(self._executor().map(fn, items, chunksize=chunksize))
        except BrokenProcessPool as e:
            self._reset()
            raise RuntimeError(
                f"search-pool worker process died (workers={self.workers}); "
                f"the pool has been discarded") from e

    def _reset(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "ParallelSearchDriver":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------- fault-tolerant loop
    def _open_journal(self, resume_dir, payload: bytes, opts, mode: str,
                      parts):
        """A TaskJournal keyed by the content hash of (graph+hw payload,
        ``CompileOptions.plan_key()``, partition) -- resuming is only
        legal when every one of those matches; scheduling-only knobs
        (batch_size, engine, worker count at fixed partition) are
        deliberately excluded, since results are bit-identical across
        them.  Keying on the full ``plan_key()`` (not just the objective,
        as the first version of this journal did) is what keeps e.g. a
        ``prune=True, count_pruned=False`` run from resuming off records
        a ``prune=False`` run committed -- their per-task eval/pruned
        splits differ, so cross-resuming would corrupt ``evaluated``."""
        # lazy: checkpoint.py pulls in jax/msgpack, which plain searches
        # never need
        from repro.checkpoint.checkpoint import TaskJournal
        h = hashlib.sha256()
        h.update(payload)
        h.update(repr((opts.plan_key(), mode, parts)).encode())
        return TaskJournal(resume_dir, h.hexdigest()[:16])

    def _run_tasks(self, fn, tasks: list, keys: list, events: list,
                   journal=None, encode=None, decode=None, degrade=None,
                   prepare=None, observe=None):
        """Dispatch ``tasks`` with retry, healing, deadlines, journaling
        and preemption drain; returns worker results in task order.

        Correctness rests on task purity: ``fn(tasks[i])`` always returns
        the same value, so journal replays, bounded re-dispatch after a
        pool break, and first-completion-wins duplicate racing all merge
        to the same result as a fault-free run.

        ``prepare``/``observe`` are the incumbent-propagation hooks for
        branch-and-bound: ``observe(result)`` runs on every completed or
        journal-resumed result, and ``prepare(task)`` rewrites a task at
        the moment it is (re-)submitted -- so later-dispatched tasks
        (and retried/duplicated ones) inherit the best-so-far incumbent.
        Both hooks may only *tighten* pruning, never change the merged
        argmin: task results stay pure up to their ``pruned`` count,
        which is scheduling-dependent by design (like ``events``) and
        excluded from the bit-identity contract.  Journal keys are
        computed from ``keys``, not the prepared task, so a resumed run
        matches records regardless of incumbent timing.
        """
        n = len(tasks)
        results: dict[int, object] = {}
        task_keys = None
        if journal is not None:
            task_keys = [journal.task_key(k) for k in keys]
            for i in range(n):
                rec = journal.get(task_keys[i])     # may raise JournalError
                if rec is not None:
                    results[i] = decode(rec)
                    if observe is not None:
                        observe(results[i])
                    events.append(FaultEvent(
                        "resume", task=keys[i],
                        detail="journaled task result reused"))
        if len(results) == n:
            return [results[i] for i in range(n)]

        live = {i: tasks[i] for i in range(n)}   # may be degraded on retry
        attempts = [0] * n
        dup_issued = [False] * n
        pending = deque(i for i in range(n) if i not in results)
        inflight: dict = {}                  # future -> (i, t0, attempt)
        monitor = StragglerMonitor(window=64,
                                   threshold=self.straggler_threshold,
                                   min_samples=5)
        # cap in-flight submissions: a pool break then only blames the
        # tasks actually handed to the broken pool, and preemption drains
        # quickly
        window = max(1, 2 * self.workers)

        def submit(i: int) -> None:
            if prepare is not None:          # inject the live incumbent at
                live[i] = prepare(live[i])   # submit time (also on retries)
            try:
                fut = self._executor().submit(fn, live[i], attempts[i])
            except BrokenProcessPool:        # broke between loop ticks
                self._reset()
                fut = self._executor().submit(fn, live[i], attempts[i])
            inflight[fut] = (i, time.monotonic(), attempts[i])

        def fill() -> None:
            while pending and len(inflight) < window:
                i = pending.popleft()
                if i not in results:
                    submit(i)

        def record(i: int, res, wall: float | None) -> None:
            results[i] = res
            if observe is not None:
                observe(res)
            if wall is not None:
                monitor.observe(wall)
            if journal is not None:
                journal.put(task_keys[i], encode(res))

        def retry(i: int, exc, reason: str) -> None:
            if attempts[i] >= self.max_retries:
                raise RuntimeError(
                    f"search-pool task {keys[i]!r} failed after "
                    f"{attempts[i] + 1} attempts ({reason}; workers="
                    f"{self.workers}, max_retries={self.max_retries})"
                ) from exc
            attempts[i] += 1
            pending.append(i)
            events.append(FaultEvent("retry", task=keys[i],
                                     attempt=attempts[i], detail=reason))

        fill()
        while len(results) < n:
            if self.guard is not None and self.guard.preempted:
                self._drain(inflight, results, keys, task_keys, journal,
                            encode, monitor, events)
                raise SearchPreempted(
                    f"search preempted: {len(results)}/{n} tasks complete"
                    + (" and journaled" if journal is not None else "")
                    + f"; resume to finish the remaining "
                      f"{n - len(results)}")
            done, _ = wait(list(inflight), timeout=_TICK_S,
                           return_when=FIRST_COMPLETED)
            broken = False
            for fut in done:
                i, t0, _att = inflight.pop(fut)
                exc = fut.exception()
                if exc is None:
                    if i not in results:     # duplicates: first one wins
                        record(i, fut.result(), time.monotonic() - t0)
                    continue
                if isinstance(exc, BrokenProcessPool):
                    broken = True
                    if i not in results:
                        retry(i, exc, "worker process died")
                    continue
                if i in results:
                    continue                 # losing duplicate failed
                if getattr(exc, "transient", False):
                    retry(i, exc, f"transient worker failure: {exc}")
                else:
                    raise exc       # deterministic error: as serial would
            if broken:
                # the pool takes every other in-flight future down with it
                for fut in list(inflight):
                    i, t0, _att = inflight.pop(fut)
                    if i not in results:
                        retry(i, None, "worker process died")
                self._reset()
            self._check_deadlines(inflight, results, attempts, dup_issued,
                                  live, keys, degrade, monitor, events,
                                  submit)
            fill()
        return [results[i] for i in range(n)]

    def _check_deadlines(self, inflight, results, attempts, dup_issued,
                         live, keys, degrade, monitor, events,
                         submit) -> None:
        """Speculative straggler re-dispatch: one duplicate per task once
        it outlives min(task_deadline_s, EWMA straggler bound)."""
        if self.task_deadline_s is None:
            return
        deadline = self.task_deadline_s
        ewma_bound = monitor.straggler_after()
        if ewma_bound is not None:
            deadline = min(deadline, ewma_bound)
        now = time.monotonic()
        for fut, (i, t0, _att) in list(inflight.items()):
            if (i in results or dup_issued[i] or now - t0 <= deadline
                    or attempts[i] >= self.max_retries):
                continue
            attempts[i] += 1
            dup_issued[i] = True
            if degrade is not None:
                live[i] = degrade(live[i])
            submit(i)
            events.append(FaultEvent(
                "straggler", task=keys[i], attempt=attempts[i],
                detail=f"duplicate dispatched after {now - t0:.2f}s > "
                       f"{deadline:.2f}s deadline"))

    def _drain(self, inflight, results, keys, task_keys, journal, encode,
               monitor, events) -> None:
        """Clean preemption drain: start nothing new, cancel what hasn't
        started, await what has, journal every completed result."""
        for fut in list(inflight):
            fut.cancel()                       # queued-only futures
        if inflight:
            done, _ = wait(list(inflight))
            for fut in done:
                i, t0, _att = inflight.pop(fut)
                if (i in results or fut.cancelled()
                        or fut.exception() is not None):
                    continue
                results[i] = fut.result()
                if journal is not None:
                    journal.put(task_keys[i], encode(fut.result()))
        events.append(FaultEvent(
            "preempted",
            detail=f"preemption drain: {len(results)} task results kept"))

    # --------------------------------------------------------------- search
    def search(self, gg, hw, options=None, *,
               min_parallel_space: int = MIN_PARALLEL_SPACE,
               warm_start=None, **legacy):
        """Parallel ``cutpoint.search``, bit-identical to the serial result.

        Knobs arrive as one :class:`repro.core.options.CompileOptions`
        (the shared field reference lives there; loose keywords still
        work through the deprecation shim).  The driver-level scheduling
        fields -- ``workers``, ``max_retries``, ``task_deadline_s`` --
        are fixed at driver construction and *ignored* on the options
        value here: a driver is a process pool, not a per-call policy.
        Additionally ``min_parallel_space`` sets the space size below
        which the serial path runs directly (the result is identical
        either way -- this is purely a fixed-cost cutoff), and
        ``options.resume_dir`` opens the task journal for checkpointed
        resume (which also forces the partitioned path, so every task is
        journaled even on small spaces).  ``warm_start`` threads a
        cached cut tuple through to the underlying search -- see
        :func:`repro.core.cutpoint.search` for its exactness contract.

        With ``prune`` on, completed task results feed a shared incumbent
        (the best objective key seen so far); tasks dispatched later
        inherit it, so the parallel search prunes *across* sub-spaces,
        not just within them.  The merged argmin, metrics, and (under
        ``count_pruned``) ``evaluated`` are still bit-identical to the
        unpruned serial search -- only ``SearchResult.pruned`` varies
        with scheduling.
        """
        opts = _cp.resolve_options(options, legacy, site="driver.search")
        blocks = _cp.split_blocks(gg)
        runs = _cp.monotone_runs(blocks)
        space = 1
        for r in runs:
            space *= len(r) + 1
        exhaustive = space <= opts.exhaustive_limit
        serial_ok = (self.workers <= 1 or not runs
                     or (exhaustive and space < min_parallel_space))
        if not runs or (serial_ok and opts.resume_dir is None):
            # workers=1 + resume_dir=None keeps cutpoint.search on its
            # serial path (it would otherwise bounce back to a driver)
            return _cp.search(
                gg, hw, opts.replace(workers=1, resume_dir=None),
                warm_start=warm_start)

        if exhaustive:
            prefixes, suffix_dims = partition_space(
                runs, self.workers * TASKS_PER_WORKER)
            return self.run_subspaces(
                gg, hw, prefixes, suffix_dims, opts,
                blocks=blocks, runs=runs, warm_start=warm_start)

        starts = _cp.descent_starts(blocks, runs)
        ws = _cp.valid_warm_start(warm_start, runs)
        if ws is not None and ws not in starts:
            starts.append(ws)       # extra deterministic start, appended
            #                         so ties still favor the cold starts
        self._searches += 1
        token = (os.getpid(), id(self), self._searches, opts.engine)
        payload = pickle.dumps((gg, hw), protocol=pickle.HIGHEST_PROTOCOL)
        events: list[FaultEvent] = []
        journal = None
        if opts.resume_dir is not None:
            journal = self._open_journal(opts.resume_dir, payload, opts,
                                         "descent", tuple(starts))
        batch_size = opts.engine_spec().batch_size
        opts = self._jax_safe_opts(opts)
        tasks = [(token, payload, s, opts.objective, batch_size,
                  opts.engine, opts.backend) for s in starts]
        results = self._run_tasks(
            _run_descent, tasks, keys=starts, events=events,
            journal=journal, encode=_encode_descent,
            decode=_decode_descent, degrade=_degrade_descent)
        visited: set = set()
        best = None
        for start, (m, seen, wev) in zip(starts, results):
            for kind, detail in wev:
                events.append(FaultEvent(kind, task=start, detail=detail))
            visited |= seen                 # start order; strict < as
            if best is None or (_cp._key(m, opts.objective)
                                < _cp._key(best, opts.objective)):
                best = m                    # the serial loop over starts
        cand = _cp.evaluate(gg, blocks, runs, best.cuts, hw)
        return _cp.SearchResult(best=cand, evaluated=len(visited),
                                runs=runs, blocks=blocks, events=events,
                                path="descent")

    def run_subspaces(self, gg, hw, prefixes, suffix_dims, options=None,
                      *, blocks=None, runs=None, warm_start=None,
                      **legacy):
        """Fault-tolerant exhaustive search over an explicit partition.

        ``search`` delegates the full-space exhaustive path here;
        benchmarks call it directly with a *slice* of the partition
        (e.g. the first N yolov2 prefixes) to run end-to-end through the
        retry/journal/deadline machinery on a bounded budget.  Returns a
        ``SearchResult`` over exactly the given sub-spaces.

        A valid ``warm_start`` (with ``prune`` on) is priced through the
        direct oracle and seeds the shared incumbent before the first
        task is dispatched, so every task can prune against the cached
        plan's key from its first batch.  Exactness is unchanged: the
        incumbent is a real candidate's key inside this space, so the
        strict ``>`` bound test can never eliminate the argmin, and
        under ``count_pruned`` the ``evaluated`` accounting is identical
        to a cold run.
        """
        opts = self._jax_safe_opts(
            _cp.resolve_options(options, legacy,
                                site="driver.run_subspaces"))
        objective = opts.objective
        if blocks is None:
            blocks = _cp.split_blocks(gg)
        if runs is None:
            runs = _cp.monotone_runs(blocks)
        self._searches += 1
        token = (os.getpid(), id(self), self._searches, opts.engine)
        payload = pickle.dumps((gg, hw), protocol=pickle.HIGHEST_PROTOCOL)
        events: list[FaultEvent] = []
        journal = None
        if opts.resume_dir is not None:
            journal = self._open_journal(
                opts.resume_dir, payload, opts, "exhaustive",
                (tuple(suffix_dims), tuple(prefixes)))
        batch_size = opts.engine_spec().batch_size
        tasks = [(token, payload, p, tuple(suffix_dims), objective,
                  batch_size, opts.engine, opts.backend, opts.prune,
                  None) for p in prefixes]
        # Incumbent propagation: every completed (or journal-resumed) task
        # result tightens a shared best-so-far key; tasks submitted after
        # that inherit it via ``prepare`` and can prune against it from
        # their first batch.  Monotone tightening only -- the argmin's own
        # task can never be pruned by any incumbent, so the merge below is
        # unchanged regardless of completion order.
        inc_box: list = [None]
        ws = _cp.valid_warm_start(warm_start, runs)
        if ws is not None and opts.prune:
            inc_box[0] = _cp._key(
                _cp.evaluate(gg, blocks, runs, ws, hw), objective)

        def _observe(res) -> None:
            m = res[0]
            if m is not None:
                k = _cp._key(m, objective)
                if inc_box[0] is None or k < inc_box[0]:
                    inc_box[0] = k

        def _prepare(task):
            if inc_box[0] is None:
                return task
            return task[:9] + (inc_box[0],)

        results = self._run_tasks(
            _run_subspace, tasks, keys=list(prefixes), events=events,
            journal=journal, encode=_encode_subspace,
            decode=_decode_subspace, degrade=_degrade_subspace,
            prepare=_prepare if opts.prune else None,
            observe=_observe if opts.prune else None)
        evaluated = 0
        pruned_total = 0
        for prefix, (_m, nev, npr, wev) in zip(prefixes, results):
            evaluated += nev
            pruned_total += npr
            for kind, detail in wev:
                events.append(FaultEvent(kind, task=prefix, detail=detail))
        if opts.count_pruned:
            # scored + pruned per task == the task's tuple count, so the
            # sum is the full enumeration count the unpruned search
            # reports -- deterministic even though the split is not
            evaluated += pruned_total
        # (objective key, cut tuple) == first optimum in product order.
        # Fully-pruned tasks contribute no candidate; at least one task
        # always survives: the global optimum's own subtree bound never
        # strictly exceeds any incumbent (including a warm-start seed,
        # which is itself a candidate inside this space), so its task is
        # never pruned whole.
        survivors = [m for m, _n, _p, _e in results if m is not None]
        assert survivors, "every sub-space pruned: bound/incumbent bug"
        best = min(survivors,
                   key=lambda m: (_cp._key(m, objective), m.cuts))
        cand = _cp.evaluate(gg, blocks, runs, best.cuts, hw)
        return _cp.SearchResult(best=cand, evaluated=evaluated,
                                runs=runs, blocks=blocks, events=events,
                                pruned=pruned_total, path="exhaustive")
