"""Residual-stream residency planner: ShortcutFusion for LM stacks.

A transformer layer is a residual block; the residual stream is the paper's
"shortcut data".  This module re-applies the paper's machinery on the
HBM -> VMEM hierarchy of a TPU:

  frame-reuse  -> RESIDENT mode: the block runs as a fused kernel
                  (kernels/fused_block.py); the shortcut tile is pinned in
                  VMEM across norm->matmul->act->matmul->add; weights are
                  streamed HBM->VMEM exactly once; intermediate activations
                  never touch HBM.
  row-reuse    -> STREAMING mode: op-by-op XLA execution; every operator's
                  inputs/outputs round-trip HBM exactly once (the paper's
                  constraint (10) analogue -- XLA fusion is modelled by
                  counting each *fusion group* boundary, i.e. act_bytes).

Two planners are provided:

  * plan_cutpoint -- paper-faithful: one cut per monotone run of per-block
    working-set size (for homogeneous LM stacks: a single cut L; blocks
    >= L resident).  Exhaustive O(N) sweep of the cut as in §IV-B.
  * plan_dp       -- beyond-paper: exact dynamic program over per-block
    modes with segment-boundary costs; a strict generalization that can
    interleave modes (useful for MoE stacks whose expert blocks never fit).

Both respect the hard VMEM budget, mirroring the SRAM constraint (*).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.hw import TPUConfig, V5E


@dataclass(frozen=True)
class LMBlockSpec:
    """Per-layer(-shard) costs, all bytes/flops PER DEVICE per step."""
    idx: int
    kind: str                 # attn | mlp | moe | ssm | rglru | cross | embed
    weight_bytes: int         # parameter bytes this device streams
    stream_bytes: int         # residual-stream tensor bytes (in == out)
    act_bytes: int            # extra HBM traffic in streaming mode
    flops: int                # FLOPs this device executes
    state_bytes: int = 0      # KV-cache / recurrent state traffic (HBM
    #                           resident in either mode)
    vmem_resident: int = 0    # VMEM needed to run resident (3 stream tiles
    #                           + weight slabs + scratch); 0 = derive

    def resident_vmem(self, hw: TPUConfig) -> int:
        if self.vmem_resident:
            return self.vmem_resident
        # 3-slot allocation (Algorithm 1): x-tile, y-tile, norm scratch.
        # Tiles are (tile_m x d); we budget 3 tiles of the stream plus a
        # double-buffered weight slab of 2 * (d x lane) columns + fp32 accum.
        tile = min(self.stream_bytes, 4 << 20)
        slab = 2 * max(1, self.weight_bytes // 64)
        slab = min(slab, 32 << 20)
        return 3 * tile + slab + (4 << 20)


@dataclass
class ResidencyPlan:
    modes: list[str]                       # 'resident' | 'streaming'
    hbm_bytes: int
    vmem_peak: int
    est_seconds: float
    cut: int | None = None                 # for the cut-point planner
    per_block: list[dict] = field(default_factory=list)

    @property
    def n_resident(self) -> int:
        return sum(m == "resident" for m in self.modes)

    def summary(self) -> str:
        gb = 1 / (1 << 30)
        return (f"{self.n_resident}/{len(self.modes)} blocks resident, "
                f"HBM {self.hbm_bytes * gb:.3f} GB/step/device, "
                f"VMEM peak {self.vmem_peak / (1 << 20):.1f} MB, "
                f"est {1e3 * self.est_seconds:.3f} ms/step")


def _block_cost(b: LMBlockSpec, mode: str, hw: TPUConfig,
                boundary_bytes: int = 0) -> tuple[int, float]:
    """(hbm_bytes, seconds) for one block in one mode.  Segment-boundary
    stream movement folds under the roofline max (it overlaps compute,
    like every other HBM transfer).  The returned time carries an
    infinitesimal traffic tie-break so compute-bound blocks still prefer
    the lower-HBM mode (the paper's DRAM-access constraint under equal
    latency)."""
    if mode == "resident":
        hbm = b.weight_bytes + b.state_bytes
    else:
        hbm = b.weight_bytes + b.state_bytes + b.act_bytes + 2 * b.stream_bytes
    hbm += boundary_bytes
    t = max(b.flops / hw.peak_flops, hbm / hw.hbm_bw)
    return hbm, t


def _evaluate(blocks: list[LMBlockSpec], modes: list[str],
              hw: TPUConfig) -> ResidencyPlan:
    hbm = 0
    t = 0.0
    vmem_peak = 0
    per_block = []
    prev = "streaming"
    for b, m in zip(blocks, modes):
        # boundary stream movement charged to the block where the mode
        # changes (resident entry reads the stream; a streaming successor
        # of a resident segment pays the segment's exit write)
        boundary = b.stream_bytes if m != prev else 0
        bb, bt = _block_cost(b, m, hw, boundary)
        if m == "resident":
            vmem_peak = max(vmem_peak, b.resident_vmem(hw))
        hbm += bb
        t += bt
        per_block.append({"idx": b.idx, "kind": b.kind, "mode": m,
                          "hbm": bb, "sec": bt})
        prev = m
    if prev == "resident":                  # trailing segment exit write
        xb = blocks[-1].stream_bytes
        hbm += xb
        t += xb / hw.hbm_bw
    return ResidencyPlan(modes=list(modes), hbm_bytes=hbm,
                         vmem_peak=vmem_peak, est_seconds=t,
                         per_block=per_block)


def _fits(b: LMBlockSpec, hw: TPUConfig, vmem_budget: int) -> bool:
    return b.resident_vmem(hw) <= vmem_budget


def plan_cutpoint(blocks: list[LMBlockSpec], hw: TPUConfig = V5E,
                  vmem_budget: int | None = None) -> ResidencyPlan:
    """Paper-faithful single-cut policy: blocks >= L resident (provided
    they fit VMEM); exhaustive sweep of L (Fig. 16/17 analogue)."""
    vmem_budget = vmem_budget or hw.vmem_bytes
    best: ResidencyPlan | None = None
    n = len(blocks)
    for cut in range(n + 1):
        modes = []
        for i, b in enumerate(blocks):
            m = "resident" if (i >= cut and _fits(b, hw, vmem_budget)) \
                else "streaming"
            modes.append(m)
        plan = _evaluate(blocks, modes, hw)
        plan.cut = cut
        if plan.vmem_peak > vmem_budget:
            continue
        if best is None or (plan.est_seconds, plan.hbm_bytes) < \
                (best.est_seconds, best.hbm_bytes):
            best = plan
    assert best is not None
    return best


def plan_dp(blocks: list[LMBlockSpec], hw: TPUConfig = V5E,
            vmem_budget: int | None = None) -> ResidencyPlan:
    """Beyond-paper exact DP: argmin over per-block modes of total time
    with boundary costs (states: mode of the previous block)."""
    vmem_budget = vmem_budget or hw.vmem_bytes
    INF = (float("inf"), float("inf"))
    # dp[mode] = ((seconds, hbm_bytes), path): lexicographic cost --
    # minimize time, tie-break on traffic (the paper's DRAM constraint)
    dp = {"streaming": ((0.0, 0), []), "resident": (INF, [])}
    for b in blocks:
        nxt = {"streaming": (INF, []), "resident": (INF, [])}
        for m in ("streaming", "resident"):
            if m == "resident" and not _fits(b, hw, vmem_budget):
                continue
            for pm in ("streaming", "resident"):
                c0, path = dp[pm]
                if c0 == INF:
                    continue
                boundary = b.stream_bytes if pm != m else 0
                bb, bt = _block_cost(b, m, hw, boundary)
                cost = (c0[0] + bt, c0[1] + bb)
                if cost < nxt[m][0]:
                    nxt[m] = (cost, path + [m])
        dp = nxt
    # exit cost for trailing resident segment
    if dp["resident"][0] != INF:
        xb = blocks[-1].stream_bytes
        c = dp["resident"][0]
        dp["resident"] = ((c[0] + xb / hw.hbm_bw, c[1] + xb),
                          dp["resident"][1])
    mode = min(dp, key=lambda k: dp[k][0])
    modes = dp[mode][1]
    return _evaluate(blocks, modes, hw)


def streaming_baseline(blocks: list[LMBlockSpec],
                       hw: TPUConfig = V5E,
                       vmem_budget: int | None = None) -> ResidencyPlan:
    """All-streaming reference plan.  ``vmem_budget`` is accepted for
    signature parity with the planners but is irrelevant: a streaming-only
    plan pins nothing in VMEM."""
    return _evaluate(blocks, ["streaming"] * len(blocks), hw)
