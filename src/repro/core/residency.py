"""Residual-stream residency planner: ShortcutFusion for LM stacks.

A transformer layer is a residual block; the residual stream is the paper's
"shortcut data".  This module re-applies the paper's machinery on the
HBM -> VMEM hierarchy of a TPU:

  frame-reuse  -> RESIDENT mode: the block runs as a fused kernel
                  (kernels/fused_block.py); the shortcut tile is pinned in
                  VMEM across norm->matmul->act->matmul->add; weights are
                  streamed HBM->VMEM exactly once; intermediate activations
                  never touch HBM.
  row-reuse    -> STREAMING mode: op-by-op XLA execution; every operator's
                  inputs/outputs round-trip HBM exactly once (the paper's
                  constraint (10) analogue -- XLA fusion is modelled by
                  counting each *fusion group* boundary, i.e. act_bytes).

Mode boundaries move the residual stream between hierarchies: a resident
segment reads the stream once on entry and writes it once on exit.  Both
transfers are sized by the stream tensor *crossing* the boundary -- the
predecessor block's output (for the stack entry, the stack's input, which
has the first block's stream size since in == out per block).  On
heterogeneous stacks (vision/cross blocks with different ``stream_bytes``)
charging anything else mis-prices every boundary.

Two planners are provided:

  * plan_cutpoint -- paper-faithful: one cut per monotone run of per-block
    working-set size (for homogeneous LM stacks: a single cut L; blocks
    >= L resident).  Exhaustive sweep of the cut as in §IV-B.
  * plan_dp       -- beyond-paper: exact dynamic program over per-block
    modes with segment-boundary costs; a strict generalization that can
    interleave modes (useful for MoE stacks whose expert blocks never fit).

Both respect the hard VMEM budget, mirroring the SRAM constraint (*).

Engine architecture
-------------------

``_evaluate`` is the *oracle*: a from-scratch per-block walk pricing one
mode vector.  The planners instead drive :class:`ResidencyEngine`, which
must agree with the oracle bit-for-bit on every metric and is built from
three pieces (mirroring ``core/cutpoint.py``'s search engine):

* **Cost tables** -- per-block static quantities (both modes' hbm bytes and
  roofline seconds under each of the four prev-mode/mode boundary cases,
  ``resident_vmem``, VMEM-fit mask) are tabulated into numpy arrays once
  per stack (:class:`CostTables`).  Elementwise IEEE float64 ops reproduce
  ``_block_cost`` exactly.
* **Checkpointed sweep** -- ``_evaluate``'s running sums are checkpointed
  at every cut position: prefix sums over the all-streaming costs, suffix
  sums over the fits-determined resident-suffix costs, and a suffix
  running max over resident VMEM.  A candidate cut is then priced by the
  checkpoint pair plus the single boundary delta at the cut, so
  ``plan_cutpoint`` sweeps all N+1 cuts in O(N) total.  Byte sums are
  exact integers; second sums use Shewchuk exact partials so any
  prefix/suffix split reproduces the oracle's ``math.fsum`` bit-for-bit.
* **Vectorized DP** -- ``plan_dp``'s transition step reads the
  pre-tabulated 2x2 boundary-cost tables instead of calling
  ``_block_cost``, and reconstructs the winning path through parent
  pointers instead of copying mode lists per state (the seed's O(N^2)
  path growth).

Oracle contract: ``ResidencyEngine.evaluate_cut(c)`` returns the same
``est_seconds`` / ``hbm_bytes`` / ``vmem_peak`` as ``_evaluate`` on that
cut's mode vector for *every* cut, and ``dp_modes`` picks the same modes
as the transition-by-transition reference DP; both planners materialize
their winner through the oracle, so the returned plan is byte-identical
to a direct O(N^2) search (tests/test_residency_engine.py enforces this
on fuzzed heterogeneous stacks and the LM benchmark archs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.hw import TPUConfig, V5E


@dataclass(frozen=True)
class LMBlockSpec:
    """Per-layer(-shard) costs, all bytes/flops PER DEVICE per step."""
    idx: int
    kind: str                 # attn | mlp | moe | ssm | rglru | cross | embed
    weight_bytes: int         # parameter bytes this device streams
    stream_bytes: int         # residual-stream tensor bytes (in == out)
    act_bytes: int            # extra HBM traffic in streaming mode
    flops: int                # FLOPs this device executes
    state_bytes: int = 0      # KV-cache / recurrent state traffic (HBM
    #                           resident in either mode)
    vmem_resident: int = 0    # VMEM needed to run resident (3 stream tiles
    #                           + weight slabs + scratch); 0 = derive

    def resident_vmem(self, hw: TPUConfig) -> int:
        if self.vmem_resident:
            return self.vmem_resident
        # 3-slot allocation (Algorithm 1): x-tile, y-tile, norm scratch.
        # Tiles are (tile_m x d); we budget 3 tiles of the stream plus a
        # double-buffered weight slab of 2 * (d x lane) columns + fp32 accum.
        tile = min(self.stream_bytes, 4 << 20)
        slab = 2 * max(1, self.weight_bytes // 64)
        slab = min(slab, 32 << 20)
        return 3 * tile + slab + (4 << 20)


@dataclass
class ResidencyPlan:
    modes: list[str]                       # 'resident' | 'streaming'
    hbm_bytes: int
    vmem_peak: int
    est_seconds: float
    cut: int | None = None                 # for the cut-point planner
    per_block: list[dict] = field(default_factory=list)

    @property
    def n_resident(self) -> int:
        return sum(m == "resident" for m in self.modes)  # det: bool count

    def summary(self) -> str:
        gb = 1 / (1 << 30)
        return (f"{self.n_resident}/{len(self.modes)} blocks resident, "
                f"HBM {self.hbm_bytes * gb:.3f} GB/step/device, "
                f"VMEM peak {self.vmem_peak / (1 << 20):.1f} MB, "
                f"est {1e3 * self.est_seconds:.3f} ms/step")


# ------------------------------------------------------------------- oracle
def _block_cost(b: LMBlockSpec, mode: str, hw: TPUConfig,
                boundary_bytes: int = 0) -> tuple[int, float]:
    """(hbm_bytes, seconds) for one block in one mode.  Segment-boundary
    stream movement folds under the roofline max (it overlaps compute,
    like every other HBM transfer).  The returned time carries an
    infinitesimal traffic tie-break so compute-bound blocks still prefer
    the lower-HBM mode (the paper's DRAM-access constraint under equal
    latency)."""
    if mode == "resident":
        hbm = b.weight_bytes + b.state_bytes
    else:
        hbm = b.weight_bytes + b.state_bytes + b.act_bytes + 2 * b.stream_bytes
    hbm += boundary_bytes
    t = max(b.flops / hw.peak_flops, hbm / hw.hbm_bw)
    return hbm, t


def _entry_stream(blocks: list[LMBlockSpec], i: int) -> int:
    """Bytes of the residual stream crossing the boundary *into* block i:
    the predecessor's output (for block 0, the stack input, which has the
    first block's stream size since in == out per block)."""
    return blocks[i - 1].stream_bytes if i else blocks[0].stream_bytes


def _evaluate(blocks: list[LMBlockSpec], modes: list[str],
              hw: TPUConfig) -> ResidencyPlan:
    """Oracle: price one mode vector block by block.

    ``est_seconds`` is the correctly-rounded (``math.fsum``) sum of the
    per-block times, so it is independent of summation order -- which lets
    :class:`ResidencyEngine` reproduce it bit-for-bit from prefix/suffix
    checkpoints.
    """
    hbm = 0
    ts: list[float] = []
    vmem_peak = 0
    per_block = []
    prev = "streaming"
    for i, (b, m) in enumerate(zip(blocks, modes)):
        # Boundary stream movement is charged to the block where the mode
        # changes and sized by the stream crossing the boundary -- the
        # *predecessor's* output (resident entry reads it; a streaming
        # successor of a resident segment pays that segment's exit write).
        boundary = _entry_stream(blocks, i) if m != prev else 0
        bb, bt = _block_cost(b, m, hw, boundary)
        if m == "resident":
            vmem_peak = max(vmem_peak, b.resident_vmem(hw))
        hbm += bb
        ts.append(bt)
        per_block.append({"idx": b.idx, "kind": b.kind, "mode": m,
                          "hbm": bb, "sec": bt})
        prev = m
    if prev == "resident":      # trailing segment exit: last block's output
        xb = blocks[-1].stream_bytes
        hbm += xb
        ts.append(xb / hw.hbm_bw)
    return ResidencyPlan(modes=list(modes), hbm_bytes=hbm,
                         vmem_peak=vmem_peak, est_seconds=math.fsum(ts),
                         per_block=per_block)


def _fits(b: LMBlockSpec, hw: TPUConfig, vmem_budget: int) -> bool:
    return b.resident_vmem(hw) <= vmem_budget


# -------------------------------------------------------------- cost tables
@dataclass(frozen=True)
class CostTables:
    """Per-block static costs, tabulated once per (stack, hw, budget).

    ``hbm``/``sec`` are keyed by ``(prev_mode, mode)``: the four boundary
    cases of ``_block_cost`` (equal modes -> no boundary; a mode change at
    block i charges ``entry[i]``, the predecessor's stream bytes).  All
    arrays have length N; values are bit-identical to the scalar oracle's.
    """
    n: int
    entry: np.ndarray                       # int64: _entry_stream per block
    rvmem: np.ndarray                       # int64: resident_vmem per block
    fits: np.ndarray                        # bool:  rvmem <= vmem_budget
    hbm: dict[tuple[str, str], np.ndarray]  # int64
    sec: dict[tuple[str, str], np.ndarray]  # float64


def build_cost_tables(blocks: list[LMBlockSpec], hw: TPUConfig,
                      vmem_budget: int) -> CostTables:
    n = len(blocks)
    w = np.array([b.weight_bytes for b in blocks], dtype=np.int64)
    state = np.array([b.state_bytes for b in blocks], dtype=np.int64)
    act = np.array([b.act_bytes for b in blocks], dtype=np.int64)
    stream = np.array([b.stream_bytes for b in blocks], dtype=np.int64)
    entry = np.array([_entry_stream(blocks, i) for i in range(n)],
                     dtype=np.int64)
    rvmem = np.array([b.resident_vmem(hw) for b in blocks], dtype=np.int64)
    flops = np.array([b.flops for b in blocks], dtype=np.float64)

    h_res = w + state
    h_str = h_res + act + 2 * stream
    hbm = {
        ("streaming", "streaming"): h_str,
        ("resident", "resident"): h_res,
        ("streaming", "resident"): h_res + entry,   # segment entry read
        ("resident", "streaming"): h_str + entry,   # segment exit write
    }
    compute_s = flops / hw.peak_flops
    sec = {k: np.maximum(compute_s, v.astype(np.float64) / hw.hbm_bw)
           for k, v in hbm.items()}
    return CostTables(n=n, entry=entry, rvmem=rvmem,
                      fits=rvmem <= vmem_budget, hbm=hbm, sec=sec)


# ---------------------------------------------------- exact float summation
def _grow_partials(partials: list[float], x: float) -> list[float]:
    """Shewchuk error-free accumulation (the ``math.fsum`` inner loop):
    returns non-overlapping partials whose exact sum is sum(partials) + x.
    ``math.fsum`` over any partials snapshot plus further terms therefore
    equals ``math.fsum`` over the original term multiset, bit-for-bit."""
    i = 0
    for y in partials:
        if abs(x) < abs(y):
            x, y = y, x
        hi = x + y
        lo = y - (hi - x)
        if lo:
            partials[i] = lo
            i += 1
        x = hi
    partials[i:] = [x]
    return partials


# ------------------------------------------------------------------- engine
class ResidencyEngine:
    """Incremental, oracle-exact residency planner core (see module
    docstring).  Build once per (stack, hw, vmem_budget); ``sweep`` then
    prices all N+1 cuts in O(N) total and ``dp_modes`` runs the exact DP
    with O(1) work per transition."""

    def __init__(self, blocks: list[LMBlockSpec], hw: TPUConfig = V5E,
                 vmem_budget: int | None = None):
        self.blocks = blocks
        self.hw = hw
        self.vmem_budget = vmem_budget or hw.vmem_bytes
        self.tables = build_cost_tables(blocks, hw, self.vmem_budget)
        self._build_checkpoints()

    # -- cut-point machinery ------------------------------------------------
    def _build_checkpoints(self) -> None:
        """Checkpoint the oracle's running sums at every cut position.

        For cut c the mode vector is: blocks < c streaming; blocks >= c in
        their *fits-mode* (resident iff they fit VMEM -- non-fitting blocks
        are forced streaming).  The fits-mode of every suffix block is
        independent of c, so one prefix pass (all-streaming costs) and one
        suffix pass (fits-mode costs with their fits-determined boundaries)
        price every cut; only block c's own boundary depends on c.
        """
        t = self.tables
        n = t.n
        fm = ["resident" if f else "streaming" for f in t.fits]
        self._fits_modes = fm
        t_ss = t.sec[("streaming", "streaming")]
        h_ss = t.hbm[("streaming", "streaming")]

        # prefix checkpoints: exact sums of all-streaming costs over [0, c)
        self._pre_sec: list[list[float]] = [[]]
        self._pre_hbm: list[int] = [0]
        parts: list[float] = []
        acc = 0
        for i in range(n):
            parts = _grow_partials(parts, float(t_ss[i]))
            acc += int(h_ss[i])
            self._pre_sec.append(list(parts))
            self._pre_hbm.append(acc)

        # suffix checkpoints over [c, n) of fits-mode costs with interior
        # boundaries (block i's boundary case is (fm[i-1], fm[i])); index 0
        # is never queried -- the cut block itself is priced separately.
        self._suf_sec: list[list[float]] = [[] for _ in range(n + 1)]
        self._suf_hbm: list[int] = [0] * (n + 1)
        self._suf_vmax: list[int] = [0] * (n + 1)
        parts = []
        acc = 0
        vmax = 0
        self._exit: tuple[float, int] | None = None
        for i in range(n - 1, 0, -1):
            key = (fm[i - 1], fm[i])
            parts = _grow_partials(parts, float(t.sec[key][i]))
            acc += int(t.hbm[key][i])
            if t.fits[i]:
                vmax = max(vmax, int(t.rvmem[i]))
            self._suf_sec[i] = list(parts)
            self._suf_hbm[i] = acc
            self._suf_vmax[i] = vmax
        if n:
            self._suf_vmax[0] = max(self._suf_vmax[1],
                                    int(t.rvmem[0]) if t.fits[0] else 0)
            if fm[-1] == "resident":
                xb = self.blocks[-1].stream_bytes
                self._exit = (xb / self.hw.hbm_bw, xb)

    def cut_modes(self, cut: int) -> tuple[list[str], list[int]]:
        """(mode vector, forced-streaming block indices) for one cut:
        blocks >= cut are resident where they fit, forced streaming where
        they don't."""
        fm = self._fits_modes
        modes = ["streaming"] * cut + fm[cut:]
        forced = [i for i in range(cut, self.tables.n) if fm[i] != "resident"]
        return modes, forced

    def evaluate_cut(self, cut: int) -> tuple[float, int, int]:
        """(est_seconds, hbm_bytes, vmem_peak) of one cut, bit-identical to
        ``_evaluate(blocks, cut_modes(cut)[0], hw)``, in O(1)."""
        t = self.tables
        n = t.n
        if cut == n:
            return math.fsum(self._pre_sec[n]), self._pre_hbm[n], 0
        # block `cut` sits at the streaming->suffix boundary: it pays the
        # entry read iff it is itself resident
        key = ("streaming", self._fits_modes[cut])
        terms = self._pre_sec[cut] + [float(t.sec[key][cut])] \
            + self._suf_sec[cut + 1]
        hbm = self._pre_hbm[cut] + int(t.hbm[key][cut]) \
            + self._suf_hbm[cut + 1]
        if self._exit is not None:
            terms.append(self._exit[0])
            hbm += self._exit[1]
        return math.fsum(terms), hbm, self._suf_vmax[cut]

    def sweep(self) -> int:
        """Best single cut (lowest (est_seconds, hbm_bytes); ties keep the
        earliest cut, as the direct ascending sweep does)."""
        best_cut = 0
        best_key: tuple[float, int] | None = None
        for cut in range(self.tables.n + 1):
            est, hbm, _ = self.evaluate_cut(cut)
            key = (est, hbm)
            if best_key is None or key < best_key:
                best_cut, best_key = cut, key
        return best_cut

    # -- DP machinery -------------------------------------------------------
    def dp_modes(self) -> list[str]:
        """Exact DP over per-block modes (states: previous block's mode),
        lexicographic (seconds, hbm_bytes) cost.  Transition costs come
        from the pre-tabulated boundary tables; the winning path is
        rebuilt through parent pointers.  Tie-breaks match the reference
        transition-by-transition DP: 'streaming' is preferred (it is
        tried first, and only strictly better costs replace it)."""
        t = self.tables
        n = t.n
        if not n:
            return []
        sec_ss, hbm_ss = (t.sec[("streaming", "streaming")].tolist(),
                          t.hbm[("streaming", "streaming")].tolist())
        sec_sr, hbm_sr = (t.sec[("streaming", "resident")].tolist(),
                          t.hbm[("streaming", "resident")].tolist())
        sec_rs, hbm_rs = (t.sec[("resident", "streaming")].tolist(),
                          t.hbm[("resident", "streaming")].tolist())
        sec_rr, hbm_rr = (t.sec[("resident", "resident")].tolist(),
                          t.hbm[("resident", "resident")].tolist())
        fits = t.fits.tolist()
        INF = (math.inf, math.inf)
        cs, cr = (0.0, 0), INF     # best cost ending streaming / resident
        par_s: list[str] = []      # chosen predecessor mode per (block, state)
        par_r: list[str] = []
        for i in range(n):
            ns, ps = (cs[0] + sec_ss[i], cs[1] + hbm_ss[i]), "streaming"
            if cr != INF:
                c = (cr[0] + sec_rs[i], cr[1] + hbm_rs[i])
                if c < ns:
                    ns, ps = c, "resident"
            nr, pr = INF, ""
            if fits[i]:
                nr, pr = (cs[0] + sec_sr[i], cs[1] + hbm_sr[i]), "streaming"
                if cr != INF:
                    c = (cr[0] + sec_rr[i], cr[1] + hbm_rr[i])
                    if c < nr:
                        nr, pr = c, "resident"
            cs, cr = ns, nr
            par_s.append(ps)
            par_r.append(pr)
        if cr != INF:              # trailing segment exit write
            xb = self.blocks[-1].stream_bytes
            cr = (cr[0] + xb / self.hw.hbm_bw, cr[1] + xb)
        m = "streaming" if cs <= cr else "resident"
        modes = [m]
        for i in range(n - 1, 0, -1):
            m = par_s[i] if m == "streaming" else par_r[i]
            modes.append(m)
        modes.reverse()
        return modes


# ----------------------------------------------------------------- planners
def _engine_for(blocks: list[LMBlockSpec], hw: TPUConfig,
                vmem_budget: int | None,
                engine: ResidencyEngine | None) -> ResidencyEngine:
    if engine is None:
        return ResidencyEngine(blocks, hw, vmem_budget)
    assert engine.blocks is blocks and engine.hw is hw \
        and engine.vmem_budget == (vmem_budget or hw.vmem_bytes), \
        "engine was built for different (blocks, hw, vmem_budget)"
    return engine


def plan_cutpoint(blocks: list[LMBlockSpec], hw: TPUConfig = V5E,
                  vmem_budget: int | None = None,
                  engine: ResidencyEngine | None = None) -> ResidencyPlan:
    """Paper-faithful single-cut policy: blocks >= L resident (provided
    they fit VMEM); exhaustive sweep of L (Fig. 16/17 analogue), priced by
    the O(N) engine.  Pass ``engine`` to reuse one built for the same
    (blocks, hw, vmem_budget); the winner is materialized through the
    oracle.  Blocks inside the resident suffix that were forced streaming
    by the VMEM fit check are flagged ``forced_streaming`` in
    ``per_block``, so ``cut`` plus the flags fully describe ``modes``."""
    engine = _engine_for(blocks, hw, vmem_budget, engine)
    cut = engine.sweep()
    modes, forced = engine.cut_modes(cut)
    plan = _evaluate(blocks, modes, hw)
    plan.cut = cut
    for i in forced:
        plan.per_block[i]["forced_streaming"] = True
    # The per-block fit check already gates every resident block, so the
    # plan-level budget invariant holds by construction -- keep it explicit
    # rather than as an unreachable rejection branch.
    assert plan.vmem_peak <= engine.vmem_budget, \
        (plan.vmem_peak, engine.vmem_budget)
    return plan


def plan_dp(blocks: list[LMBlockSpec], hw: TPUConfig = V5E,
            vmem_budget: int | None = None,
            engine: ResidencyEngine | None = None) -> ResidencyPlan:
    """Beyond-paper exact DP: argmin over per-block modes of total time
    with boundary costs (states: mode of the previous block).  Pass
    ``engine`` to reuse one built for the same (blocks, hw, vmem_budget)."""
    engine = _engine_for(blocks, hw, vmem_budget, engine)
    return _evaluate(blocks, engine.dp_modes(), hw)


def streaming_baseline(blocks: list[LMBlockSpec],
                       hw: TPUConfig = V5E,
                       vmem_budget: int | None = None) -> ResidencyPlan:
    """All-streaming reference plan.  ``vmem_budget`` is accepted for
    signature parity with the planners but is irrelevant: a streaming-only
    plan pins nothing in VMEM."""
    return _evaluate(blocks, ["streaming"] * len(blocks), hw)
