"""On-chip buffer sizing: paper equations (1)-(7)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache

import numpy as np

from repro.core.allocator import Allocation
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig


@dataclass
class SRAMReport:
    weight_buff: int
    row_buff: int
    out_buff: int
    write_buff: int
    buff: list[int]
    side_buff: int
    sram_total: int
    bram18k: int

    def __str__(self) -> str:
        mb = 1 / (1 << 20)
        return (f"SRAM {self.sram_total * mb:.3f} MB "
                f"(w={self.weight_buff * mb:.3f} row={self.row_buff * mb:.3f} "
                f"out={self.out_buff * mb:.3f} wr={self.write_buff * mb:.3f} "
                f"buf={[round(b * mb, 3) for b in self.buff]} "
                f"side={self.side_buff * mb:.3f}) bram18k={self.bram18k}")


def bram18k_count(depth: int, width_bits: int) -> int:
    """Eq. (7): BRAM18k = ceil(depth/1024) * ceil(width/18)."""
    if depth == 0:
        return 0
    return math.ceil(depth / 1024) * math.ceil(width_bits / 18)


def sram_report(gg: GroupedGraph, alloc: Allocation,
                hw: FPGAConfig) -> SRAMReport:
    policy = alloc.policy
    compute = [g for g in gg.groups if g.is_compute or g.kind == "scale"]

    # Eq. (1): in row-reuse mode the entire layer weights are pre-loaded
    # on-chip (constraint (10): weights from DRAM exactly once).
    weight_buff = max((g.weight_size for g in compute
                       if policy[g.gid] == "row"), default=0)

    # Eq. (2): buffer 1 is shared between feature maps and weights.
    buff = list(alloc.buff)
    buff[1] = max(buff[1], weight_buff)

    # Eq. (3): six rows of the widest input (incl. one prefetch row).
    row_buff = max((6 * g.head.in_w * g.head.in_ch * g.head.qa
                    for g in compute), default=0)

    # Eq. (4): partial-sum buffer, 4-byte accumulators; frame mode buffers a
    # whole To-channel frame, row mode only one row (frame dominates).
    out_frame = max((g.head.out_w * g.head.out_h * hw.to * g.head.qs
                     for g in compute if policy[g.gid] == "frame"), default=0)
    out_row = max((g.head.out_w * hw.to * g.head.qs
                   for g in compute if policy[g.gid] == "row"), default=0)
    out_buff = max(out_frame, out_row)

    # Eq. (5): write buffer.
    wr_row = max((g.tail.out_w * hw.to * g.tail.qa
                  for g in compute if policy[g.gid] == "row"), default=0)
    wr_frame = max((g.tail.out_w * g.tail.out_h * hw.to * g.tail.qa
                    for g in compute
                    if policy[g.gid] == "frame"
                    and g.gid in alloc.boundary_writes), default=0)
    write_buff = max(wr_row, wr_frame)

    # Eq. (6).
    sram_total = (row_buff + out_buff + write_buff
                  + sum(buff) + alloc.side_buff)   # det: int-exact bytes

    bram = _bram18k_total(row_buff, out_buff, write_buff, buff,
                          alloc.side_buff, hw)

    return SRAMReport(weight_buff=weight_buff, row_buff=row_buff,
                      out_buff=out_buff, write_buff=write_buff, buff=buff,
                      side_buff=alloc.side_buff, sram_total=sram_total,
                      bram18k=bram)


@lru_cache(maxsize=65536)
def _brams(total_bytes: int, width_bits: int, banks: int) -> int:
    """Eq. (7) for one physical buffer of ``banks`` banks (pure, cached:
    the cut-point engine hits the same few buffer sizes millions of
    times)."""
    if total_bytes == 0:
        return 0
    depth = math.ceil(total_bytes * 8 / (banks * width_bits))
    return banks * bram18k_count(depth, width_bits)


def _bram18k_total(row_buff: int, out_buff: int, write_buff: int,
                   buff: list[int], side_buff: int, hw: FPGAConfig) -> int:
    # Eq. (7) applied per physical buffer, To banks of 8-bit (x2 for the
    # double-INT8 weight feed), 32-bit for partial sums.
    to = hw.to
    return (_brams(row_buff, 8, to) + _brams(out_buff, 32, to)
            + _brams(write_buff, 8, to)
            + sum(_brams(b, 8, to) for b in buff)  # det: int bank counts
            + _brams(side_buff, 8, to))


# ---------------------------------------------------- vectorized evaluation
@dataclass
class SRAMTables:
    """Static per-group candidate terms for eqs. (1)-(5); the maxima are
    taken per candidate policy as masked array reductions."""
    compute: np.ndarray       # bool: compute/scale groups (eq. 1-5 domain)
    weight: np.ndarray        # int64: weight bytes (eq. 1 candidates)
    out_frame: np.ndarray     # int64: eq. (4) frame-mode candidates
    out_row: np.ndarray       # int64: eq. (4) row-mode candidates
    wr_row: np.ndarray        # int64: eq. (5) row-mode candidates
    wr_frame: list[int]       # eq. (5) frame-mode boundary-write candidates
    row_buff: int             # eq. (3): policy-independent


def sram_tables(gg: GroupedGraph, hw: FPGAConfig) -> SRAMTables:
    n = len(gg.groups)
    compute = np.zeros(n, dtype=bool)
    weight = np.zeros(n, dtype=np.int64)
    out_frame = np.zeros(n, dtype=np.int64)
    out_row = np.zeros(n, dtype=np.int64)
    wr_row = np.zeros(n, dtype=np.int64)
    wr_frame = [0] * n
    row_buff = 0
    for g in gg.groups:
        if not (g.is_compute or g.kind == "scale"):
            continue
        compute[g.gid] = True
        weight[g.gid] = g.weight_size
        row_buff = max(row_buff, 6 * g.head.in_w * g.head.in_ch * g.head.qa)
        out_frame[g.gid] = g.head.out_w * g.head.out_h * hw.to * g.head.qs
        out_row[g.gid] = g.head.out_w * hw.to * g.head.qs
        wr_row[g.gid] = g.tail.out_w * hw.to * g.tail.qa
        wr_frame[g.gid] = g.tail.out_w * g.tail.out_h * hw.to * g.tail.qa
    return SRAMTables(compute=compute, weight=weight, out_frame=out_frame,
                      out_row=out_row, wr_row=wr_row, wr_frame=wr_frame,
                      row_buff=row_buff)


def wr_frame_max(t: SRAMTables, alloc: Allocation, frame) -> int:
    """The candidate-dependent eq. (5) frame-mode term of
    ``sram_total_fast``: max write-buffer candidate over the allocation's
    frame-mode boundary writes.  The engine extracts this per candidate
    while the replayed allocation is live (``frame`` is that candidate's
    mask row); ``sram_total_fast_batch`` combines it with the vectorized
    maxima."""
    cm = t.compute
    wft = t.wr_frame
    wr = 0
    for gid in alloc.boundary_writes:
        if cm[gid] and frame[gid] and wft[gid] > wr:
            wr = wft[gid]
    return wr


def sram_total_fast_batch(t: SRAMTables, frame: np.ndarray,
                          cand_terms: list, hw: FPGAConfig,
                          maxima=None,
                          bram_memo: dict | None = None
                          ) -> tuple[list[int], list[int]]:
    """``sram_total_fast`` for B candidates: the four policy-dependent
    maxima of eqs. (1)/(4)/(5) become masked 2-D int64 reductions over the
    frame-mask matrix; the per-candidate terms arrive as
    ``cand_terms[i] = (buff0, buff1, buff2, side_buff, wr_frame)`` --
    the replayed buffer sizes plus :func:`wr_frame_max`.  Integer
    maxima/sums are exact, so each element is bit-identical to the scalar
    path.

    ``maxima`` optionally injects precomputed ``(weight_buff, out_frame,
    out_row, wr_row)`` per-candidate maxima (the Pallas backend computes
    them on-device).  ``bram_memo`` memoizes eq. (7) over the full
    buffer-size tuple -- neighbouring candidates in a batch hit the same
    handful of buffer shapes, so six lru lookups become one dict hit; the
    dict must be scoped to one (graph tables, hw) pair (the engine owns
    one per instance)."""
    if maxima is None:
        compute = t.compute[None, :]
        rowm = compute & ~frame
        frm = compute & frame
        wbuff = np.where(rowm, t.weight[None, :], 0).max(axis=1)
        outf = np.where(frm, t.out_frame[None, :], 0).max(axis=1)
        outr = np.where(rowm, t.out_row[None, :], 0).max(axis=1)
        wrr = np.where(rowm, t.wr_row[None, :], 0).max(axis=1)
    else:
        wbuff, outf, outr, wrr = maxima
    wbuff = wbuff.tolist()
    outf = outf.tolist()
    outr = outr.tolist()
    wrr = wrr.tolist()
    totals: list[int] = []
    brams: list[int] = []
    row_buff = t.row_buff
    for i, (b0, b1, b2, side, wr_frame) in enumerate(cand_terms):
        if wbuff[i] > b1:
            b1 = wbuff[i]
        out_buff = max(outf[i], outr[i])
        write_buff = max(wrr[i], wr_frame)
        totals.append(row_buff + out_buff + write_buff
                      + b0 + b1 + b2 + side)
        key = (out_buff, write_buff, b0, b1, b2, side)
        bram = None if bram_memo is None else bram_memo.get(key)
        if bram is None:
            bram = _bram18k_total(row_buff, out_buff, write_buff,
                                  [b0, b1, b2], side, hw)
            if bram_memo is not None:
                bram_memo[key] = bram
        brams.append(bram)
    return totals, brams


def sram_total_fast(t: SRAMTables, frame: np.ndarray, alloc: Allocation,
                    hw: FPGAConfig) -> tuple[int, int]:
    """(sram_total, bram18k), bit-identical to ``sram_report``."""
    rowm = t.compute & ~frame
    frm = t.compute & frame
    weight_buff = int(t.weight.max(where=rowm, initial=0))
    buff = list(alloc.buff)
    buff[1] = max(buff[1], weight_buff)
    out_buff = max(int(t.out_frame.max(where=frm, initial=0)),
                   int(t.out_row.max(where=rowm, initial=0)))
    wr_row = int(t.wr_row.max(where=rowm, initial=0))
    wr_frame = max((t.wr_frame[gid] for gid in alloc.boundary_writes
                    if frm[gid]), default=0)
    write_buff = max(wr_row, wr_frame)
    sram_total = (t.row_buff + out_buff + write_buff
                  + sum(buff) + alloc.side_buff)   # det: int-exact bytes
    bram = _bram18k_total(t.row_buff, out_buff, write_buff, buff,
                          alloc.side_buff, hw)
    return sram_total, bram
