"""On-chip buffer sizing: paper equations (1)-(7)."""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.allocator import Allocation
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig


@dataclass
class SRAMReport:
    weight_buff: int
    row_buff: int
    out_buff: int
    write_buff: int
    buff: list[int]
    side_buff: int
    sram_total: int
    bram18k: int

    def __str__(self) -> str:
        mb = 1 / (1 << 20)
        return (f"SRAM {self.sram_total * mb:.3f} MB "
                f"(w={self.weight_buff * mb:.3f} row={self.row_buff * mb:.3f} "
                f"out={self.out_buff * mb:.3f} wr={self.write_buff * mb:.3f} "
                f"buf={[round(b * mb, 3) for b in self.buff]} "
                f"side={self.side_buff * mb:.3f}) bram18k={self.bram18k}")


def bram18k_count(depth: int, width_bits: int) -> int:
    """Eq. (7): BRAM18k = ceil(depth/1024) * ceil(width/18)."""
    if depth == 0:
        return 0
    return math.ceil(depth / 1024) * math.ceil(width_bits / 18)


def sram_report(gg: GroupedGraph, alloc: Allocation,
                hw: FPGAConfig) -> SRAMReport:
    policy = alloc.policy
    compute = [g for g in gg.groups if g.is_compute or g.kind == "scale"]

    # Eq. (1): in row-reuse mode the entire layer weights are pre-loaded
    # on-chip (constraint (10): weights from DRAM exactly once).
    weight_buff = max((g.weight_size for g in compute
                       if policy[g.gid] == "row"), default=0)

    # Eq. (2): buffer 1 is shared between feature maps and weights.
    buff = list(alloc.buff)
    buff[1] = max(buff[1], weight_buff)

    # Eq. (3): six rows of the widest input (incl. one prefetch row).
    row_buff = max((6 * g.head.in_w * g.head.in_ch * g.head.qa
                    for g in compute), default=0)

    # Eq. (4): partial-sum buffer, 4-byte accumulators; frame mode buffers a
    # whole To-channel frame, row mode only one row (frame dominates).
    out_frame = max((g.head.out_w * g.head.out_h * hw.to * g.head.qs
                     for g in compute if policy[g.gid] == "frame"), default=0)
    out_row = max((g.head.out_w * hw.to * g.head.qs
                   for g in compute if policy[g.gid] == "row"), default=0)
    out_buff = max(out_frame, out_row)

    # Eq. (5): write buffer.
    wr_row = max((g.tail.out_w * hw.to * g.tail.qa
                  for g in compute if policy[g.gid] == "row"), default=0)
    wr_frame = max((g.tail.out_w * g.tail.out_h * hw.to * g.tail.qa
                    for g in compute
                    if policy[g.gid] == "frame"
                    and g.gid in alloc.boundary_writes), default=0)
    write_buff = max(wr_row, wr_frame)

    # Eq. (6).
    sram_total = (row_buff + out_buff + write_buff
                  + sum(buff) + alloc.side_buff)

    # Eq. (7) applied per physical buffer, To banks of 8-bit (x2 for the
    # double-INT8 weight feed), 32-bit for partial sums.
    def brams(total_bytes: int, width_bits: int) -> int:
        if total_bytes == 0:
            return 0
        banks = hw.to
        depth = math.ceil(total_bytes * 8 / (banks * width_bits))
        return banks * bram18k_count(depth, width_bits)

    bram = (brams(row_buff, 8) + brams(out_buff, 32) + brams(write_buff, 8)
            + sum(brams(b, 8) for b in buff) + brams(alloc.side_buff, 8))

    return SRAMReport(weight_buff=weight_buff, row_buff=row_buff,
                      out_buff=out_buff, write_buff=write_buff, buff=buff,
                      side_buff=alloc.side_buff, sram_total=sram_total,
                      bram18k=bram)
