"""Off-chip access model: paper equations (8)-(9).

``dram_fm`` generalizes eq. (8) with explicit boundary terms so that
arbitrary (non-contiguous) policies are accounted exactly; for the paper's
contiguous segment policies it reduces to eq. (8):

  row-mode conv groups:   in_size + out_size        (stream through DRAM)
  row-mode fused shortcut: + shortcut in_size        (Fig. 9: 2 reads 1 write)
  frame-mode groups:      0, except
     - row->frame boundary reads (input fetched once),
     - frame->row / final-output boundary writes,
     - long-path spills (concat/route operands): write + read
       == the paper's  2 x in_size(concat)  term.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.allocator import Allocation, _is_side
from repro.core.grouping import GroupedGraph


@dataclass
class DRAMReport:
    fm_bytes: int
    weight_bytes: int

    @property
    def total(self) -> int:             # eq. (9)
        return self.fm_bytes + self.weight_bytes

    def __str__(self) -> str:
        mb = 1 / (1 << 20)
        return (f"DRAM fm={self.fm_bytes * mb:.2f} MB + "
                f"w={self.weight_bytes * mb:.2f} MB = {self.total * mb:.2f} MB")


def dram_fm(gg: GroupedGraph, alloc: Allocation) -> int:
    policy = alloc.policy
    fm = 0
    for g in gg.groups:
        if _is_side(gg, g):
            continue                          # SE side path: on-chip always
        mode = policy[g.gid]
        if mode == "row":
            if g.kind in ("concat", "route"):
                # Feature-merging redirect (TensorRT-style, §III-A): the
                # producers already wrote into the concat destination.
                continue
            sc = gg.shortcut_source_group(g)
            sc_bytes = gg.groups[sc].out_size if sc is not None else 0
            fm += g.in_size + g.out_size + sc_bytes
            if g.kind == "add" and g.head.kind == "add":
                # standalone eltwise: in+out counted; second operand:
                extra = sum(gg.groups[i].out_size
                            for i in gg.group_inputs(g)[1:]
                            if i >= 0)
                fm += extra
        else:
            # Reads of DRAM-resident inputs (boundaries, spills, concat
            # gathers) are charged to the consumer via boundary_reads; the
            # write side is charged to the producer here.
            fm += alloc.boundary_reads.get(g.gid, 0)
            if g.gid in alloc.boundary_writes or g.gid in alloc.spilled:
                fm += g.out_size
    return fm


def dram_report(gg: GroupedGraph, alloc: Allocation) -> DRAMReport:
    weights = sum(g.weight_size for g in gg.groups)   # read exactly once
    return DRAMReport(fm_bytes=dram_fm(gg, alloc), weight_bytes=weights)


def baseline_total(gg: GroupedGraph) -> int:
    """Paper's baseline (Table V footnote): weights/inputs/outputs accessed
    from DRAM exactly once *per layer* (node granularity -- interior tensors
    are written by their producer and re-read by each consumer)."""
    total = 0
    for n in gg.graph.nodes:
        if n.kind == "input":
            continue
        g = gg.groups[gg.node_group[n.idx]]
        if _is_side(gg, g):
            continue                        # SE side path: tiny, on-chip
        if n.kind in ("concat", "route"):
            continue                        # redirect, no movement
        total += n.in_size + n.out_size + n.weight_size
        if n.kind == "add":                 # second (shortcut) operand read
            total += sum(gg.graph.nodes[i].out_size for i in n.inputs[1:])
    return total
