"""Off-chip access model: paper equations (8)-(9).

``dram_fm`` generalizes eq. (8) with explicit boundary terms so that
arbitrary (non-contiguous) policies are accounted exactly; for the paper's
contiguous segment policies it reduces to eq. (8):

  row-mode conv groups:   in_size + out_size        (stream through DRAM)
  row-mode fused shortcut: + shortcut in_size        (Fig. 9: 2 reads 1 write)
  frame-mode groups:      0, except
     - row->frame boundary reads (input fetched once),
     - frame->row / final-output boundary writes,
     - long-path spills (concat/route operands): write + read
       == the paper's  2 x in_size(concat)  term.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.allocator import Allocation, _is_side
from repro.core.grouping import Group, GroupedGraph


@dataclass
class DRAMReport:
    fm_bytes: int
    weight_bytes: int

    @property
    def total(self) -> int:             # eq. (9)
        return self.fm_bytes + self.weight_bytes

    def __str__(self) -> str:
        mb = 1 / (1 << 20)
        return (f"DRAM fm={self.fm_bytes * mb:.2f} MB + "
                f"w={self.weight_bytes * mb:.2f} MB = {self.total * mb:.2f} MB")


def row_fm_bytes(gg: GroupedGraph, g: Group) -> int:
    """Row-mode DRAM feature-map traffic of one group (policy-independent)."""
    if g.kind in ("concat", "route"):
        # Feature-merging redirect (TensorRT-style, §III-A): the
        # producers already wrote into the concat destination.
        return 0
    fm = g.in_size + g.out_size
    if g.head.kind == "add":
        # Standalone eltwise: in+out counted above; every extra operand is
        # read once.  group_inputs[1:] already includes the shortcut
        # source, so the fused-shortcut term below must NOT be added on
        # top (it used to be, double-counting the second operand -- the
        # memory simulator counts 2 reads + 1 write, tests/
        # test_simulator_audit.py keeps the two in lock-step).
        fm += sum(gg.groups[i].out_size        # det: int-exact byte counts
                  for i in gg.group_inputs(g)[1:]
                  if i >= 0)
    else:
        sc = gg.shortcut_source_group(g)
        if sc is not None:            # fused add: one shortcut read
            fm += gg.groups[sc].out_size
    return fm


def dram_fm(gg: GroupedGraph, alloc: Allocation) -> int:
    policy = alloc.policy
    fm = 0
    for g in gg.groups:
        if _is_side(gg, g):
            continue                          # SE side path: on-chip always
        mode = policy[g.gid]
        if mode == "row":
            fm += row_fm_bytes(gg, g)
        else:
            # Reads of DRAM-resident inputs (boundaries, spills, concat
            # gathers) are charged to the consumer via boundary_reads; the
            # write side is charged to the producer here.
            fm += alloc.boundary_reads.get(g.gid, 0)
            if g.gid in alloc.boundary_writes or g.gid in alloc.spilled:
                fm += g.out_size
    return fm


def dram_report(gg: GroupedGraph, alloc: Allocation) -> DRAMReport:
    # det: int-exact byte counts (read exactly once)
    weights = sum(g.weight_size for g in gg.groups)
    return DRAMReport(fm_bytes=dram_fm(gg, alloc), weight_bytes=weights)


# ---------------------------------------------------- vectorized evaluation
@dataclass
class DRAMTables:
    """Static per-group quantities for vectorized DRAM evaluation."""
    row_fm: np.ndarray        # int64: row-mode fm traffic (0 for side/merge)
    out_size: list[int]       # per-gid output bytes (Python ints, exact)
    side: np.ndarray          # bool
    weight_bytes: int         # constant weight traffic, eq. (9)


def dram_tables(gg: GroupedGraph) -> DRAMTables:
    n = len(gg.groups)
    row_fm = np.zeros(n, dtype=np.int64)
    side = np.zeros(n, dtype=bool)
    out_size = [0] * n
    for g in gg.groups:
        out_size[g.gid] = g.out_size
        if _is_side(gg, g):
            side[g.gid] = True
        else:
            row_fm[g.gid] = row_fm_bytes(gg, g)
    return DRAMTables(row_fm=row_fm, out_size=out_size, side=side,
                      # det: int-exact byte counts
                      weight_bytes=sum(g.weight_size for g in gg.groups))


def dram_fm_fast(t: DRAMTables, frame: np.ndarray,
                 alloc: Allocation) -> int:
    """``dram_fm`` as an array reduction over the allocation delta: the row
    term is a masked sum of the static table; the frame term touches only
    the boundary/spill sets the allocator actually produced (all of whose
    members are frame-mode, non-side groups by construction)."""
    # det: all four reductions below are over exact int64/Python-int byte
    # counts -- no float rounding, any summation order is bit-identical
    fm = int(t.row_fm[~frame].sum())      # row_fm is 0 for side groups
    fm += sum(alloc.boundary_reads.values())                    # det: int
    out = t.out_size
    fm += sum(out[gid] for gid in alloc.boundary_writes)        # det: int
    fm += sum(out[gid] for gid in alloc.spilled                 # det: int
              if gid not in alloc.boundary_writes)
    return fm


def boundary_fm_bytes(alloc: Allocation, out_size: list[int]) -> int:
    """The candidate-dependent part of ``dram_fm_fast``: boundary reads +
    boundary writes + spill write-outs, as one exact Python int.  The
    engine extracts this per candidate while the replayed allocation is
    live; ``dram_fm_fast_batch`` adds the vectorized row-mode term."""
    writes = alloc.boundary_writes
    fm = 0
    for rb in alloc.boundary_reads.values():
        fm += rb
    for gid in writes:
        fm += out_size[gid]
    for gid in alloc.spilled:
        if gid not in writes:
            fm += out_size[gid]
    return fm


def dram_fm_fast_batch(t: DRAMTables, frame: np.ndarray,
                       boundary_fm: list[int],
                       row_terms=None) -> list[int]:
    """``dram_fm_fast`` for B candidates: one masked 2-D int64 reduction
    over the frame-mask matrix for the row-mode term, plus the
    per-candidate boundary/spill totals (``boundary_fm[i]`` from
    :func:`boundary_fm_bytes` -- exact ints, so each element is
    bit-identical to the scalar path).

    ``row_terms`` optionally injects precomputed per-candidate row-mode
    sums (the Pallas backend computes them on-device); when given they are
    used verbatim."""
    if row_terms is None:
        # det: int64 matrix reduction, exact at any association order
        row_terms = np.where(frame, 0, t.row_fm[None, :]).sum(axis=1)
    return [int(rt) + b for rt, b in zip(row_terms.tolist(), boundary_fm)]


def baseline_total(gg: GroupedGraph) -> int:
    """Paper's baseline (Table V footnote): weights/inputs/outputs accessed
    from DRAM exactly once *per layer* (node granularity -- interior tensors
    are written by their producer and re-read by each consumer)."""
    total = 0
    for n in gg.graph.nodes:
        if n.kind == "input":
            continue
        g = gg.groups[gg.node_group[n.idx]]
        if _is_side(gg, g):
            continue                        # SE side path: tiny, on-chip
        if n.kind in ("concat", "route"):
            continue                        # redirect, no movement
        total += n.in_size + n.out_size + n.weight_size
        if n.kind == "add":                 # second (shortcut) operand read
            # det: int-exact byte counts
            total += sum(gg.graph.nodes[i].out_size for i in n.inputs[1:])
    return total
