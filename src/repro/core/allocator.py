"""Reuse-aware static memory allocation (paper Algorithm 1, §IV-A).

Given a grouped graph and a data-reuse policy L (mode per group, 'row' or
'frame'), statically assign the three interchangeable physical buffers
{0,1,2} to the input / output / shortcut tensors of every frame-mode group,
maximising on-chip shortcut reuse.  Buffer sizes are the max over all
tensors assigned to each buffer (Algorithm 1).

Deviations from the paper, all conservative:
  * allocation is simulated with exact liveness at *group* granularity
    (instructions are per group, Fig. 5b), which reproduces the paper's
    hand-drawn allocations of Fig. 13 for plain / residual / SE blocks;
  * tensors that cannot be held (no free buffer, e.g. FPN lateral data and
    concat operands -- the paper's "long-path" data) are spilled to DRAM,
    exactly as §IV-A prescribes for long-lifetime data;
  * small SE side-path tensors (global-pool + FC outputs) live in a
    dedicated side space, as in Fig. 13(c)/(d).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.grouping import Group, GroupedGraph

NUM_BUFFERS = 3
SIDE_THRESHOLD = 64 << 10           # tensors <= 64 KB ride in the side space
GRAPH_INPUT = -1                    # pseudo producer id of the input image

Policy = dict[int, str]             # gid -> 'row' | 'frame'


@dataclass
class Allocation:
    policy: Policy
    alloc_in: dict[int, int] = field(default_factory=dict)
    alloc_out: dict[int, int] = field(default_factory=dict)
    alloc_shortcut: dict[int, int] = field(default_factory=dict)
    buff: list[int] = field(default_factory=lambda: [0] * NUM_BUFFERS)
    side_buff: int = 0
    # gids whose output was spilled to DRAM although produced in frame mode
    spilled: set[int] = field(default_factory=set)
    # gids whose output additionally crosses a frame->row/final boundary
    boundary_writes: set[int] = field(default_factory=set)
    # frame gids reading (an) input from DRAM (row->frame boundary, spill
    # re-reads, concat gathers).  gid -> bytes read
    boundary_reads: dict[int, int] = field(default_factory=dict)

    @property
    def total_fm_buffer(self) -> int:
        return sum(self.buff) + self.side_buff


def _is_side(gg: GroupedGraph, g: Group) -> bool:
    """SE side-path groups (global-pool / FC chains with tiny outputs)."""
    return (g.head.kind in ("fc", "globalpool")
            and g.out_size <= SIDE_THRESHOLD
            and g.head.out_h == 1 and g.head.out_w == 1)


@dataclass
class AllocState:
    """Full sequential allocator state after processing a prefix of groups.

    The allocator walks groups in gid order; everything it carries between
    iterations lives here, so a snapshot taken at any group boundary can be
    cloned and replayed forward (the cut-point engine checkpoints these at
    monotone-run boundaries to make candidate evaluation incremental)."""
    alloc: Allocation
    # consumer counts not yet satisfied, per producing gid
    remaining: dict[int, int]
    # location of each produced tensor: buffer id, 'side', or 'dram'
    location: dict[int, int | str]
    # buffer id -> producing gid currently held live
    live_in_buffer: dict[int, int]

    def clone(self) -> "AllocState":
        a = self.alloc
        return AllocState(
            alloc=Allocation(
                policy=dict(a.policy),
                alloc_in=dict(a.alloc_in), alloc_out=dict(a.alloc_out),
                alloc_shortcut=dict(a.alloc_shortcut), buff=list(a.buff),
                side_buff=a.side_buff, spilled=set(a.spilled),
                boundary_writes=set(a.boundary_writes),
                boundary_reads=dict(a.boundary_reads)),
            remaining=dict(self.remaining),
            location=dict(self.location),
            live_in_buffer=dict(self.live_in_buffer))


def init_alloc_state(gg: GroupedGraph) -> AllocState:
    # Consumer counts at group level (plus 1 virtual consumer for the final
    # network output so it is always written out).
    remaining = {g.gid: len(gg.group_consumers(g)) for g in gg.groups}
    return AllocState(alloc=Allocation(policy={}), remaining=remaining,
                      location={GRAPH_INPUT: "dram"}, live_in_buffer={})


@dataclass(frozen=True)
class GroupStep:
    """Static per-group facts consumed by the allocator loop body, resolved
    once per graph so replays touch no Group/GroupedGraph objects."""
    gid: int
    is_side: bool
    gin: tuple[int, ...]          # producing gids (main path first)
    src_sizes: tuple[int, ...]    # out bytes of each gin source
    sc_src: int | None
    sc_size: int
    in_size: int
    out_size: int


def graph_steps(gg: GroupedGraph) -> list[GroupStep]:
    """Per-graph step table, cached on the GroupedGraph."""
    steps = getattr(gg, "_alloc_steps", None)
    if steps is not None:
        return steps
    input_size = gg.graph.nodes[0].out_size
    steps = []
    for g in gg.groups:
        gin = tuple(gg.group_inputs(g))
        sc_src = gg.shortcut_source_group(g)
        steps.append(GroupStep(
            gid=g.gid, is_side=_is_side(gg, g), gin=gin,
            src_sizes=tuple(input_size if s == GRAPH_INPUT
                            else gg.groups[s].out_size for s in gin),
            sc_src=sc_src,
            sc_size=gg.groups[sc_src].out_size if sc_src is not None else 0,
            in_size=g.in_size, out_size=g.out_size))
    gg._alloc_steps = steps
    return steps


def alloc_step(state: AllocState, step: GroupStep, mode: str) -> None:
    """Process one group under ``mode``, advancing ``state`` in place.

    This is the loop body of Algorithm 1; ``allocate`` applies it to every
    group and the incremental search engine replays it from a checkpoint."""
    alloc = state.alloc
    remaining = state.remaining
    location = state.location
    live_in_buffer = state.live_in_buffer
    gid = step.gid
    gin = step.gin

    def release_if_dead(src: int) -> None:
        if src == GRAPH_INPUT or remaining.get(src, 0) > 0:
            return
        loc = location.get(src)
        if isinstance(loc, int) and live_in_buffer.get(loc) == src:
            del live_in_buffer[loc]

    if step.is_side:
        # SE side path: on-chip side space regardless of mode.
        if step.out_size > alloc.side_buff:
            alloc.side_buff = step.out_size
        location[gid] = "side"
        for src in gin:
            remaining[src] = remaining.get(src, 1) - 1
            release_if_dead(src)
        return

    if mode == "row":
        # Feature maps stream through DRAM; no {0,1,2} assignment.
        location[gid] = "dram"
        for src in gin:
            remaining[src] = remaining.get(src, 1) - 1
            # A frame-produced tensor consumed by a row group must have
            # been written to DRAM at the boundary.
            if isinstance(location.get(src), int):
                alloc.boundary_writes.add(src)
            release_if_dead(src)
        return

    # ---------------------------------------------------- frame mode
    in_buffers: set[int] = set()
    read_bytes = 0
    for src, src_size in zip(gin, step.src_sizes):
        loc = location.get(src, "dram")
        if isinstance(loc, int):
            in_buffers.add(loc)
        elif loc == "dram":
            # row->frame boundary (or spilled/long-path data): the
            # group's input is fetched from DRAM into its input buffer.
            read_bytes += src_size
    if read_bytes:
        alloc.boundary_reads[gid] = (
            alloc.boundary_reads.get(gid, 0) + read_bytes)

    # Record alloc_in / alloc_shortcut from where the operands live.
    main_src = gin[0] if gin else GRAPH_INPUT
    main_loc = location.get(main_src, "dram")
    buff = alloc.buff
    if isinstance(main_loc, int):
        alloc.alloc_in[gid] = main_loc
        buff[main_loc] = max(buff[main_loc], step.in_size)
    else:
        b = next((i for i in range(NUM_BUFFERS)
                  if i not in live_in_buffer), None)
        if b is not None:
            alloc.alloc_in[gid] = b
            buff[b] = max(buff[b], step.in_size)
            # transient: the fetched input lives only during this group,
            # but the output must not clobber it while it is being read.
            in_buffers.add(b)
    if step.sc_src is not None:
        sloc = location.get(step.sc_src, "dram")
        if isinstance(sloc, int):
            alloc.alloc_shortcut[gid] = sloc
            buff[sloc] = max(buff[sloc], step.sc_size)

    # Consume inputs (shortcut included -- group_inputs covers it).
    for src in gin:
        remaining[src] = remaining.get(src, 1) - 1

    # Concat operands are long-path by definition: producers must have
    # spilled (handled below when the producer ran) or be re-read.
    if remaining.get(gid, 0) == 0:
        # Final output: written straight to DRAM through the write
        # buffer (eq. 5 final_layers term).
        location[gid] = "dram"
        alloc.boundary_writes.add(gid)
    else:
        b = next((i for i in range(NUM_BUFFERS)
                  if i not in live_in_buffer and i not in in_buffers), None)
        if b is None:
            # reuse the main input's buffer if the input dies here
            if (isinstance(main_loc, int)
                    and remaining.get(main_src, 0) == 0
                    and live_in_buffer.get(main_loc) == main_src):
                del live_in_buffer[main_loc]
                b = main_loc
        if b is None:
            # Long-path data (paper §IV-A): spill to DRAM.
            location[gid] = "dram"
            alloc.spilled.add(gid)
        else:
            location[gid] = b
            live_in_buffer[b] = gid
            alloc.alloc_out[gid] = b
            buff[b] = max(buff[b], step.out_size)

    for src in gin:
        release_if_dead(src)


def allocate(gg: GroupedGraph, policy: Policy) -> Allocation:
    state = init_alloc_state(gg)
    state.alloc.policy = dict(policy)
    for step in graph_steps(gg):
        alloc_step(state, step, policy[step.gid])
    return state.alloc


def spill_is_long_path(gg: GroupedGraph, gid: int,
                       long_path_span: int = 8) -> bool:
    """Whether a spill of ``gid``'s output is tolerable long-path data
    (policy-independent, so the search engine precomputes it per gid)."""
    g = gg.groups[gid]
    cons = gg.group_consumers(g)
    if any(gg.groups[c].kind in ("concat", "route") for c in cons):
        return True
    span = max((c - gid for c in cons), default=0)
    return span > long_path_span


def frame_feasible(gg: GroupedGraph, policy: Policy,
                   alloc: Allocation, long_path_span: int = 8) -> bool:
    """Constraint (10) check: frame-mode feature maps must stay on-chip.

    Spills are tolerated only for genuinely long-path data: concat/route
    operands and shortcut spans longer than ``long_path_span`` groups (the
    paper stores those off-chip by design)."""
    return all(spill_is_long_path(gg, gid, long_path_span)
               for gid in alloc.spilled)
