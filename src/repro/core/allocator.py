"""Reuse-aware static memory allocation (paper Algorithm 1, §IV-A).

Given a grouped graph and a data-reuse policy L (mode per group, 'row' or
'frame'), statically assign the three interchangeable physical buffers
{0,1,2} to the input / output / shortcut tensors of every frame-mode group,
maximising on-chip shortcut reuse.  Buffer sizes are the max over all
tensors assigned to each buffer (Algorithm 1).

Deviations from the paper, all conservative:
  * allocation is simulated with exact liveness at *group* granularity
    (instructions are per group, Fig. 5b), which reproduces the paper's
    hand-drawn allocations of Fig. 13 for plain / residual / SE blocks;
  * tensors that cannot be held (no free buffer, e.g. FPN lateral data and
    concat operands -- the paper's "long-path" data) are spilled to DRAM,
    exactly as §IV-A prescribes for long-lifetime data;
  * small SE side-path tensors (global-pool + FC outputs) live in a
    dedicated side space, as in Fig. 13(c)/(d).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.grouping import Group, GroupedGraph

NUM_BUFFERS = 3
SIDE_THRESHOLD = 64 << 10           # tensors <= 64 KB ride in the side space
GRAPH_INPUT = -1                    # pseudo producer id of the input image

Policy = dict[int, str]             # gid -> 'row' | 'frame'


@dataclass
class Allocation:
    policy: Policy
    alloc_in: dict[int, int] = field(default_factory=dict)
    alloc_out: dict[int, int] = field(default_factory=dict)
    alloc_shortcut: dict[int, int] = field(default_factory=dict)
    buff: list[int] = field(default_factory=lambda: [0] * NUM_BUFFERS)
    side_buff: int = 0
    # gids whose output was spilled to DRAM although produced in frame mode
    spilled: set[int] = field(default_factory=set)
    # gids whose output additionally crosses a frame->row/final boundary
    boundary_writes: set[int] = field(default_factory=set)
    # frame gids reading (an) input from DRAM (row->frame boundary, spill
    # re-reads, concat gathers).  gid -> bytes read
    boundary_reads: dict[int, int] = field(default_factory=dict)

    @property
    def total_fm_buffer(self) -> int:
        return sum(self.buff) + self.side_buff


def _is_side(gg: GroupedGraph, g: Group) -> bool:
    """SE side-path groups (global-pool / FC chains with tiny outputs)."""
    return (g.head.kind in ("fc", "globalpool")
            and g.out_size <= SIDE_THRESHOLD
            and g.head.out_h == 1 and g.head.out_w == 1)


def allocate(gg: GroupedGraph, policy: Policy) -> Allocation:
    alloc = Allocation(policy=dict(policy))

    # Consumer counts at group level (plus 1 virtual consumer for the final
    # network output so it is always written out).
    consumers: dict[int, list[int]] = {g.gid: gg.group_consumers(g)
                                       for g in gg.groups}
    remaining = {gid: len(c) for gid, c in consumers.items()}

    # location of each produced tensor: buffer id, 'side', or 'dram'
    location: dict[int, int | str] = {GRAPH_INPUT: "dram"}
    live_in_buffer: dict[int, int] = {}          # buffer id -> producing gid

    def free_buffer_for(exclude: set[int]) -> int | None:
        for b in range(NUM_BUFFERS):
            if b not in live_in_buffer and b not in exclude:
                return b
        return None

    def release_if_dead(gid: int) -> None:
        if gid == GRAPH_INPUT or remaining.get(gid, 0) > 0:
            return
        loc = location.get(gid)
        if isinstance(loc, int) and live_in_buffer.get(loc) == gid:
            del live_in_buffer[loc]

    for g in gg.groups:
        mode = policy[g.gid]
        gin = gg.group_inputs(g)
        sc_src = gg.shortcut_source_group(g)

        if _is_side(gg, g):
            # SE side path: on-chip side space regardless of mode.
            alloc.side_buff = max(alloc.side_buff, g.out_size)
            location[g.gid] = "side"
            for src in gin:
                remaining[src] = remaining.get(src, 1) - 1
                release_if_dead(src)
            continue

        if mode == "row":
            # Feature maps stream through DRAM; no {0,1,2} assignment.
            location[g.gid] = "dram"
            for src in gin:
                remaining[src] = remaining.get(src, 1) - 1
                # A frame-produced tensor consumed by a row group must have
                # been written to DRAM at the boundary.
                if isinstance(location.get(src), int):
                    alloc.boundary_writes.add(src)
                release_if_dead(src)
            continue

        # ---------------------------------------------------- frame mode
        in_buffers: set[int] = set()
        read_bytes = 0
        for src in gin:
            loc = location.get(src, "dram")
            if isinstance(loc, int):
                in_buffers.add(loc)
            elif loc == "dram":
                # row->frame boundary (or spilled/long-path data): the
                # group's input is fetched from DRAM into its input buffer.
                src_size = (gg.graph.nodes[0].out_size if src == GRAPH_INPUT
                            else gg.groups[src].out_size)
                read_bytes += src_size
        if read_bytes:
            alloc.boundary_reads[g.gid] = (
                alloc.boundary_reads.get(g.gid, 0) + read_bytes)

        # Record alloc_in / alloc_shortcut from where the operands live.
        main_src = gin[0] if gin else GRAPH_INPUT
        main_loc = location.get(main_src, "dram")
        if isinstance(main_loc, int):
            alloc.alloc_in[g.gid] = main_loc
            alloc.buff[main_loc] = max(alloc.buff[main_loc], g.in_size)
        else:
            b = free_buffer_for(set())
            if b is not None:
                alloc.alloc_in[g.gid] = b
                alloc.buff[b] = max(alloc.buff[b], g.in_size)
                # transient: the fetched input lives only during this group,
                # but the output must not clobber it while it is being read.
                in_buffers.add(b)
        if sc_src is not None:
            sloc = location.get(sc_src, "dram")
            if isinstance(sloc, int):
                alloc.alloc_shortcut[g.gid] = sloc
                alloc.buff[sloc] = max(alloc.buff[sloc],
                                       gg.groups[sc_src].out_size)

        # Consume inputs (shortcut included -- group_inputs covers it).
        for src in gin:
            remaining[src] = remaining.get(src, 1) - 1

        # Concat operands are long-path by definition: producers must have
        # spilled (handled below when the producer ran) or be re-read.
        if remaining.get(g.gid, 0) == 0:
            # Final output: written straight to DRAM through the write
            # buffer (eq. 5 final_layers term).
            location[g.gid] = "dram"
            alloc.boundary_writes.add(g.gid)
        else:
            exclude = set(in_buffers)
            b = free_buffer_for(exclude)
            if b is None:
                # reuse the main input's buffer if the input dies here
                if (isinstance(main_loc, int)
                        and remaining.get(main_src, 0) == 0
                        and live_in_buffer.get(main_loc) == main_src):
                    del live_in_buffer[main_loc]
                    b = main_loc
            if b is None:
                # Long-path data (paper §IV-A): spill to DRAM.
                location[g.gid] = "dram"
                alloc.spilled.add(g.gid)
            else:
                location[g.gid] = b
                live_in_buffer[b] = g.gid
                alloc.alloc_out[g.gid] = b
                alloc.buff[b] = max(alloc.buff[b], g.out_size)

        for src in gin:
            release_if_dead(src)

    return alloc


def frame_feasible(gg: GroupedGraph, policy: Policy,
                   alloc: Allocation, long_path_span: int = 8) -> bool:
    """Constraint (10) check: frame-mode feature maps must stay on-chip.

    Spills are tolerated only for genuinely long-path data: concat/route
    operands and shortcut spans longer than ``long_path_span`` groups (the
    paper stores those off-chip by design)."""
    for gid in alloc.spilled:
        g = gg.groups[gid]
        cons = gg.group_consumers(g)
        long_path = any(gg.groups[c].kind in ("concat", "route") for c in cons)
        if not long_path:
            span = max((c - gid for c in cons), default=0)
            long_path = span > long_path_span
        if not long_path:
            return False
    return True
