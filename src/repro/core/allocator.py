"""Reuse-aware static memory allocation (paper Algorithm 1, §IV-A).

Given a grouped graph and a data-reuse policy L (mode per group, 'row' or
'frame'), statically assign the three interchangeable physical buffers
{0,1,2} to the input / output / shortcut tensors of every frame-mode group,
maximising on-chip shortcut reuse.  Buffer sizes are the max over all
tensors assigned to each buffer (Algorithm 1).

Deviations from the paper, all conservative:
  * allocation is simulated with exact liveness at *group* granularity
    (instructions are per group, Fig. 5b), which reproduces the paper's
    hand-drawn allocations of Fig. 13 for plain / residual / SE blocks;
  * tensors that cannot be held (no free buffer, e.g. FPN lateral data and
    concat operands -- the paper's "long-path" data) are spilled to DRAM,
    exactly as §IV-A prescribes for long-lifetime data;
  * small SE side-path tensors (global-pool + FC outputs) live in a
    dedicated side space, as in Fig. 13(c)/(d).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np

from repro.core.grouping import Group, GroupedGraph

NUM_BUFFERS = 3
SIDE_THRESHOLD = 64 << 10           # tensors <= 64 KB ride in the side space
GRAPH_INPUT = -1                    # pseudo producer id of the input image

# Integer encoding of ``AllocState.location`` shared by the export/import
# round-trip below and the scan-style device replay (kernels/alloc_scan.py):
# buffer ids {0,1,2} map to themselves, the two symbolic locations get the
# codes past the last buffer, and an empty ``live_in_buffer`` slot is
# ``LIVE_EMPTY`` (safe: real gids are >= 0 and the graph input never owns a
# buffer).
LOC_SIDE = NUM_BUFFERS
LOC_DRAM = NUM_BUFFERS + 1
LIVE_EMPTY = -1

Policy = dict[int, str]             # gid -> 'row' | 'frame'


@dataclass
class Allocation:
    policy: Policy
    alloc_in: dict[int, int] = field(default_factory=dict)
    alloc_out: dict[int, int] = field(default_factory=dict)
    alloc_shortcut: dict[int, int] = field(default_factory=dict)
    buff: list[int] = field(default_factory=lambda: [0] * NUM_BUFFERS)
    side_buff: int = 0
    # gids whose output was spilled to DRAM although produced in frame mode
    spilled: set[int] = field(default_factory=set)
    # gids whose output additionally crosses a frame->row/final boundary
    boundary_writes: set[int] = field(default_factory=set)
    # frame gids reading (an) input from DRAM (row->frame boundary, spill
    # re-reads, concat gathers).  gid -> bytes read
    boundary_reads: dict[int, int] = field(default_factory=dict)

    @property
    def total_fm_buffer(self) -> int:
        return sum(self.buff) + self.side_buff


def _is_side(gg: GroupedGraph, g: Group) -> bool:
    """SE side-path groups (global-pool / FC chains with tiny outputs)."""
    return (g.head.kind in ("fc", "globalpool")
            and g.out_size <= SIDE_THRESHOLD
            and g.head.out_h == 1 and g.head.out_w == 1)


@dataclass
class AllocState:
    """Full sequential allocator state after processing a prefix of groups.

    The allocator walks groups in gid order; everything it carries between
    iterations lives here, so a snapshot taken at any group boundary can be
    cloned and replayed forward (the cut-point engine checkpoints these at
    monotone-run boundaries to make candidate evaluation incremental, and
    ``score_batch`` replays each shared cut prefix of a batch exactly once
    from these checkpoints).

    ``remaining`` and ``location`` are flat per-gid lists rather than
    dicts: a checkpoint clone is then two C-level list copies, which is
    what keeps the millions of per-candidate replays of a batched
    exhaustive search cheap.  Index ``-1`` (Python's last-element alias)
    is the ``GRAPH_INPUT`` pseudo producer, so ``remaining[src]`` /
    ``location[src]`` work verbatim for real gids and the graph input.

    ``lean=True`` (the search engines) skips recording the
    ``alloc_in``/``alloc_out``/``alloc_shortcut`` assignment maps: they
    never influence metrics, and the winning tuple is re-materialized
    through the full oracle anyway, so the engine neither writes nor
    clones them."""
    alloc: Allocation
    # consumer counts not yet satisfied, per gid ([-1] = graph input)
    remaining: list[int]
    # location of each produced tensor: buffer id, 'side', or 'dram'
    location: list[int | str]
    # buffer id -> producing gid currently held live
    live_in_buffer: dict[int, int]
    # skip the assignment-map record keeping (search-engine replays)
    lean: bool = False
    # journals of boundary-set additions since the caller last cleared
    # them: each ``alloc_step`` that grows ``boundary_writes`` /
    # ``boundary_reads`` / ``spilled`` appends the gid here.  The search
    # engine drains these per replayed run to update its incremental
    # cost extraction in O(additions) instead of re-walking the full
    # (mostly prefix-shared) boundary sets per candidate.
    j_writes: list[int] = field(default_factory=list)
    j_reads: list[int] = field(default_factory=list)
    j_spills: list[int] = field(default_factory=list)

    def clone(self) -> "AllocState":
        # journals intentionally start empty: snapshots are taken at run
        # boundaries, after the caller drained them
        a = self.alloc
        return AllocState(
            alloc=Allocation(
                policy=dict(a.policy),
                alloc_in=dict(a.alloc_in), alloc_out=dict(a.alloc_out),
                alloc_shortcut=dict(a.alloc_shortcut), buff=list(a.buff),
                side_buff=a.side_buff, spilled=set(a.spilled),
                boundary_writes=set(a.boundary_writes),
                boundary_reads=dict(a.boundary_reads)),
            remaining=self.remaining.copy(),
            location=self.location.copy(),
            live_in_buffer=dict(self.live_in_buffer),
            lean=self.lean)

def init_alloc_state(gg: GroupedGraph, lean: bool = False) -> AllocState:
    # Consumer counts at group level (plus 1 virtual consumer for the final
    # network output so it is always written out).  The trailing slot is
    # GRAPH_INPUT (= index -1): location starts at 'dram'; its remaining
    # count starts at 1, matching the dict-era ``.get(src, 1)`` default.
    remaining = [len(gg.group_consumers(g)) for g in gg.groups] + [1]
    location: list[int | str] = ["dram"] * (len(gg.groups) + 1)
    return AllocState(alloc=Allocation(policy={}), remaining=remaining,
                      location=location, live_in_buffer={}, lean=lean)


class GroupStep(NamedTuple):
    """Static per-group facts consumed by the allocator loop body, resolved
    once per graph so replays touch no Group/GroupedGraph objects.  A
    NamedTuple so the (very hot) ``alloc_step`` body unpacks it in one
    bytecode instead of eight attribute lookups."""
    gid: int
    is_side: bool
    gin: tuple[int, ...]          # producing gids (main path first)
    src_sizes: tuple[int, ...]    # out bytes of each gin source
    sc_src: int | None
    sc_size: int
    in_size: int
    out_size: int


def graph_steps(gg: GroupedGraph) -> list[GroupStep]:
    """Per-graph step table, cached on the GroupedGraph."""
    steps = getattr(gg, "_alloc_steps", None)
    if steps is not None:
        return steps
    input_size = gg.graph.nodes[0].out_size
    steps = []
    for g in gg.groups:
        gin = tuple(gg.group_inputs(g))
        sc_src = gg.shortcut_source_group(g)
        steps.append(GroupStep(
            gid=g.gid, is_side=_is_side(gg, g), gin=gin,
            src_sizes=tuple(input_size if s == GRAPH_INPUT
                            else gg.groups[s].out_size for s in gin),
            sc_src=sc_src,
            sc_size=gg.groups[sc_src].out_size if sc_src is not None else 0,
            in_size=g.in_size, out_size=g.out_size))
    gg._alloc_steps = steps
    return steps


def alloc_step(state: AllocState, step: GroupStep, mode: str) -> None:
    """Process one group under ``mode``, advancing ``state`` in place.

    This is the loop body of Algorithm 1; ``allocate`` applies it to every
    group and the incremental search engine replays it from a checkpoint
    (millions of times per exhaustive search -- the body is written with
    flat list indexing and no per-call allocations on purpose)."""
    (gid, is_side, gin, src_sizes, sc_src, sc_size,
     in_size, out_size) = step
    alloc = state.alloc
    remaining = state.remaining
    location = state.location
    live_in_buffer = state.live_in_buffer

    # "release if dead" -- a consumed tensor whose last consumer this is
    # frees its buffer -- is inlined at each consumption site below
    # (type(loc) is int: locations are exactly int | str).

    if is_side:
        # SE side path: on-chip side space regardless of mode.
        if out_size > alloc.side_buff:
            alloc.side_buff = out_size
        location[gid] = "side"
        for src in gin:
            r = remaining[src] - 1
            remaining[src] = r
            if r <= 0 and src != GRAPH_INPUT:
                loc = location[src]
                if type(loc) is int and live_in_buffer.get(loc) == src:
                    del live_in_buffer[loc]
        return

    if mode == "row":
        # Feature maps stream through DRAM; no {0,1,2} assignment.
        location[gid] = "dram"
        bw = alloc.boundary_writes
        for src in gin:
            r = remaining[src] - 1
            remaining[src] = r
            loc = location[src]
            if type(loc) is int:
                # A frame-produced tensor consumed by a row group must
                # have been written to DRAM at the boundary.
                if src not in bw:
                    bw.add(src)
                    state.j_writes.append(src)
                if (r <= 0 and src != GRAPH_INPUT
                        and live_in_buffer.get(loc) == src):
                    del live_in_buffer[loc]
        return

    # ---------------------------------------------------- frame mode
    in_buffers: set[int] = set()
    read_bytes = 0
    for src, src_size in zip(gin, src_sizes):
        loc = location[src]
        if type(loc) is int:
            in_buffers.add(loc)
        elif loc == "dram":
            # row->frame boundary (or spilled/long-path data): the
            # group's input is fetched from DRAM into its input buffer.
            read_bytes += src_size
    if read_bytes:
        alloc.boundary_reads[gid] = (
            alloc.boundary_reads.get(gid, 0) + read_bytes)
        state.j_reads.append(gid)

    # Record alloc_in / alloc_shortcut from where the operands live.
    record = not state.lean
    main_src = gin[0] if gin else GRAPH_INPUT
    main_loc = location[main_src]
    buff = alloc.buff
    if type(main_loc) is int:
        if record:
            alloc.alloc_in[gid] = main_loc
        if in_size > buff[main_loc]:
            buff[main_loc] = in_size
    else:
        b = None
        for i in range(NUM_BUFFERS):
            if i not in live_in_buffer:
                b = i
                break
        if b is not None:
            if record:
                alloc.alloc_in[gid] = b
            if in_size > buff[b]:
                buff[b] = in_size
            # transient: the fetched input lives only during this group,
            # but the output must not clobber it while it is being read.
            in_buffers.add(b)
    if sc_src is not None:
        sloc = location[sc_src]
        if type(sloc) is int:
            if record:
                alloc.alloc_shortcut[gid] = sloc
            if sc_size > buff[sloc]:
                buff[sloc] = sc_size

    # Consume inputs (shortcut included -- group_inputs covers it).
    for src in gin:
        remaining[src] -= 1

    # Concat operands are long-path by definition: producers must have
    # spilled (handled below when the producer ran) or be re-read.
    if remaining[gid] == 0:
        # Final output: written straight to DRAM through the write
        # buffer (eq. 5 final_layers term).
        location[gid] = "dram"
        bw = alloc.boundary_writes
        if gid not in bw:
            bw.add(gid)
            state.j_writes.append(gid)
    else:
        b = None
        for i in range(NUM_BUFFERS):
            if i not in live_in_buffer and i not in in_buffers:
                b = i
                break
        if b is None:
            # reuse the main input's buffer if the input dies here
            if (type(main_loc) is int
                    and remaining[main_src] == 0
                    and live_in_buffer.get(main_loc) == main_src):
                del live_in_buffer[main_loc]
                b = main_loc
        if b is None:
            # Long-path data (paper §IV-A): spill to DRAM.
            location[gid] = "dram"
            sp = alloc.spilled
            if gid not in sp:
                sp.add(gid)
                state.j_spills.append(gid)
        else:
            location[gid] = b
            live_in_buffer[b] = gid
            if record:
                alloc.alloc_out[gid] = b
            if out_size > buff[b]:
                buff[b] = out_size

    for src in gin:
        if remaining[src] <= 0 and src != GRAPH_INPUT:
            loc = location[src]
            if type(loc) is int and live_in_buffer.get(loc) == src:
                del live_in_buffer[loc]


def allocate(gg: GroupedGraph, policy: Policy) -> Allocation:
    state = init_alloc_state(gg)
    state.alloc.policy = dict(policy)
    for step in graph_steps(gg):
        alloc_step(state, step, policy[step.gid])
    return state.alloc


def iter_alloc_states(gg: GroupedGraph, policy: Policy):
    """Journal export: replay Algorithm 1 under ``policy`` and yield
    ``(step, state)`` after every ``alloc_step``.

    The yielded ``AllocState`` is the live (mutating) replay state, not a
    snapshot -- callers that only *observe* per-step facts (buffer
    ownership transitions, boundary-journal additions) read what they need
    before advancing.  This is what the static verifier
    (``repro.analysis.liveness``) derives per-buffer live intervals from:
    ``live_in_buffer`` transitions between consecutive yields are exactly
    the buffer claim/release events of the allocator's journal, and the
    ``j_writes``/``j_reads``/``j_spills`` journals carry the boundary-set
    additions of the step just executed (drained per yield)."""
    state = init_alloc_state(gg)
    state.alloc.policy = dict(policy)
    for step in graph_steps(gg):
        state.j_writes.clear()
        state.j_reads.clear()
        state.j_spills.clear()
        alloc_step(state, step, policy[step.gid])
        yield step, state


# --------------------------------------------------- state tensorization
# ``AllocState`` is a handful of Python containers; the scan-style device
# replay needs the same information as fixed-width integer arrays (one
# lane per gid).  ``state_to_arrays`` / ``arrays_to_state`` are the
# canonical encoding -- kernels/alloc_scan.py seeds its initial scan state
# from the exported ``init_alloc_state`` and tests round-trip arbitrary
# mid-replay snapshots through both directions.

def state_to_arrays(state: AllocState) -> dict[str, np.ndarray]:
    """Encode a (lean) allocator state as fixed-width integer arrays.

    Layout (``n`` = group count; the trailing slot of the per-gid arrays
    is the ``GRAPH_INPUT`` pseudo producer, mirroring the list encoding
    where index ``-1`` aliases the last element):

    ====================  =======================================
    ``remaining``         (n+1,) int64 unmet consumer counts
    ``location``          (n+1,) int8  ``LOC_*`` codes / buffer id
    ``live``              (3,)   int64 owning gid or ``LIVE_EMPTY``
    ``buff``              (3,)   int64 buffer byte maxima
    ``side_buff``         ()     int64
    ``boundary_writes``   (n,)   bool
    ``boundary_reads``    (n,)   int64 bytes per consuming gid
    ``spilled``           (n,)   bool
    ====================  =======================================

    The metrics-irrelevant assignment maps (``alloc_in`` etc.) and the
    drained journals are intentionally not part of the encoding -- they
    are exactly what ``lean`` replay states never carry."""
    n = len(state.remaining) - 1
    a = state.alloc
    location = np.empty(n + 1, dtype=np.int8)
    for i, loc in enumerate(state.location):
        location[i] = (loc if type(loc) is int
                       else LOC_SIDE if loc == "side" else LOC_DRAM)
    live = np.full(NUM_BUFFERS, LIVE_EMPTY, dtype=np.int64)
    for b, gid in state.live_in_buffer.items():
        live[b] = gid
    bw = np.zeros(n, dtype=bool)
    bw[list(a.boundary_writes)] = True
    br = np.zeros(n, dtype=np.int64)
    for gid, v in a.boundary_reads.items():
        br[gid] = v
    spilled = np.zeros(n, dtype=bool)
    spilled[list(a.spilled)] = True
    return {
        "remaining": np.asarray(state.remaining, dtype=np.int64),
        "location": location,
        "live": live,
        "buff": np.asarray(a.buff, dtype=np.int64),
        "side_buff": np.int64(a.side_buff),
        "boundary_writes": bw,
        "boundary_reads": br,
        "spilled": spilled,
    }


def arrays_to_state(arrays: dict[str, np.ndarray],
                    lean: bool = True) -> AllocState:
    """Inverse of :func:`state_to_arrays`: rebuild a replayable
    ``AllocState`` from the tensor encoding.  ``alloc_step`` can continue
    from the result exactly as from the original snapshot."""
    location: list[int | str] = [
        int(c) if c < NUM_BUFFERS else ("side" if c == LOC_SIDE else "dram")
        for c in arrays["location"].tolist()]
    live = {b: gid for b, gid in enumerate(arrays["live"].tolist())
            if gid != LIVE_EMPTY}
    bw = {int(g) for g in np.flatnonzero(arrays["boundary_writes"])}
    br_arr = arrays["boundary_reads"]
    br = {int(g): int(br_arr[g]) for g in np.flatnonzero(br_arr)}
    sp = {int(g) for g in np.flatnonzero(arrays["spilled"])}
    alloc = Allocation(policy={}, buff=arrays["buff"].astype(int).tolist(),
                       side_buff=int(arrays["side_buff"]), spilled=sp,
                       boundary_writes=bw, boundary_reads=br)
    return AllocState(alloc=alloc,
                      remaining=arrays["remaining"].astype(int).tolist(),
                      location=location, live_in_buffer=live, lean=lean)


def alloc_bound_terms(state: AllocState) -> tuple[int, int, int, int]:
    """Monotone buffer terms of a (checkpointed) prefix state:
    ``(buff[0], buff[1], buff[2], side_buff)``.

    Every one of these is only ever *max-updated* by ``alloc_step`` (the
    ``if x > buff[b]`` / ``if out_size > side_buff`` sites above), so the
    values read from any prefix state lower-bound the values of every
    replay that continues from it, whatever modes the remaining groups
    take.  The same monotonicity holds for the boundary sets
    (``boundary_writes`` / ``boundary_reads`` / ``spilled`` only grow),
    which is what makes the cut-point engine's incremental accumulators
    (``_x_io`` / ``_x_bfm`` / ``_x_wrf``) valid prefix floors too.  The
    branch-and-bound pruner (``cutpoint.CutpointEngine.prefix_bound``)
    builds its admissible SRAM floor from exactly these terms."""
    a = state.alloc
    b = a.buff
    return b[0], b[1], b[2], a.side_buff


def spill_is_long_path(gg: GroupedGraph, gid: int,
                       long_path_span: int = 8) -> bool:
    """Whether a spill of ``gid``'s output is tolerable long-path data
    (policy-independent, so the search engine precomputes it per gid)."""
    g = gg.groups[gid]
    cons = gg.group_consumers(g)
    if any(gg.groups[c].kind in ("concat", "route") for c in cons):
        return True
    span = max((c - gid for c in cons), default=0)
    return span > long_path_span


def frame_feasible(gg: GroupedGraph, policy: Policy,
                   alloc: Allocation, long_path_span: int = 8) -> bool:
    """Constraint (10) check: frame-mode feature maps must stay on-chip.

    Spills are tolerated only for genuinely long-path data: concat/route
    operands and shortcut spans longer than ``long_path_span`` groups (the
    paper stores those off-chip by design)."""
    return all(spill_is_long_path(gg, gid, long_path_span)
               for gid in alloc.spilled)
