"""Group-wise instruction generation (paper Fig. 5b).

Each node group is described by an 11-word instruction (32-bit words): the
convolution geometry, activation type, pooling/upsampling option, fused
element-wise (shortcut) operand, data-reuse mode, and the static buffer
allocation {alloc_in, alloc_out, alloc_shortcut} from Algorithm 1.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.allocator import Allocation
from repro.core.grouping import GroupedGraph

WORDS = 11

OPCODES = {"conv": 0, "dwconv": 1, "fc": 2, "add": 3, "concat": 4,
           "route": 5, "upsample": 6, "maxpool": 7, "avgpool": 8,
           "globalpool": 9, "scale": 10}
ACTS = {"linear": 0, "relu": 1, "leaky": 2, "swish": 3, "sigmoid": 4}
MODES = {"row": 0, "frame": 1}
OFFCHIP = 3                                    # buffer id meaning DRAM

# Bit width of every unsigned field in the 11-word encoding, in the order
# encode() packs them.  This is the single source of truth for range
# validation: encode() refuses to emit a word a field does not fit in, and
# the static verifier (repro.analysis) checks decoded/mutated instructions
# against the same table without encoding them.
FIELD_WIDTHS = {
    "opcode": 8, "mode": 4, "act": 4, "k": 8, "stride": 8,       # word 0
    "in_ch": 32, "out_ch": 32, "in_h": 32, "in_w": 32,           # words 1-4
    "fused_pool": 8, "fused_eltwise": 8, "fused_upsample": 8,    # word 5
    "alloc_in": 4, "alloc_out": 4, "alloc_shortcut": 4,          # word 6
    "gid": 32,                                                   # word 9
}
# src_main / src_shortcut (words 7/8) are signed 32-bit: -1 is the
# network-input / no-shortcut sentinel.
SIGNED_FIELDS = ("src_main", "src_shortcut")


def field_overflows(name: str, value: int) -> bool:
    """True if ``value`` does not fit the encoding slot of ``name``."""
    if name in SIGNED_FIELDS:
        return not (-(1 << 31) <= value < (1 << 31))
    return not (0 <= value < (1 << FIELD_WIDTHS[name]))


@dataclass
class GroupInstruction:
    gid: int
    opcode: int
    mode: int
    k: int
    stride: int
    in_ch: int
    out_ch: int
    in_h: int
    in_w: int
    act: int
    fused_pool: int          # 0 none, 1 max2x2, 2 global-avg
    fused_eltwise: int       # 0 none, 1 add
    fused_upsample: int
    alloc_in: int            # {0,1,2} or OFFCHIP
    alloc_out: int
    alloc_shortcut: int
    src_main: int            # producer gid (-1 = network input)
    src_shortcut: int        # producer gid of shortcut operand (-1 = none)

    def encode(self) -> np.ndarray:
        # Refuse to emit a truncated word: a field past its slot width used
        # to be silently masked (``& 0xFF`` etc.), corrupting the stream.
        for name in FIELD_WIDTHS:
            if field_overflows(name, getattr(self, name)):
                raise ValueError(
                    f"GroupInstruction.encode: field {name}="
                    f"{getattr(self, name)} overflows its "
                    f"{FIELD_WIDTHS[name]}-bit slot (gid {self.gid})")
        for name in SIGNED_FIELDS:
            if field_overflows(name, getattr(self, name)):
                raise ValueError(
                    f"GroupInstruction.encode: field {name}="
                    f"{getattr(self, name)} overflows its signed 32-bit "
                    f"slot (gid {self.gid})")
        w = np.zeros(WORDS, dtype=np.uint32)
        w[0] = (self.opcode) | ((self.mode) << 8) \
            | ((self.act) << 12) | ((self.k) << 16) \
            | ((self.stride) << 24)
        w[1] = self.in_ch
        w[2] = self.out_ch
        w[3] = self.in_h
        w[4] = self.in_w
        w[5] = (self.fused_pool) | ((self.fused_eltwise) << 8) \
            | ((self.fused_upsample) << 16)
        w[6] = (self.alloc_in) | ((self.alloc_out) << 4) \
            | ((self.alloc_shortcut) << 8)
        w[7] = np.uint32(self.src_main & 0xFFFFFFFF)
        w[8] = np.uint32(self.src_shortcut & 0xFFFFFFFF)
        w[9] = self.gid
        w[10] = 0xC0FFEE                        # group terminator marker
        return w

    @classmethod
    def decode(cls, w: np.ndarray) -> "GroupInstruction":
        if int(w[10]) != 0xC0FFEE:
            raise ValueError(
                f"corrupt instruction stream: terminator word is "
                f"{int(w[10]):#x}, expected 0xc0ffee")
        return cls(
            gid=int(w[9]),
            opcode=int(w[0]) & 0xFF, mode=(int(w[0]) >> 8) & 0xF,
            act=(int(w[0]) >> 12) & 0xF, k=(int(w[0]) >> 16) & 0xFF,
            stride=(int(w[0]) >> 24) & 0xFF,
            in_ch=int(w[1]), out_ch=int(w[2]), in_h=int(w[3]), in_w=int(w[4]),
            fused_pool=int(w[5]) & 0xFF, fused_eltwise=(int(w[5]) >> 8) & 0xFF,
            fused_upsample=(int(w[5]) >> 16) & 0xFF,
            alloc_in=int(w[6]) & 0xF, alloc_out=(int(w[6]) >> 4) & 0xF,
            alloc_shortcut=(int(w[6]) >> 8) & 0xF,
            src_main=int(np.int32(np.uint32(w[7]))),
            src_shortcut=int(np.int32(np.uint32(w[8]))))


def generate_instructions(gg: GroupedGraph,
                          alloc: Allocation) -> list[GroupInstruction]:
    ins: list[GroupInstruction] = []
    for g in gg.groups:
        head, tail = g.head, g.tail
        fused_pool = 0
        fused_elt = 0
        fused_up = 0
        for n in g.nodes[1:] if head.is_compute else g.nodes:
            if n.kind == "maxpool":
                fused_pool = 1
            elif n.kind in ("avgpool", "globalpool"):
                fused_pool = 2
            elif n.kind == "add":
                fused_elt = 1
            elif n.kind == "upsample":
                fused_up = n.stride
        gin = gg.group_inputs(g)
        sc = gg.shortcut_source_group(g)
        ins.append(GroupInstruction(
            gid=g.gid,
            opcode=OPCODES[head.kind],
            mode=MODES[alloc.policy[g.gid]],
            k=head.k, stride=head.stride,
            in_ch=head.in_ch, out_ch=tail.out_ch,
            in_h=head.in_h, in_w=head.in_w,
            act=ACTS.get(head.act, 0),
            fused_pool=fused_pool, fused_eltwise=fused_elt,
            fused_upsample=fused_up,
            alloc_in=alloc.alloc_in.get(g.gid, OFFCHIP),
            alloc_out=alloc.alloc_out.get(g.gid, OFFCHIP),
            alloc_shortcut=alloc.alloc_shortcut.get(g.gid, OFFCHIP),
            src_main=gin[0] if gin else -1,
            src_shortcut=sc if sc is not None else -1))
    return ins


def encode_stream(instructions: list[GroupInstruction]) -> np.ndarray:
    return np.concatenate([i.encode() for i in instructions])


def decode_stream(stream: np.ndarray) -> list[GroupInstruction]:
    if stream.size % WORDS != 0:
        raise ValueError(
            f"instruction stream of {stream.size} words is not a multiple "
            f"of the {WORDS}-word instruction size (truncated or "
            f"misaligned stream)")
    return [GroupInstruction.decode(stream[i:i + WORDS])
            for i in range(0, stream.size, WORDS)]
