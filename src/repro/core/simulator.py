"""Functional accelerator simulator.

Executes a compiled instruction stream against an explicit memory model:
DRAM (tensor store + byte counters) and the three physical on-chip buffers
{0,1,2} plus the SE side space.  Data movement follows the instruction
fields produced by the compiler; math is delegated to the same per-node ops
as the JAX reference, so

  * numerical equality with cnn/jax_ref.run_graph validates the grouping,
    the static buffer allocation and the instruction encoding (a clobbered
    buffer corrupts the output), and
  * the DRAM byte counters validate the analytical model of core/dram.py.

``execute=False`` runs the memory model only (dry traffic count) so full
YOLO-scale networks can be audited in milliseconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.cnn.jax_ref import apply_node
from repro.core.allocator import Allocation, _is_side
from repro.core.grouping import GroupedGraph
from repro.core.isa import OFFCHIP, GroupInstruction


@dataclass
class MemCounters:
    dram_reads: int = 0
    dram_writes: int = 0
    weight_reads: int = 0
    onchip_hits: int = 0
    # counted DRAM reads of a tensor nothing ever wrote to DRAM -- always 0
    # for a healthy plan; nonzero means the stream reads garbage (the
    # dynamic twin of the static verifier's SF021/SF022/SF041)
    dangling_reads: int = 0

    @property
    def fm_total(self) -> int:
        return self.dram_reads + self.dram_writes

    @property
    def total(self) -> int:
        return self.fm_total + self.weight_reads


@dataclass
class SimState:
    # gid -> tensor (None in dry mode)
    dram: dict[int, object] = field(default_factory=dict)
    buffers: dict[int, tuple[int, object]] = field(default_factory=dict)
    side: dict[int, object] = field(default_factory=dict)
    node_side: dict[int, object] = field(default_factory=dict)
    counters: MemCounters = field(default_factory=MemCounters)


class Simulator:
    def __init__(self, gg: GroupedGraph, alloc: Allocation,
                 instructions: list[GroupInstruction],
                 params: dict[int, np.ndarray] | None = None,
                 execute: bool = True):
        self.gg = gg
        self.alloc = alloc
        self.instructions = {i.gid: i for i in instructions}
        self.params = params or {}
        self.execute = execute
        self.state = SimState()

    # ------------------------------------------------------------ plumbing
    def _tensor_bytes(self, gid: int) -> int:
        if gid == -1:
            return self.gg.graph.nodes[0].out_size
        return self.gg.groups[gid].out_size

    def _fetch(self, src_gid: int, frame_mode: bool, count: bool = True):
        """Fetch an operand tensor, updating counters per its location.

        Row-mode consumers always stream from DRAM, even if a stale copy
        sits in a buffer (the hardware's row pipeline has no random access
        into the frame buffers)."""
        st = self.state
        if src_gid in st.side:
            return st.side[src_gid]
        if frame_mode:
            for _b, (owner, tensor) in st.buffers.items():
                if owner == src_gid:
                    st.counters.onchip_hits += self._tensor_bytes(src_gid)
                    return tensor
        # DRAM read (row streaming, boundary, spill or network input).
        if count:
            st.counters.dram_reads += self._tensor_bytes(src_gid)
            if src_gid not in st.dram:
                st.counters.dangling_reads += 1
        return st.dram.get(src_gid)

    def _store(self, gid: int, tensor, instr: GroupInstruction) -> None:
        st = self.state
        g = self.gg.groups[gid]
        is_frame = instr.mode == 1
        if _is_side(self.gg, g):
            st.side[gid] = tensor
            return
        if not is_frame:
            if g.kind not in ("concat", "route"):   # redirect writes nothing
                st.counters.dram_writes += g.out_size
            st.dram[gid] = tensor
            return
        spilled = gid in self.alloc.spilled
        boundary = gid in self.alloc.boundary_writes
        if instr.alloc_out != OFFCHIP and not spilled:
            # evict previous owner of the physical buffer
            st.buffers[instr.alloc_out] = (gid, tensor)
        if spilled or boundary:
            st.counters.dram_writes += g.out_size
            st.dram[gid] = tensor

    # ------------------------------------------------------------- running
    def run(self, x: np.ndarray | None = None):
        st = self.state
        if self.execute:
            assert x is not None
            st.dram[-1] = np.asarray(x)
        else:
            # Dry mode tracks locations only, but the network input is
            # still DRAM-resident -- seed it so the dangling-read counter
            # never misfires on the first fetch.
            st.dram[-1] = None

        final = None
        for g in self.gg.groups:
            instr = self.instructions[g.gid]
            # ---- weights: streamed from DRAM exactly once (constraint 10)
            st.counters.weight_reads += g.weight_size
            # ---- gather operands
            gin = self.gg.group_inputs(g)
            frame = instr.mode == 1
            # Redirected feature-merging (row concat/route) and the SE side
            # path move no DRAM data (see dram.py).
            count = not (_is_side(self.gg, g)
                         or (not frame and g.kind in ("concat", "route")))
            operands = ([self._fetch(s, frame, count) for s in gin]
                        if gin else [self._fetch(-1, frame, count)])
            # ---- compute
            out = None
            if self.execute:
                out = self._execute_group(g, gin, operands)
            self._store(g.gid, out, instr)
            final = out if self.execute else None
        return final

    def _execute_group(self, g, gin, operands):
        # Map producer gid -> tensor for resolving node-level inputs.
        env: dict[int, object] = {}
        src_map = dict(zip(gin, operands)) if gin else {-1: operands[0]}

        def node_operand(i: int):
            if i in env:
                return env[i]
            owner = self.gg.node_group[i]
            if owner == g.gid:
                return env[i]
            og = self.gg.groups[owner] if owner >= 0 else None
            if og is not None and og.tail.idx != i:
                # Side product of a dual-output group (SE pooled copy):
                # delivered through the on-chip side space, never DRAM.
                return self.state.node_side[i]
            return src_map[owner]

        out = None
        for n in g.nodes:
            ops = [node_operand(i) for i in n.inputs] or [src_map[-1]]
            out = apply_node(n, ops, self.params)
            env[n.idx] = out
            if g.side_tail is not None and n.idx == g.side_tail.idx:
                self.state.node_side[n.idx] = out
        # The group's main output is its tail node, not necessarily the
        # last node executed (dual-output groups).
        return env[g.tail.idx]


def simulate(gg: GroupedGraph, alloc: Allocation,
             instructions: list[GroupInstruction],
             params: dict[int, np.ndarray] | None = None,
             x: np.ndarray | None = None,
             execute: bool = True) -> tuple[object, MemCounters]:
    sim = Simulator(gg, alloc, instructions, params, execute)
    out = sim.run(x)
    return out, sim.state.counters
