"""End-to-end ShortcutFusion compiler: graph -> ExecutionPlan.

Pipeline (Fig. 4): CNN parser & analyzer (grouping) -> block-wise optimizer
(cut-point search with the reuse-aware allocator + timing/DRAM models) ->
instruction generation.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.allocator import Allocation, allocate, frame_feasible
from repro.core.cutpoint import Candidate, SearchResult, search, sweep_single_cut
from repro.core.dram import DRAMReport, baseline_total, dram_report
from repro.core.grouping import GroupedGraph, group_nodes
from repro.core.hw import FPGAConfig, KCU1500
from repro.core.ir import Graph
from repro.core.isa import GroupInstruction, generate_instructions
from repro.core.sram import SRAMReport, sram_report
from repro.core.timing import LatencyReport, latency_report


@dataclass
class ExecutionPlan:
    graph: Graph
    grouped: GroupedGraph
    hw: FPGAConfig
    candidate: Candidate
    alloc: Allocation
    sram: SRAMReport
    dram: DRAMReport
    latency: LatencyReport
    instructions: list[GroupInstruction]
    search: SearchResult | None = None

    # ------------------------------------------------------------- metrics
    @property
    def latency_ms(self) -> float:
        return 1e3 * self.latency.cycles / self.hw.freq

    @property
    def gops(self) -> float:
        return 2 * self.graph.total_macs() / (self.latency.cycles / self.hw.freq) / 1e9

    @property
    def mac_efficiency(self) -> float:
        return self.gops * 1e9 / self.hw.peak_gops

    @property
    def baseline_dram(self) -> int:
        return baseline_total(self.grouped)

    @property
    def offchip_reduction(self) -> float:
        base = self.baseline_dram
        return (base - self.dram.total) / base if base else 0.0

    def summary(self) -> str:
        mb = 1 / (1 << 20)
        return (f"{self.graph.name}: {len(self.grouped.groups)} groups, "
                f"latency {self.latency_ms:.2f} ms, {self.gops:.0f} GOPS "
                f"(MAC eff {100 * self.mac_efficiency:.1f}%), "
                f"DRAM {self.dram.total * mb:.1f} MB "
                f"(fm {self.dram.fm_bytes * mb:.2f} MB, "
                f"-{100 * self.offchip_reduction:.1f}% vs baseline "
                f"{self.baseline_dram * mb:.1f} MB), "
                f"SRAM {self.sram.sram_total * mb:.3f} MB")


def compile_graph(graph: Graph, hw: FPGAConfig = KCU1500,
                  objective: str = "latency",
                  policy: dict[int, str] | None = None) -> ExecutionPlan:
    """Compile a CNN graph.  If ``policy`` is given it is used verbatim
    (e.g. all-row baseline); otherwise the cut-point optimizer runs."""
    graph.validate()
    gg = group_nodes(graph)
    result: SearchResult | None = None
    if policy is None:
        result = search(gg, hw, objective=objective)
        cand = result.best
        alloc = cand.alloc
    else:
        alloc = allocate(gg, policy)
    sram = sram_report(gg, alloc, hw)
    dram = dram_report(gg, alloc)
    latency = latency_report(gg, alloc, hw)
    if policy is not None:
        feasible = (sram.sram_total <= hw.sram_budget
                    and frame_feasible(gg, policy, alloc))
        cand = Candidate(
            cuts=(), policy=policy, alloc=alloc,
            latency_cycles=latency.cycles,
            dram_total=dram.total, dram_fm=dram.fm_bytes,
            sram_total=sram.sram_total, bram18k=sram.bram18k,
            feasible=feasible)
    return ExecutionPlan(
        graph=graph, grouped=gg, hw=hw, candidate=cand, alloc=alloc,
        sram=sram, dram=dram, latency=latency,
        instructions=generate_instructions(gg, alloc),
        search=result)


def all_row_policy(gg: GroupedGraph) -> dict[int, str]:
    return {g.gid: "row" for g in gg.groups}


def all_frame_policy(gg: GroupedGraph) -> dict[int, str]:
    return {g.gid: "frame" for g in gg.groups}
