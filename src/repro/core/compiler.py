"""End-to-end ShortcutFusion compiler: graph -> ExecutionPlan.

Pipeline (paper Fig. 4), one pass per stage:

1. **Parse & analyze** -- ``grouping.group_nodes`` fuses the node graph
   into accelerator instruction groups (conv + its post-processing chain).
2. **Block-wise optimize** -- ``cutpoint.search`` picks a frame-/row-reuse
   mode per residual block by searching cut positions over the monotone
   runs of feature-map size, scoring each candidate with the reuse-aware
   allocator (allocator.py) plus the SRAM/DRAM/latency models (sram.py /
   dram.py / timing.py).  ``workers > 1`` parallelizes this search across
   processes (search_pool.py) with a bit-identical result.
3. **Generate instructions** -- ``isa.generate_instructions`` lowers the
   winning allocation to the accelerator's register-level instruction
   stream (one GroupInstruction per group).

The result is an :class:`ExecutionPlan`: the chosen policy/allocation, the
three analytic reports the paper tabulates (SRAM, DRAM, latency), derived
metrics (GOPS, MAC efficiency, off-chip reduction vs. the all-row
baseline), and the instruction stream.  Everything is static -- no
hardware or input tensors are involved -- which is what lets
tests/benchmarks audit the plan against the functional simulator
(core/simulator.py) byte-for-byte.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field

from repro.core.allocator import Allocation, allocate, frame_feasible
from repro.core.cutpoint import (DEFAULT_BATCH_SIZE,  # noqa: F401
                                 EXHAUSTIVE_LIMIT, Candidate, SearchResult,
                                 search, sweep_single_cut)
from repro.core.options import CompileOptions, resolve_options
from repro.core.dram import DRAMReport, baseline_total, dram_report
from repro.core.grouping import GroupedGraph, group_nodes
from repro.core.hw import FPGAConfig, KCU1500
from repro.core.ir import Graph
from repro.core.isa import GroupInstruction, generate_instructions
from repro.core.sram import SRAMReport, sram_report
from repro.core.timing import LatencyReport, latency_report


@dataclass
class ExecutionPlan:
    graph: Graph
    grouped: GroupedGraph
    hw: FPGAConfig
    candidate: Candidate
    alloc: Allocation
    sram: SRAMReport
    dram: DRAMReport
    latency: LatencyReport
    instructions: list[GroupInstruction]
    search: SearchResult | None = None
    # static-verifier findings (empty when verify="off" or the plan is
    # clean); see repro.analysis
    diagnostics: list = field(default_factory=list)

    # ------------------------------------------------------------- metrics
    @property
    def latency_ms(self) -> float:
        return 1e3 * self.latency.cycles / self.hw.freq

    @property
    def gops(self) -> float:
        return 2 * self.graph.total_macs() / (self.latency.cycles / self.hw.freq) / 1e9

    @property
    def mac_efficiency(self) -> float:
        return self.gops * 1e9 / self.hw.peak_gops

    @property
    def baseline_dram(self) -> int:
        return baseline_total(self.grouped)

    @property
    def offchip_reduction(self) -> float:
        base = self.baseline_dram
        return (base - self.dram.total) / base if base else 0.0

    def summary(self) -> str:
        mb = 1 / (1 << 20)
        return (f"{self.graph.name}: {len(self.grouped.groups)} groups, "
                f"latency {self.latency_ms:.2f} ms, {self.gops:.0f} GOPS "
                f"(MAC eff {100 * self.mac_efficiency:.1f}%), "
                f"DRAM {self.dram.total * mb:.1f} MB "
                f"(fm {self.dram.fm_bytes * mb:.2f} MB, "
                f"-{100 * self.offchip_reduction:.1f}% vs baseline "
                f"{self.baseline_dram * mb:.1f} MB), "
                f"SRAM {self.sram.sram_total * mb:.3f} MB")


def apply_verification(plan: ExecutionPlan, mode: str,
                       site: str = "compile_graph") -> ExecutionPlan:
    """Run the static plan verifier (``repro.analysis``) over a finished
    plan, per the ``verify`` mode: ``"off"`` is a no-op, ``"warn"``
    records the diagnostics on ``plan.diagnostics`` and emits a
    ``UserWarning`` per error-severity finding, ``"strict"`` raises
    ``repro.analysis.VerificationError`` on any error-severity
    diagnostic.  A pure post-check: the plan bytes are never changed,
    which is why the compile service runs this on cache *hits* too
    instead of keying the cache on ``verify``."""
    if mode == "off":
        return plan
    # Imported lazily: analysis depends on core, not the reverse.
    from repro.analysis import (VerificationError, errors_of,
                                verify_execution_plan)
    plan.diagnostics = verify_execution_plan(plan)
    errors = errors_of(plan.diagnostics)
    if errors and mode == "strict":
        raise VerificationError(plan.graph.name, plan.diagnostics)
    for d in errors:
        warnings.warn(f"{site}({plan.graph.name}): {d.render()}",
                      stacklevel=3)
    return plan


def compile_graph(graph: Graph, hw: FPGAConfig = KCU1500,
                  options: CompileOptions | None = None,
                  *, policy: dict[int, str] | None = None,
                  guard=None, warm_start=None,
                  **legacy) -> ExecutionPlan:
    """Compile a CNN graph into an :class:`ExecutionPlan`.

    All search/scheduling knobs arrive as one
    :class:`repro.core.options.CompileOptions` -- that class's docstring
    is the single knob reference (objective, exhaustive_limit, workers,
    batch_size, engine, backend, max_retries, task_deadline_s,
    resume_dir, prune, count_pruned, verify).  The legacy loose-keyword
    spelling (``compile_graph(g, hw, workers=8)``) still works through
    the deprecation shim and emits
    :class:`~repro.core.options.LegacyKnobWarning`.

    Three arguments stay outside the options value because they are not
    reusable configuration: ``policy`` (gid -> "row"/"frame") skips the
    optimizer and compiles the given policy verbatim -- this is how the
    all-row baseline and ablation plans are built (feasibility is still
    computed honestly for the resulting Candidate); ``guard`` is a live
    :class:`~repro.runtime.fault_tolerance.PreemptionGuard` that makes
    SIGTERM drain the search cleanly (``SearchPreempted``) instead of
    dying mid-task; ``warm_start`` is a cut tuple (typically from the
    compile service's plan cache) forwarded to
    :func:`repro.core.cutpoint.search`, which prices it through the
    oracle and seeds the branch-and-bound incumbent -- exhaustive-path
    results stay bit-identical to a cold compile.
    """
    opts = resolve_options(options, legacy, site="compile_graph")
    graph.validate()
    gg = group_nodes(graph)
    result: SearchResult | None = None
    if policy is None:
        result = search(gg, hw, opts, guard=guard, warm_start=warm_start)
        cand = result.best
        alloc = cand.alloc
    else:
        alloc = allocate(gg, policy)
    sram = sram_report(gg, alloc, hw)
    dram = dram_report(gg, alloc)
    latency = latency_report(gg, alloc, hw)
    if policy is not None:
        feasible = (sram.sram_total <= hw.sram_budget
                    and frame_feasible(gg, policy, alloc))
        cand = Candidate(
            cuts=(), policy=policy, alloc=alloc,
            latency_cycles=latency.cycles,
            dram_total=dram.total, dram_fm=dram.fm_bytes,
            sram_total=sram.sram_total, bram18k=sram.bram18k,
            feasible=feasible)
    plan = ExecutionPlan(
        graph=graph, grouped=gg, hw=hw, candidate=cand, alloc=alloc,
        sram=sram, dram=dram, latency=latency,
        instructions=generate_instructions(gg, alloc),
        search=result)
    return apply_verification(plan, opts.verify)


def all_row_policy(gg: GroupedGraph) -> dict[int, str]:
    """Every group streams row-by-row: the paper's off-chip baseline
    (eq. 9) that the optimizer's DRAM reduction is measured against."""
    return {g.gid: "row" for g in gg.groups}


def all_frame_policy(gg: GroupedGraph) -> dict[int, str]:
    """Every group keeps whole feature maps on-chip: the minimum-traffic /
    maximum-SRAM corner, infeasible for large inputs but the anchor of the
    Fig. 16/17 trade-off sweeps."""
    return {g.gid: "frame" for g in gg.groups}
