"""Unified compile-options API: one frozen dataclass for every knob.

Historically ``compile_graph`` / ``cutpoint.search`` /
``ParallelSearchDriver.search`` each carried their own copy of ~13 loose
keyword knobs, and the three signatures drifted.  :class:`CompileOptions`
is now the single source of truth: every entry point accepts
``options=CompileOptions(...)``, the legacy keyword spellings keep
working through a deprecation shim (:func:`resolve_options`, emitting
:class:`LegacyKnobWarning` -- promoted to an error in tier-1 CI so no
internal caller regresses), and the knob documentation lives in exactly
one place -- the field table below.

The class also draws the line the compile *service* (``repro.service``)
keys its persistent plan cache on: **plan-affecting** fields change what
plan a compile can produce and therefore feed the cache hash
(:meth:`CompileOptions.plan_key`), while **scheduling-only** fields
change wall clock, resilience, or post-checks but never the plan bytes
(:meth:`CompileOptions.schedule`) -- the bit-identity contract proven by
tests/test_search_pool.py, test_score_batch.py, test_alloc_scan.py and
test_branch_bound.py is what makes that split sound.  The same
``plan_key()`` keys the ``resume_dir`` task journals, so journals
written under different plan-affecting option sets can never collide
(they used to: the PR 6 journal key predated ``prune``/``count_pruned``).

Field reference (the one knob table; README mirrors it)
-------------------------------------------------------

Plan-affecting (feed ``plan_key()`` and the service cache hash):

``objective``
    What the optimizer minimizes; feasibility always dominates.
    ``"latency"`` -> (infeasible, latency_cycles, sram_total),
    ``"sram"`` -> (infeasible, sram_total, latency_cycles),
    ``"dram"`` -> (infeasible, dram_total, latency_cycles).
``exhaustive_limit``
    Cut-product spaces up to this size are enumerated exhaustively
    (guaranteed optimum); beyond it coordinate descent with
    deterministic restarts runs instead.  Changing the limit can move a
    graph across that boundary and change the argmin, so it is
    plan-affecting.
``backend``
    ``CutpointEngine`` scoring backend: ``"numpy"`` (default,
    oracle-exact) or ``"pallas"`` (staged float32 on-device batch
    reduction, kernels/score_batch.py -- NOT oracle-exact, hence
    plan-affecting).
``prune``
    ``True`` (default) runs exhaustive enumeration as exact
    branch-and-bound; the argmin and metrics are bit-identical to the
    unpruned search, but ``SearchResult.pruned`` and (under
    ``count_pruned=False``) the scored count depend on it, so compiles
    under different ``prune`` settings must not share journals or cache
    records.
``count_pruned``
    ``True`` (default) counts pruned candidates into
    ``SearchResult.evaluated`` (== the full enumeration count,
    deterministic); ``False`` reports only actually-scored candidates,
    which legitimately varies with scheduling.

Scheduling-only (wall clock / resilience / post-checks; excluded from
``plan_key()`` because results are bit-identical across them):

``workers``
    ``1`` (default) searches serially in-process; ``N > 1`` farms
    disjoint sub-spaces over a process pool
    (``core/search_pool.py``); ``None`` uses ``os.cpu_count()``.
``batch_size``
    Cut tuples priced per ``CutpointEngine.score_batch`` call
    (``1`` falls back to the per-tuple loop).  An ``@N`` suffix on
    ``engine`` overrides it.
``engine``
    How candidate metrics are *executed* (never *what* they are --
    every engine value is bit-identical, which is exactly why the knob
    is scheduling-only).  Grammar: ``name[:variant][@batch]``:

    * ``"journal"`` (default) -- checkpointed Python allocator replay
      per candidate (``CutpointEngine._replay``).
    * ``"device"`` -- tensorized allocator scan over the whole batch
      (``kernels/alloc_scan.py``); variants select the scan
      implementation: ``"device"`` == ``"device:reference"`` (numpy),
      ``"device:scan"`` (``jax.lax.scan``), ``"device:pallas"``.
    * ``"pipeline"`` -- the fully fused on-device search pipeline
      (``kernels/search_pipeline.py``): in-kernel candidate
      enumeration + alloc-scan replay + cost reductions + hierarchical
      argmin; the host receives only each sub-space's winner.
      Variants: ``"pipeline"`` (auto: lax when jax is available, else
      the numpy reference), ``"pipeline:reference"``,
      ``"pipeline:lax"``, ``"pipeline:pallas"``.

    ``@N`` appended to any spelling overrides ``batch_size`` for that
    engine (``"pipeline@4096"``).  The float32 Pallas *scoring* kernel
    is NOT an engine value: it changes plan bytes, so it stays on the
    plan-affecting ``backend`` field.
``max_retries``
    Re-dispatch budget per parallel task for *transient* failures (a
    dead worker process, an injected ChaosError, a straggler
    duplicate).  Deterministic errors always propagate.
``task_deadline_s``
    Per-task wall-clock deadline enabling speculative straggler
    re-dispatch (``None`` disables).
``resume_dir``
    Directory for the task-granular completion journal
    (``checkpoint/checkpoint.py::TaskJournal``): completed tasks are
    committed atomically and skipped on re-run, so a killed or
    preempted compile resumes byte-identically.  The journal's search
    key derives from ``plan_key()`` + the partition, never from
    scheduling knobs.
``verify``
    Static plan verifier (``repro.analysis``) post-pass: ``"off"``
    (default), ``"warn"`` (diagnostics recorded on
    ``plan.diagnostics`` + UserWarning per error), ``"strict"``
    (raises ``VerificationError``).  A pure check -- the plan bytes
    are unchanged -- so the service re-runs it on cache hits instead
    of keying the cache on it.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from dataclasses import dataclass

# Cut-product spaces up to this size are enumerated exhaustively; the
# yolov2 detector's full 7.96M-tuple space fits (paper-scale exactness).
EXHAUSTIVE_LIMIT = 8_000_000

# Cut tuples scored per ``CutpointEngine.score_batch`` call in the search
# loops.  Large enough to amortize the numpy dispatch overhead of the 2-D
# reductions across the batch, small enough that the B x G mask/IO
# matrices stay cache-resident.
DEFAULT_BATCH_SIZE = 1024

_OBJECTIVES = ("latency", "sram", "dram")
_BACKENDS = ("numpy", "pallas")
_VERIFY_MODES = ("off", "warn", "strict")

# engine= grammar: name[:variant][@batch].  Variant "" means the engine's
# default implementation; every (name, variant) pair below is bit-identical
# to every other, which is what keeps ``engine`` scheduling-only.
_ENGINE_VARIANTS = {
    "journal": ("",),
    "device": ("", "reference", "scan", "pallas"),
    "pipeline": ("", "reference", "lax", "pallas"),
}

# The plan-affecting / scheduling-only split (see module docstring).
PLAN_FIELDS = ("objective", "exhaustive_limit", "backend", "prune",
               "count_pruned")
SCHEDULE_FIELDS = ("workers", "batch_size", "engine", "max_retries",
                   "task_deadline_s", "resume_dir", "verify")


class LegacyKnobWarning(DeprecationWarning):
    """A compile entry point was called with loose legacy keyword knobs
    (``workers=``, ``batch_size=``, ``replay=``, ...) instead of
    ``options=CompileOptions(...)``.  The shim maps them onto the
    dataclass so behaviour is unchanged; tier-1 CI promotes this warning
    to an error so no internal caller regresses to the old spelling."""


@dataclass(frozen=True)
class EngineSpec:
    """A parsed ``engine=`` value (see the module docstring's grammar).

    ``variant`` is the resolved implementation name, never ``""``:
    ``resolve_engine`` substitutes each engine's default.  ``batch_size``
    is the effective batch (an ``@N`` suffix wins over the caller's
    default)."""
    name: str                  # "journal" / "device" / "pipeline"
    variant: str               # resolved implementation, e.g. "reference"
    batch_size: int | None     # from "@N", else the caller's default

    def spelling(self) -> str:
        """The canonical string this spec round-trips to."""
        s = f"{self.name}:{self.variant}" if self.name != "journal" \
            else self.name
        if self.batch_size is not None:
            s += f"@{self.batch_size}"
        return s


def _default_variant(name: str) -> str:
    if name == "device":
        return "reference"
    if name == "pipeline":
        # lax is the production default when jax is importable; the numpy
        # reference otherwise.  Both are bit-identical, so auto-selection
        # cannot change results -- only wall clock.
        try:
            import jax                                   # noqa: F401
            return "lax"
        except Exception:                    # pragma: no cover - jax baked
            return "reference"
    return ""


def resolve_engine(engine: str,
                   default_batch: int | None = None) -> EngineSpec:
    """Parse and validate an ``engine=`` string into an :class:`EngineSpec`.

    Raises ``ValueError`` on an unknown name, an unknown variant for the
    name, or a malformed ``@batch`` suffix.  ``default_batch`` fills
    ``batch_size`` when no ``@N`` suffix is present.
    """
    if not isinstance(engine, str):
        raise ValueError(f"engine={engine!r}: expected a string "
                         f"'name[:variant][@batch]'")
    spec, batch = engine, default_batch
    if "@" in spec:
        spec, _, bs = spec.partition("@")
        if not bs.isdigit() or int(bs) < 1:
            raise ValueError(f"engine={engine!r}: '@{bs}' batch suffix "
                             f"must be a positive integer")
        batch = int(bs)
    name, _, variant = spec.partition(":")
    variants = _ENGINE_VARIANTS.get(name)
    if variants is None:
        raise ValueError(f"engine={engine!r}: expected one of "
                         f"{tuple(sorted(_ENGINE_VARIANTS))} "
                         f"(grammar: name[:variant][@batch])")
    if variant not in variants:
        raise ValueError(f"engine={engine!r}: unknown variant "
                         f"{variant!r} for {name!r}; expected one of "
                         f"{tuple(v for v in variants if v)}")
    if not variant:
        variant = _default_variant(name)
    return EngineSpec(name=name, variant=variant, batch_size=batch)


def degrade_engine(engine: str) -> str:
    """The safe fallback spelling for ``engine``: the journal replay,
    preserving any explicit ``@batch`` suffix.

    This is the single degrade target the parallel runtime routes
    through -- a failing device or pipeline task, and every speculative
    straggler duplicate, re-runs under the returned engine (bit-identical
    by the replay contract, so degradation only costs wall clock)."""
    spec = resolve_engine(engine)
    if spec.batch_size is not None:
        return f"journal@{spec.batch_size}"
    return "journal"


try:
    from typing import Protocol, runtime_checkable
except ImportError:                          # pragma: no cover - py>=3.10
    Protocol = object

    def runtime_checkable(cls):
        return cls


@runtime_checkable
class ReplayEngine(Protocol):
    """What the search runtime requires of a candidate-scoring engine.

    ``CutpointEngine`` is the one production implementation;
    ``ParallelSearchDriver``'s workers, the serial ``search`` loop and
    the compile service all resolve their ``CompileOptions.engine``
    string into a concrete implementation through this surface (see
    ``CutpointEngine.run_subspace`` for the dispatch).  Every
    implementation must be bit-identical on ``run_subspace``'s winner --
    the contract that keeps ``engine`` scheduling-only."""

    evaluations: int

    def score_batch(self, cuts_batch, memoize: bool = True,
                    skip=None) -> list: ...

    def run_subspace(self, prefix, suffix_dims, objective: str,
                     batch_size: int, incumbent_key=None,
                     prune: bool = True) -> tuple: ...


@dataclass(frozen=True)
class CompileOptions:
    """Every compile/search knob, in one frozen value object.

    See the module docstring for the per-field reference (the single
    source of truth the README table mirrors).  Instances are immutable
    and hashable; derive variants with :meth:`replace`.
    """

    objective: str = "latency"
    exhaustive_limit: int = EXHAUSTIVE_LIMIT
    workers: int | None = 1
    batch_size: int = DEFAULT_BATCH_SIZE
    engine: str = "journal"
    backend: str = "numpy"
    max_retries: int = 2
    task_deadline_s: float | None = None
    resume_dir: str | os.PathLike | None = None
    prune: bool = True
    count_pruned: bool = True
    verify: str = "off"

    def __post_init__(self) -> None:
        if self.objective not in _OBJECTIVES:
            raise ValueError(f"objective={self.objective!r}: expected one "
                             f"of {_OBJECTIVES}")
        resolve_engine(self.engine)       # validates the grammar; raises
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend={self.backend!r}: expected one of "
                             f"{_BACKENDS}")
        if self.verify not in _VERIFY_MODES:
            raise ValueError(f"verify={self.verify!r}: expected one of "
                             f"{_VERIFY_MODES}")
        if self.exhaustive_limit < 0:
            raise ValueError(f"exhaustive_limit={self.exhaustive_limit}: "
                             f"must be >= 0")
        if self.batch_size < 1:
            raise ValueError(f"batch_size={self.batch_size}: must be >= 1")
        if self.workers is not None and self.workers < 1:
            raise ValueError(f"workers={self.workers}: must be >= 1 or "
                             f"None (= all cores)")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries}: must be "
                             f">= 0")
        if self.task_deadline_s is not None and self.task_deadline_s <= 0:
            raise ValueError(f"task_deadline_s={self.task_deadline_s}: "
                             f"must be > 0 or None")

    # ---------------------------------------------------------- derivation
    def replace(self, **changes) -> "CompileOptions":
        """A copy with ``changes`` applied (re-validated)."""
        return dataclasses.replace(self, **changes)

    def engine_spec(self) -> EngineSpec:
        """The parsed :class:`EngineSpec` of this option set; its
        ``batch_size`` is the effective one (an ``@N`` engine suffix
        overrides the ``batch_size`` field)."""
        return resolve_engine(self.engine, self.batch_size)

    def plan_key(self) -> tuple:
        """Canonical tuple of the plan-affecting fields.

        This is what the service's persistent plan cache and the
        ``resume_dir`` task journals hash: two option sets with equal
        ``plan_key()`` are guaranteed (by the repo's bit-identity
        contract) to compile any request to byte-identical plans, and
        two with different ``plan_key()`` must never share cache records
        or journals.
        """
        return tuple((name, getattr(self, name)) for name in PLAN_FIELDS)

    def schedule(self) -> tuple:
        """Canonical tuple of the scheduling-only fields (wall clock /
        resilience / post-checks; never part of any cache or journal
        key).  ``resume_dir`` is normalized to a string so the tuple
        stays comparable and msgpack-able."""
        out = []
        for name in SCHEDULE_FIELDS:
            v = getattr(self, name)
            if name == "resume_dir" and v is not None:
                v = os.fspath(v)
            out.append((name, v))
        return tuple(out)


_FIELD_NAMES = tuple(f.name for f in dataclasses.fields(CompileOptions))

# Retired keyword spellings the legacy shim still understands.  ``replay``
# predates the unified ``engine`` knob; its two values map 1:1 onto engine
# spellings ("journal" -> "journal", "device" -> "device").
_RETIRED_KNOBS = ("replay",)


def resolve_options(options: CompileOptions | None,
                    legacy: dict | None,
                    site: str = "compile",
                    stacklevel: int = 3) -> CompileOptions:
    """Resolve an entry point's ``(options=, **legacy)`` pair.

    * both empty -> default :class:`CompileOptions`;
    * ``options`` given -> returned as-is (legacy knobs must be absent);
    * legacy knobs given -> mapped onto a fresh ``CompileOptions`` with a
      :class:`LegacyKnobWarning` (promoted to an error in tier-1 CI).
      The retired ``replay=`` spelling is translated onto ``engine=``
      (``"journal"``/``"device"``, unchanged meaning).

    Unknown legacy names raise ``TypeError`` exactly as a wrong keyword
    argument would have before the redesign.
    """
    legacy = dict(legacy) if legacy else {}
    unknown = sorted(set(legacy) - set(_FIELD_NAMES) - set(_RETIRED_KNOBS))
    if unknown:
        raise TypeError(f"{site}() got unexpected keyword argument(s) "
                        f"{', '.join(map(repr, unknown))}")
    if "replay" in legacy:
        if "engine" in legacy:
            raise TypeError(f"{site}(): pass engine=..., not both the "
                            f"retired replay= spelling and engine=")
        legacy["engine"] = legacy.pop("replay")
    if options is not None:
        if not isinstance(options, CompileOptions):
            raise TypeError(f"{site}(): options must be a CompileOptions, "
                            f"got {type(options).__name__}")
        if legacy:
            raise TypeError(
                f"{site}(): pass either options=CompileOptions(...) or "
                f"legacy keyword knobs, not both "
                f"(got {sorted(legacy)})")
        return options
    if legacy:
        warnings.warn(
            f"{site}({', '.join(sorted(legacy))}=...): loose keyword "
            f"knobs are deprecated; pass "
            f"options=CompileOptions({', '.join(sorted(legacy))}=...) "
            f"instead (see repro.core.options)",
            LegacyKnobWarning, stacklevel=stacklevel)
        return CompileOptions(**legacy)
    return CompileOptions()
