"""CNN parser & analyzer: re-organize nodes into fused instruction groups.

Mirrors Fig. 5: Convolution, Activation (implicit in the conv node),
Normalization (folded), Pooling, Element-wise (shortcut), Scale and
Up-sampling nodes are fused into a single group when they form a simple
producer chain -- exactly the fusions the back-end accelerator supports
(output of the MAC array forwarded through the post-processing chain without
a memory round-trip).  Concat/route stay standalone (feature-merging is a
redirect, Fig. 5 discussion).

A :class:`Group` is the unit everything downstream operates on: the
allocator assigns each group's output a buffer (or a DRAM round-trip), the
cut-point optimizer assigns each group a reuse mode via its residual
*block* (cutpoint.split_blocks aggregates groups back into blocks), the
cost models charge traffic/latency per group, and the ISA emits exactly
one instruction per group.  Group ids are dense and topological --
``groups[i].gid == i`` -- and every derived quantity (sizes, MACs, fused
add, dual output) is a property over the member nodes, so a Group never
caches state that could go stale under graph edits.

:class:`GroupedGraph` additionally carries three topology caches filled
once by :func:`group_nodes` -- per-group inputs, consumers, and the
shortcut-source map -- because the allocator and the cost models query
group topology inside the O(N^k) cut-point search where a dict lookup
matters.  The caches are private to this module; callers use the
``group_inputs`` / ``group_consumers`` / ``shortcut_source_group``
accessors.  The input image maps to pseudo-group ``-1`` (it owns no
buffer and no instruction).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ir import Graph, LayerNode

# Node kinds a compute group may absorb after the conv.
FUSABLE = ("maxpool", "avgpool", "globalpool", "add", "upsample", "scale")


@dataclass
class Group:
    gid: int
    nodes: list[LayerNode] = field(default_factory=list)
    # Fig. 13(d): a dwconv group may emit BOTH its feature map (main output)
    # and an on-the-fly global-pooled copy for the SE side path.
    dual_output: bool = False

    # -------------------------------------------------------------- derived
    @property
    def head(self) -> LayerNode:
        return self.nodes[0]

    @property
    def tail(self) -> LayerNode:
        """Main-output node (excludes the side pooled copy)."""
        if self.dual_output:
            return self.nodes[-2]
        return self.nodes[-1]

    @property
    def side_tail(self) -> LayerNode | None:
        return self.nodes[-1] if self.dual_output else None

    @property
    def kind(self) -> str:
        return self.head.kind

    @property
    def is_compute(self) -> bool:
        return self.head.is_compute

    @property
    def macs(self) -> int:
        return sum(n.macs for n in self.nodes)

    @property
    def weight_size(self) -> int:
        return sum(n.weight_size for n in self.nodes)

    @property
    def in_size(self) -> int:
        return self.head.in_size

    @property
    def out_size(self) -> int:
        return self.tail.out_size

    @property
    def fused_add(self) -> LayerNode | None:
        for n in self.nodes:
            if n.kind == "add":
                return n
        return None

    @property
    def has_dw(self) -> bool:
        return any(n.kind == "dwconv" for n in self.nodes)

    def __repr__(self) -> str:
        ks = "+".join(n.kind for n in self.nodes)
        return f"G{self.gid}[{ks} n{self.head.idx}-{self.tail.idx}]"


@dataclass
class GroupedGraph:
    graph: Graph
    groups: list[Group]
    # node idx -> group id
    node_group: dict[int, int]
    # Topology caches, filled once by group_nodes (allocation/timing/DRAM
    # models query these inside the O(N^k) cut-point search).
    _inputs: dict[int, list[int]] = field(default_factory=dict)
    _consumers: dict[int, list[int]] = field(default_factory=dict)
    _shortcut_src: dict[int, int | None] = field(default_factory=dict)

    def producer_group(self, node_idx: int) -> Group:
        return self.groups[self.node_group[node_idx]]

    def group_inputs(self, g: Group) -> list[int]:
        """Group ids feeding this group (main path first, then shortcut)."""
        return self._inputs[g.gid]

    def group_consumers(self, g: Group) -> list[int]:
        return self._consumers[g.gid]

    def shortcut_source_group(self, g: Group) -> int | None:
        """Group id producing the shortcut operand of g's fused add."""
        return self._shortcut_src[g.gid]

    def _build_caches(self) -> None:
        for g in self.groups:
            member = {n.idx for n in g.nodes}
            seen: list[int] = []
            for n in g.nodes:
                for i in n.inputs:
                    if i not in member:
                        gid = self.node_group[i]
                        if gid not in seen:
                            seen.append(gid)
            self._inputs[g.gid] = seen
            self._consumers[g.gid] = []
            src: int | None = None
            add = g.fused_add
            if add is not None:
                for i in add.inputs[1:]:
                    if i not in member:
                        src = self.node_group[i]
                        break
            self._shortcut_src[g.gid] = src
        for g in self.groups:
            for src in self._inputs[g.gid]:
                if src >= 0 and g.gid not in self._consumers[src]:
                    self._consumers[src].append(g.gid)


def group_nodes(graph: Graph) -> GroupedGraph:
    """Greedy chain fusion (the paper's analyzer, Fig. 5a).

    Each compute node (conv/dwconv/fc) opens a group and absorbs the
    linear chain of FUSABLE post-processing nodes that immediately follows
    it -- a successor fuses only if it is the next node in topological
    order and consumes the current tail as its main input, i.e. the chain
    the accelerator can stream through without a memory round-trip.  A
    node with multiple consumers ends the chain, with one exception
    (Fig. 13d): a depthwise conv that feeds both the main path and an SE
    global-pool keeps the pooled copy in-group (``dual_output``), because
    the hardware produces it on the fly.  Non-compute nodes that nothing
    absorbed (concat, route, standalone adds/pools) become single-node
    groups.
    """
    groups: list[Group] = []
    node_group: dict[int, int] = {}
    consumed: set[int] = set()

    consumer_map: dict[int, list[LayerNode]] = {n.idx: [] for n in graph}
    for n in graph:
        for i in n.inputs:
            consumer_map[i].append(n)

    for n in graph:
        if n.idx in consumed:
            continue
        if n.kind == "input":
            continue                      # the input image is not a group
        grp = Group(gid=len(groups), nodes=[n])
        consumed.add(n.idx)
        node_group[n.idx] = grp.gid
        if n.is_compute:
            # Absorb a linear chain of post-processing nodes.
            tail = n
            while True:
                nxt = None
                for c in consumer_map[tail.idx]:
                    if (c.kind in FUSABLE and c.idx == tail.idx + 1
                            and c.inputs[0] == tail.idx):
                        nxt = c
                        break
                # Special case (Fig. 13d): a dwconv may also feed the SE
                # global-pool concurrently; the pooled copy is produced on
                # the fly, so globalpool fuses even though the dwconv output
                # has another consumer.
                if nxt is None:
                    break
                multi = len(consumer_map[tail.idx]) > 1
                if multi and nxt.kind != "globalpool":
                    break
                grp.nodes.append(nxt)
                consumed.add(nxt.idx)
                node_group[nxt.idx] = grp.gid
                tail = nxt
                if nxt.kind == "globalpool" and multi:
                    grp.dual_output = True
                    break
        groups.append(grp)

    # Map the input node to a pseudo-group id of -1 handled by callers; to
    # keep lookups total, alias it to the first group.
    for n in graph:
        if n.kind == "input":
            node_group[n.idx] = -1
    gg = GroupedGraph(graph=graph, groups=groups, node_group=node_group)
    gg._build_caches()
    return gg
