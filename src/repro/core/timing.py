"""Cycle-accurate-style latency model (paper §IV-B, Fig. 3).

The paper validates a cycle-accurate simulator against RTL; we model the
same pipeline structure analytically per group:

row-based weight reuse (Fig. 3b):
    the layer's full weights are pre-loaded on-chip (constraint (10)), then
    rows stream: compute overlaps feature-map DRAM traffic.
      latency = weight_load + max(compute_cycles, fm_dram_cycles)

frame-based weight reuse (Fig. 3a):
    feature maps resident on-chip; weight-block loads are hidden by the
    computation of the previous sub-frame ("the latency of reading the
    weight blocks ... can be hidden by the computation"):
      latency = max(compute_cycles, weight_dram_cycles + boundary_io_cycles)

Post-processing nodes fused into the group (pool / eltwise / upsample /
scale) ride the output chain and add no cycles (§III-B-2: "the element-wise
layer does not incur an additional timing overhead").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.allocator import Allocation, _is_side
from repro.core.grouping import Group, GroupedGraph
from repro.core.hw import FPGAConfig


@dataclass
class LatencyReport:
    cycles: float
    per_group: dict[int, float] = field(default_factory=dict)

    def seconds(self, hw: FPGAConfig) -> float:
        return self.cycles / hw.freq

    def ms(self, hw: FPGAConfig) -> float:
        return 1e3 * self.seconds(hw)


def compute_cycles(g: Group, hw: FPGAConfig) -> float:
    """MAC-array occupancy with lane-granularity effects.

    Normal conv / fc: the shared array performs a Ti x To MAC step per
    cycle, so cycles = out_h*out_w*k^2 * ceil(Cin/Ti) * ceil(Cout/To); layers
    with few channels waste lanes (this is what drives the paper's 19.4%
    MAC efficiency on EfficientNet vs ~71% on ResNet152).
    Depthwise / SE-scale: single-mult path (Fig. 7b, 8a): one <=32-MAC
    kernel per array per cycle => To outputs/cycle."""
    import math
    cyc = 0.0
    for n in g.nodes:
        if n.macs == 0:
            continue
        if n.kind in ("dwconv", "scale"):
            kernel_passes = max(1, math.ceil(n.k * n.k / 32))
            cyc += (n.out_h * n.out_w * math.ceil(n.out_ch / hw.to)
                    * kernel_passes)
        else:
            cyc += (n.out_h * n.out_w * n.k * n.k
                    * math.ceil((n.in_ch / n.groups) / hw.ti)
                    * math.ceil(n.out_ch / hw.to))
    return cyc


def row_latency(gg: GroupedGraph, g: Group, hw: FPGAConfig,
                comp: float) -> float:
    """Row-mode (Fig. 3b) group latency.  Depends only on the group and the
    graph topology, never on the allocation, so it can be tabulated once."""
    if g.kind in ("concat", "route"):
        return hw.group_overhead_cycles              # redirect: free
    bpc = hw.dram_bytes_per_cycle
    extra = 0
    if g.head.kind == "add":
        # Standalone eltwise: every extra operand streamed once.  The
        # shortcut source is among group_inputs[1:], so the fused-shortcut
        # term below would double-count it (dram.row_fm_bytes has the
        # same split; the simulator byte counters arbitrate).
        extra = sum(gg.groups[i].out_size      # det: int-exact byte counts
                    for i in gg.group_inputs(g)[1:] if i >= 0)
    else:
        sc = gg.shortcut_source_group(g)
        if sc is not None:            # fused add: one shortcut read
            extra = gg.groups[sc].out_size
    fm_bytes = g.in_size + g.out_size + extra
    weight_load = g.weight_size / bpc
    return weight_load + max(comp, fm_bytes / bpc) + hw.group_overhead_cycles


def group_latency(gg: GroupedGraph, g: Group, alloc: Allocation,
                  hw: FPGAConfig) -> float:
    policy = alloc.policy
    if _is_side(gg, g):
        # SE side path: a handful of MACs + pooling, fully hidden behind the
        # main path in hardware; charge only its compute.
        return compute_cycles(g, hw)

    bpc = hw.dram_bytes_per_cycle
    mode = policy[g.gid]
    comp = compute_cycles(g, hw)

    if mode == "row":
        return row_latency(gg, g, hw, comp)

    # frame mode
    io_bytes = alloc.boundary_reads.get(g.gid, 0)
    if g.gid in alloc.boundary_writes or g.gid in alloc.spilled:
        io_bytes += g.out_size
    mem = (g.weight_size + io_bytes) / bpc
    return max(comp, mem) + hw.group_overhead_cycles


def latency_report(gg: GroupedGraph, alloc: Allocation,
                   hw: FPGAConfig) -> LatencyReport:
    per_group = {g.gid: group_latency(gg, g, alloc, hw) for g in gg.groups}
    # det: float reduction fixed left-to-right in gid order (dict insertion
    # order); latency_cycles_fast reproduces this association exactly
    return LatencyReport(cycles=sum(per_group.values()), per_group=per_group)


# ---------------------------------------------------- vectorized evaluation
@dataclass
class LatencyTables:
    """Static per-group quantities for vectorized latency evaluation.

    Every entry is computed with exactly the scalar code paths above
    (``compute_cycles`` / ``row_latency``), so the vectorized total is
    bit-identical to ``latency_report`` for any allocation."""
    comp: np.ndarray          # float64: compute cycles per group
    row: np.ndarray           # float64: full row-mode latency per group
    weight: np.ndarray        # float64: weight bytes per group
    side: np.ndarray          # bool: SE side-path groups


def latency_tables(gg: GroupedGraph, hw: FPGAConfig) -> LatencyTables:
    n = len(gg.groups)
    comp = np.empty(n)
    row = np.empty(n)
    weight = np.empty(n)
    side = np.zeros(n, dtype=bool)
    for g in gg.groups:
        c = compute_cycles(g, hw)
        comp[g.gid] = c
        weight[g.gid] = g.weight_size
        if _is_side(gg, g):
            side[g.gid] = True
            row[g.gid] = c
        else:
            row[g.gid] = row_latency(gg, g, hw, c)
    return LatencyTables(comp=comp, row=row, weight=weight, side=side)


def latency_cycles_fast(t: LatencyTables, frame: np.ndarray,
                        io_bytes: np.ndarray, hw: FPGAConfig) -> float:
    """Total cycles for a policy given per-group frame mask and per-group
    frame-mode boundary-I/O bytes (from the allocation).

    Elementwise IEEE ops match the scalar model bit-for-bit; the final sum
    runs left-to-right in gid order, exactly like ``latency_report``."""
    mem = (t.weight + io_bytes) / hw.dram_bytes_per_cycle
    frame_lat = np.maximum(t.comp, mem) + hw.group_overhead_cycles
    per = np.where(t.side, t.comp, np.where(frame, frame_lat, t.row))
    # det: float reduction fixed left-to-right in gid order, the same
    # association as latency_report's scalar sum (bit-identical)
    return sum(per.tolist())


def latency_cycles_fast_batch(t: LatencyTables, frame: np.ndarray,
                              io_bytes: np.ndarray,
                              hw: FPGAConfig) -> np.ndarray:
    """Total cycles for B candidate policies at once.

    ``frame`` is the B x G frame-mask matrix, ``io_bytes`` the B x G
    frame-mode boundary-I/O matrix; returns the (B,) cycle totals.  Row b
    is bit-identical to ``latency_cycles_fast(t, frame[b], io_bytes[b])``:
    the elementwise ops are the same IEEE operations broadcast over the
    batch axis, and the per-row total is taken with ``np.cumsum`` along
    the group axis -- a strictly sequential left-to-right accumulation,
    i.e. exactly the addition order of the scalar path's Python ``sum``
    (``np.sum``'s pairwise reduction would NOT reproduce it)."""
    mem = (t.weight[None, :] + io_bytes) / hw.dram_bytes_per_cycle
    frame_lat = np.maximum(t.comp[None, :], mem) + hw.group_overhead_cycles
    per = np.where(t.side[None, :], t.comp[None, :],
                   np.where(frame, frame_lat, t.row[None, :]))
    return np.cumsum(per, axis=1)[:, -1]


def gops(gg: GroupedGraph, alloc: Allocation, hw: FPGAConfig) -> float:
    """Achieved GOPS (2 ops per MAC) for DSP/MAC-efficiency reporting."""
    total_ops = 2 * gg.graph.total_macs()
    rep = latency_report(gg, alloc, hw)
    return total_ops / rep.seconds(hw) / 1e9
