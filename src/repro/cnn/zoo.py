"""The paper's CNN workloads as compiler IR graphs.

Layer tables follow the canonical public definitions (Darknet cfg files for
YOLO, torchvision for ResNet/VGG, the EfficientNet paper for B1, the
RetinaNet paper for the FPN + heads).  Node counts land within a few nodes of
the paper's Table III ("number of layers including shortcut, concatenation,
etc.") -- exact parity is impossible without the authors' private parser, and
the compiler results depend only on the shapes, which are exact.
"""
from __future__ import annotations

from repro.core.ir import Graph, make_input


# --------------------------------------------------------------------- VGG16
def vgg16_conv(input_size: int = 224) -> Graph:
    g = Graph("vgg16-conv")
    make_input(g, input_size, input_size)
    cfg = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]
    for ch, reps in cfg:
        for _ in range(reps):
            g.add("conv", out_ch=ch, k=3, act="relu")
        g.add("maxpool", k=2, stride=2)
    return g


# -------------------------------------------------------------------- YOLOv2
def yolov2(input_size: int = 416) -> Graph:
    g = Graph("yolov2")
    make_input(g, input_size, input_size)

    def cbl(ch, k=3):
        return g.add("conv", out_ch=ch, k=k, act="leaky")

    cbl(32); g.add("maxpool", k=2, stride=2)
    cbl(64); g.add("maxpool", k=2, stride=2)
    cbl(128); cbl(64, 1); cbl(128); g.add("maxpool", k=2, stride=2)
    cbl(256); cbl(128, 1); cbl(256); g.add("maxpool", k=2, stride=2)
    cbl(512); cbl(256, 1); cbl(512); cbl(256, 1)
    route16 = cbl(512)                                    # 26x26x512 passthrough
    g.add("maxpool", k=2, stride=2)
    cbl(1024); cbl(512, 1); cbl(1024); cbl(512, 1); cbl(1024)
    cbl(1024); cbl(1024)
    trunk = g.nodes[-1]
    # passthrough: 1x1 conv on route16, space-to-depth, concat with trunk.
    side = g.add("conv", inputs=[route16.idx], out_ch=64, k=1, act="leaky")
    reorg = g.add("route", inputs=[side.idx],
                  out_h=side.out_h // 2, out_w=side.out_w // 2,
                  out_ch=side.out_ch * 4)                 # space-to-depth
    g.add("concat", inputs=[trunk.idx, reorg.idx])
    cbl(1024)
    g.add("conv", out_ch=425, k=1, act="linear")
    return g


# -------------------------------------------------------------------- YOLOv3
def yolov3(input_size: int = 416) -> Graph:
    g = Graph("yolov3")
    make_input(g, input_size, input_size)

    def cbl(ch, k=3, stride=1, inputs=None):
        kw = dict(out_ch=ch, k=k, stride=stride, act="leaky")
        if inputs is not None:
            kw["inputs"] = inputs
        return g.add("conv", **kw)

    def res_block(mid, out):
        entry = g.nodes[-1]
        cbl(mid, 1)
        cbl(out, 3)
        g.add("add", inputs=[len(g.nodes) - 1, entry.idx])

    cbl(32)
    cbl(64, stride=2)
    res_block(32, 64)
    cbl(128, stride=2)
    for _ in range(2):
        res_block(64, 128)
    cbl(256, stride=2)
    for _ in range(8):
        res_block(128, 256)
    route_a = g.nodes[-1]                                  # 52x52x256
    cbl(512, stride=2)
    for _ in range(8):
        res_block(256, 512)
    route_b = g.nodes[-1]                                  # 26x26x512
    cbl(1024, stride=2)
    for _ in range(4):
        res_block(512, 1024)

    def head(base_ch, concat_with=None, route_from=None):
        if route_from is not None:
            g.add("route", inputs=[route_from])
            cbl(base_ch // 2, 1)
            g.add("upsample", stride=2)
            g.add("concat", inputs=[len(g.nodes) - 1, concat_with])
        cbl(base_ch, 1); cbl(base_ch * 2, 3)
        cbl(base_ch, 1); cbl(base_ch * 2, 3)
        branch = cbl(base_ch, 1)
        cbl(base_ch * 2, 3)
        g.add("conv", out_ch=255, k=1, act="linear")
        return branch

    b1 = head(512)
    b2 = head(256, concat_with=route_b.idx, route_from=b1.idx)
    head(128, concat_with=route_a.idx, route_from=b2.idx)
    return g


# -------------------------------------------------------------------- ResNet
def resnet(depth: int = 50, input_size: int = 224) -> Graph:
    blocks = {50: (3, 4, 6, 3), 101: (3, 4, 23, 3), 152: (3, 8, 36, 3)}[depth]
    g = Graph(f"resnet{depth}")
    make_input(g, input_size, input_size)
    g.add("conv", out_ch=64, k=7, stride=2, act="relu")
    g.add("maxpool", k=3, stride=2)

    in_planes = 64
    for stage, reps in enumerate(blocks):
        width = 64 * (2 ** stage)
        for b in range(reps):
            stride = 2 if (stage > 0 and b == 0) else 1
            entry = g.nodes[-1]
            g.add("conv", out_ch=width, k=1, act="relu")
            g.add("conv", out_ch=width, k=3, stride=stride, act="relu")
            main = g.add("conv", out_ch=width * 4, k=1, act="linear")
            if b == 0:      # projection shortcut
                proj = g.add("conv", inputs=[entry.idx], out_ch=width * 4,
                             k=1, stride=stride, act="linear")
                g.add("add", inputs=[main.idx, proj.idx])
            else:
                g.add("add", inputs=[main.idx, entry.idx])
            in_planes = width * 4
    g.add("globalpool")
    g.add("fc", out_ch=1000, in_ch=in_planes, in_h=1, in_w=1,
          out_h=1, out_w=1)
    return g


# ----------------------------------------------------------- EfficientNet-B1
def efficientnet_b1(input_size: int = 256) -> Graph:
    """EfficientNet-B1: B0 stage table scaled depth x1.1, width x1.0."""
    g = Graph("efficientnet-b1")
    make_input(g, input_size, input_size)
    g.add("conv", out_ch=32, k=3, stride=2, act="swish")           # stem

    # (expand, channels, reps, stride, kernel) -- B1 depths.
    stages = [(1, 16, 2, 1, 3), (6, 24, 3, 2, 3), (6, 40, 3, 2, 5),
              (6, 80, 4, 2, 3), (6, 112, 4, 1, 5), (6, 192, 5, 2, 5),
              (6, 320, 2, 1, 3)]
    for expand, ch, reps, stride, k in stages:
        for b in range(reps):
            s = stride if b == 0 else 1
            entry = g.nodes[-1]
            in_ch = entry.out_ch
            mid = in_ch * expand
            if expand != 1:
                g.add("conv", out_ch=mid, k=1, act="swish")        # expand
            g.add("dwconv", k=k, stride=s, act="swish")            # depthwise
            dw = g.nodes[-1]
            # Squeeze-and-Excitation side path (Fig. 13c/d).
            g.add("globalpool", inputs=[dw.idx])
            g.add("fc", out_ch=max(1, in_ch // 4), in_ch=mid,
                  in_h=1, in_w=1, out_h=1, out_w=1, act="swish")
            se = g.add("fc", out_ch=mid, in_ch=max(1, in_ch // 4),
                       in_h=1, in_w=1, out_h=1, out_w=1, act="sigmoid")
            g.add("scale", inputs=[dw.idx, se.idx])                # channel scale
            main = g.add("conv", out_ch=ch, k=1, act="linear")     # project
            if s == 1 and in_ch == ch:
                g.add("add", inputs=[main.idx, entry.idx])
    g.add("conv", out_ch=1280, k=1, act="swish")                   # head
    g.add("globalpool")
    g.add("fc", out_ch=1000, in_ch=1280, in_h=1, in_w=1, out_h=1, out_w=1)
    return g


# ----------------------------------------------------------------- RetinaNet
def retinanet(input_size: int = 512) -> Graph:
    """ResNet50-FPN RetinaNet; heads instantiated per pyramid level."""
    g = resnet(50, input_size)
    g.name = "retinanet"
    # Drop classifier head (globalpool + fc) from the backbone.
    g.nodes = g.nodes[:-2]
    # Locate stage outputs C3, C4, C5 (last add of stages 2, 3, 4).
    adds = [n.idx for n in g.nodes if n.kind == "add"]
    c3, c4, c5 = adds[3 + 4 - 1], adds[3 + 4 + 6 - 1], adds[-1]

    lat5 = g.add("conv", inputs=[c5], out_ch=256, k=1, act="linear")
    lat4 = g.add("conv", inputs=[c4], out_ch=256, k=1, act="linear")
    lat3 = g.add("conv", inputs=[c3], out_ch=256, k=1, act="linear")
    up5 = g.add("upsample", inputs=[lat5.idx], stride=2)
    m4 = g.add("add", inputs=[lat4.idx, up5.idx])
    up4 = g.add("upsample", inputs=[m4.idx], stride=2)
    m3 = g.add("add", inputs=[lat3.idx, up4.idx])
    p3 = g.add("conv", inputs=[m3.idx], out_ch=256, k=3, act="linear")
    p4 = g.add("conv", inputs=[m4.idx], out_ch=256, k=3, act="linear")
    p5 = g.add("conv", inputs=[lat5.idx], out_ch=256, k=3, act="linear")
    p6 = g.add("conv", inputs=[c5], out_ch=256, k=3, stride=2, act="linear")
    p7 = g.add("conv", inputs=[p6.idx], out_ch=256, k=3, stride=2, act="relu")

    for level in (p3, p4, p5, p6, p7):
        for _head in range(2):                       # cls head + box head
            prev = level.idx
            for _ in range(4):
                c = g.add("conv", inputs=[prev], out_ch=256, k=3, act="relu")
                prev = c.idx
            out_ch = 9 * 80 if _head == 0 else 9 * 4
            g.add("conv", inputs=[prev], out_ch=out_ch, k=3, act="linear")
    return g


# -------------------------------------------------------------- MobileNetV3
def mobilenet_v3(input_size: int = 224) -> Graph:
    """MobileNetV3-Large -- the paper's Fig. 1 block (MBConv + SE).
    h-swish is modelled as swish (same dataflow/cost in the compiler)."""
    g = Graph("mobilenet-v3")
    make_input(g, input_size, input_size)
    g.add("conv", out_ch=16, k=3, stride=2, act="swish")           # stem

    # (kernel, expand_ch, out_ch, SE, act, stride)
    table = [
        (3, 16, 16, False, "relu", 1), (3, 64, 24, False, "relu", 2),
        (3, 72, 24, False, "relu", 1), (5, 72, 40, True, "relu", 2),
        (5, 120, 40, True, "relu", 1), (5, 120, 40, True, "relu", 1),
        (3, 240, 80, False, "swish", 2), (3, 200, 80, False, "swish", 1),
        (3, 184, 80, False, "swish", 1), (3, 184, 80, False, "swish", 1),
        (3, 480, 112, True, "swish", 1), (3, 672, 112, True, "swish", 1),
        (5, 672, 160, True, "swish", 2), (5, 960, 160, True, "swish", 1),
        (5, 960, 160, True, "swish", 1),
    ]
    for k, exp, out, se, act, s in table:
        entry = g.nodes[-1]
        in_ch = entry.out_ch
        if exp != in_ch:
            g.add("conv", out_ch=exp, k=1, act=act)                # expand
        g.add("dwconv", k=k, stride=s, act=act)                    # depthwise
        dw = g.nodes[-1]
        if se:
            g.add("globalpool", inputs=[dw.idx])
            g.add("fc", out_ch=max(1, exp // 4), in_ch=exp,
                  in_h=1, in_w=1, out_h=1, out_w=1, act="relu")
            gate = g.add("fc", out_ch=exp, in_ch=max(1, exp // 4),
                         in_h=1, in_w=1, out_h=1, out_w=1, act="sigmoid")
            g.add("scale", inputs=[dw.idx, gate.idx])
        main = g.add("conv", out_ch=out, k=1, act="linear")        # project
        if s == 1 and in_ch == out:
            g.add("add", inputs=[main.idx, entry.idx])
    g.add("conv", out_ch=960, k=1, act="swish")
    g.add("globalpool")
    g.add("fc", out_ch=1280, in_ch=960, in_h=1, in_w=1, out_h=1, out_w=1,
          act="swish")
    g.add("fc", out_ch=1000, in_ch=1280, in_h=1, in_w=1, out_h=1, out_w=1)
    return g


CNN_BUILDERS = {
    "vgg16-conv": vgg16_conv,
    "yolov2": yolov2,
    "yolov3": yolov3,
    "resnet50": lambda input_size=224: resnet(50, input_size),
    "resnet152": lambda input_size=224: resnet(152, input_size),
    "efficientnet-b1": efficientnet_b1,
    "retinanet": retinanet,
    "mobilenet-v3": mobilenet_v3,
}


def build_cnn(name: str, input_size: int | None = None) -> Graph:
    builder = CNN_BUILDERS[name]
    g = builder(input_size) if input_size else builder()
    g.validate()
    return g
