from repro.cnn.zoo import (  # noqa: F401
    build_cnn, vgg16_conv, yolov2, yolov3, resnet, efficientnet_b1,
    retinanet, CNN_BUILDERS)
