"""Direct JAX execution of a compiler IR graph.

This is the paper's "unified software reference code for hardware
verification" (Fig. 4): the same network semantics, executed op-by-op with
no memory schedule.  The functional simulator (core/simulator.py) must match
it bit-for-bit in fp32 -- any buffer-allocation bug shows up as corruption.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ir import Graph, LayerNode


def init_params(graph: Graph, seed: int = 0) -> dict[int, np.ndarray]:
    """Per-node weights, NHWC kernels [k, k, cin/groups, cout]."""
    rng = np.random.default_rng(seed)
    params: dict[int, np.ndarray] = {}
    for n in graph:
        if n.kind == "conv":
            shape = (n.k, n.k, n.in_ch // n.groups, n.out_ch)
        elif n.kind == "dwconv":
            shape = (n.k, n.k, 1, n.in_ch)
        elif n.kind == "fc":
            shape = (n.in_ch, n.out_ch)
        else:
            continue
        params[n.idx] = (rng.standard_normal(shape, dtype=np.float32)
                        * (2.0 / np.sqrt(np.prod(shape[:-1]))))
    return params


def _act(x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "relu":
        return jax.nn.relu(x)
    if act == "leaky":
        return jnp.where(x > 0, x, 0.1 * x)
    if act == "swish":
        return x * jax.nn.sigmoid(x)
    if act == "sigmoid":
        return jax.nn.sigmoid(x)
    return x


def apply_node(n: LayerNode, operands: list[jnp.ndarray],
               params: dict[int, np.ndarray]) -> jnp.ndarray:
    """Execute one IR node.  operands follow n.inputs order; activations are
    NHWC with a leading batch of 1."""
    x = operands[0]
    if n.kind in ("conv", "dwconv"):
        w = jnp.asarray(params[n.idx])
        fgc = n.in_ch if n.kind == "dwconv" else n.groups
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(n.stride, n.stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=fgc)
        return _act(y, n.act)
    if n.kind == "fc":
        w = jnp.asarray(params[n.idx])
        y = x.reshape(x.shape[0], -1) @ w
        return _act(y, n.act).reshape(x.shape[0], 1, 1, n.out_ch)
    if n.kind == "maxpool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, n.k, n.k, 1),
            (1, n.stride, n.stride, 1), "SAME")
    if n.kind == "avgpool":
        s = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, (1, n.k, n.k, 1),
            (1, n.stride, n.stride, 1), "SAME")
        return s / (n.k * n.k)
    if n.kind == "globalpool":
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    if n.kind == "upsample":
        return jnp.repeat(jnp.repeat(x, n.stride, axis=1), n.stride, axis=2)
    if n.kind == "add":
        return operands[0] + operands[1]
    if n.kind == "concat":
        return jnp.concatenate(operands, axis=-1)
    if n.kind == "route":
        if n.out_ch == 4 * n.in_ch:          # space-to-depth (YOLOv2 reorg)
            b, h, w, c = x.shape
            x = x.reshape(b, h // 2, 2, w // 2, 2, c)
            return x.transpose(0, 1, 3, 2, 4, 5).reshape(b, h // 2, w // 2, 4 * c)
        return x                              # identity passthrough
    if n.kind == "scale":
        se = operands[1].reshape(1, 1, 1, -1)  # [1,1,1,C] channel gates
        return x * se
    raise ValueError(f"cannot execute node kind {n.kind}")


def run_graph(graph: Graph, params: dict[int, np.ndarray],
              x: np.ndarray) -> dict[int, jnp.ndarray]:
    """Execute every node; returns all node outputs keyed by idx."""
    outs: dict[int, jnp.ndarray] = {}
    for n in graph:
        if n.kind == "input":
            outs[n.idx] = jnp.asarray(x)
            continue
        operands = [outs[i] for i in n.inputs]
        outs[n.idx] = apply_node(n, operands, params)
    return outs
