"""Persistent, content-addressed plan cache.

One record per :func:`repro.service.canonical.request_key`, stored as

    <root>/plan_<key[:32]>.rec

with the same commit discipline as the compiler's task journals
(``checkpoint/checkpoint.py``): the record body is msgpack compressed
through the shared codec (zstd, or the zlib fallback), wrapped with its
sha256 digest, and written via ``atomic_write_bytes`` (tmp + fsync +
``os.replace``) -- a kill mid-write leaves either the old record or the
new one, never a torn file.  A record that fails its digest or schema
check on read is treated as a *miss* and deleted (unlike the task
journal, which raises: a journal resumes half-finished state, while a
cache entry is always safely recomputable).

Versioning: every record carries :data:`CACHE_SCHEMA_VERSION`; bumping
the version (a codec or canonicalization change) silently invalidates
the whole store record-by-record, no migration pass needed.

Eviction: bounded record count, LRU by file mtime (a served hit touches
its record's mtime).  Eviction runs at ``put`` time, so a read-only
serving process never deletes records under a writer.

Warm-start lookup: every record's wrapper carries a small metadata map
-- graph fingerprint, hw signature, plan-affecting options, winning
cuts -- readable without decompressing the plan body.  :meth:`nearest`
scans those for records of the same net family (equal graph
fingerprint) and returns the cut tuple of the one whose hw signature is
closest (normalized L1 distance over the numeric FPGAConfig fields), so
a miss for a known net on a *new* hardware config can seed the
branch-and-bound incumbent with the plan of the nearest known config.
"""
from __future__ import annotations

import hashlib
import os
from pathlib import Path

import msgpack

from repro.checkpoint.checkpoint import (atomic_write_bytes, get_codec,
                                         get_decompressor)
from repro.service.canonical import CACHE_SCHEMA_VERSION

# Default record-count bound; ~10-100 KB per record, so the default store
# stays well under 100 MB.
DEFAULT_CAPACITY = 1024


class PlanCache:
    def __init__(self, root: str | os.PathLike,
                 capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity={capacity}: must be >= 1")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.capacity = capacity

    # -------------------------------------------------------------- records
    def _path(self, key: str) -> Path:
        return self.root / f"plan_{key[:32]}.rec"

    def put(self, key: str, blob: bytes, meta: dict) -> None:
        """Commit ``blob`` (an encoded plan) under ``key``.

        ``meta`` must be msgpack-able; it is stored uncompressed in the
        wrapper so :meth:`nearest` can scan it cheaply.
        """
        codec, compress = get_codec()
        body = compress(blob)
        payload = msgpack.packb(
            {"v": CACHE_SCHEMA_VERSION, "codec": codec,
             "digest": hashlib.sha256(body).hexdigest(), "meta": meta,
             "body": body}, use_bin_type=True)
        atomic_write_bytes(self._path(key), payload)
        self._evict()

    def _read_wrapper(self, path: Path) -> dict | None:
        """The verified wrapper at ``path``, or None (deleting the file)
        if it is damaged or from another schema version."""
        try:
            wrapper = msgpack.unpackb(path.read_bytes(), raw=False)
            if (wrapper["v"] != CACHE_SCHEMA_VERSION
                    or hashlib.sha256(wrapper["body"]).hexdigest()
                    != wrapper["digest"]):
                raise ValueError("schema or digest mismatch")
            return wrapper
        except FileNotFoundError:
            return None
        except Exception:
            # stale schema or torn/corrupt record: a cache entry is always
            # recomputable, so drop it and report a miss
            path.unlink(missing_ok=True)
            return None

    def get(self, key: str) -> bytes | None:
        """The plan blob for ``key``, or None on miss.  A hit touches the
        record's mtime (the LRU clock)."""
        path = self._path(key)
        wrapper = self._read_wrapper(path)
        if wrapper is None:
            return None
        try:
            os.utime(path)
        except OSError:
            pass                           # racing eviction loses the touch
        return get_decompressor(wrapper["codec"])(wrapper["body"])

    def __contains__(self, key: str) -> bool:
        return self._read_wrapper(self._path(key)) is not None

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("plan_*.rec"))

    def _evict(self) -> None:
        recs = sorted(self.root.glob("plan_*.rec"),
                      key=lambda p: (p.stat().st_mtime, p.name))
        for path in recs[:max(0, len(recs) - self.capacity)]:
            path.unlink(missing_ok=True)

    # ----------------------------------------------------------- warm start
    def nearest(self, graph_fp: str, hw_sig: list,
                require_path: str | None = None) -> tuple | None:
        """Cut tuple of the cached plan closest to ``(graph_fp, hw_sig)``.

        Only records of the *same* net family (equal canonical-graph
        fingerprint) are considered -- cut tuples are meaningless across
        different run structures; ``valid_warm_start`` downstream guards
        the residual risk of a fingerprint-equal graph changing shape
        across schema versions.  ``require_path`` additionally restricts
        donors to records whose stored search path matches (the daemon
        passes ``"exhaustive"`` when seeding a descent-path request, so
        only oracle-exact argmins ever seed descent searches).  Distance
        is the normalized L1 gap over the numeric hw fields (ti, to,
        sram_budget, dram_bw, ...), ties broken by record name for
        determinism.  Returns ``None`` when no family record exists --
        including on an exact-key hit's config, which is fine:
        ``nearest`` is only consulted on misses.
        """
        ref = {name: val for name, val in hw_sig
               if isinstance(val, (int, float))}
        best: tuple | None = None
        for path in sorted(self.root.glob("plan_*.rec")):
            wrapper = self._read_wrapper(path)
            if wrapper is None:
                continue
            meta = wrapper.get("meta") or {}
            if meta.get("graph_fp") != graph_fp or "cuts" not in meta:
                continue
            if (require_path is not None
                    and meta.get("path") != require_path):
                continue
            dist = 0.0
            for name, val in meta.get("hw_sig", []):
                if name in ref and val:
                    dist += abs(ref[name] - val) / max(abs(ref[name]),
                                                       abs(val))
            cand = (dist, path.name, tuple(meta["cuts"]))
            if best is None or cand < best:
                best = cand
        return best[2] if best is not None else None
