"""Compile-as-a-service: a long-lived daemon serving ExecutionPlans from
a persistent, content-addressed plan cache.

Public surface:

* :class:`~repro.service.daemon.CompileService` -- the daemon (bounded
  request queue, coalescing, warm-started misses, per-ticket timing).
* :class:`~repro.service.cache.PlanCache` -- the on-disk store (atomic
  msgpack+zstd records, digest-verified, schema-versioned, LRU-bounded).
* :func:`~repro.service.canonical.request_key` /
  :func:`~repro.service.canonical.graph_fingerprint` -- deterministic
  request hashing (insertion-order- and PYTHONHASHSEED-independent).
* :func:`~repro.service.codec.encode_plan` /
  :func:`~repro.service.codec.decode_plan` -- the ExecutionPlan codec
  behind the byte-identity contract.

See docs/architecture.md ("Compile service") for the design.
"""
from repro.service.cache import PlanCache
from repro.service.canonical import (CACHE_SCHEMA_VERSION, canonical_graph,
                                     graph_fingerprint, hw_signature,
                                     request_key)
from repro.service.codec import PlanCodecError, decode_plan, encode_plan
from repro.service.daemon import (CompileService, ServiceClosed,
                                  ServiceOverloaded, Ticket)

__all__ = [
    "CACHE_SCHEMA_VERSION", "CompileService", "PlanCache",
    "PlanCodecError", "ServiceClosed", "ServiceOverloaded", "Ticket",
    "canonical_graph", "decode_plan", "encode_plan", "graph_fingerprint",
    "hw_signature", "request_key",
]
