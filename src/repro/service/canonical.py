"""Deterministic request canonicalization for the compile service.

The plan cache is content-addressed: a request is ``(graph, hw,
CompileOptions)``, and two requests that can only ever compile to the
same plan must hash equal.  That requires a graph signature that is

* **insertion-order independent** -- the same network built by two code
  paths that append nodes in different (topologically valid) orders must
  canonicalize identically, so node indices cannot appear in the hash
  directly;
* **process independent** -- the hash must survive a fresh interpreter
  with a different ``PYTHONHASHSEED``, so nothing here uses Python's
  ``hash()``; everything goes through sha256 over a msgpack encoding;
* **cosmetics-blind** -- ``LayerNode.name`` and ``Graph.name`` are
  display strings with no bearing on the plan, so they are excluded
  (the weights *shape* signature is fully implied by the structural
  fields: in_ch/out_ch/k/groups/qw).

Canonicalization runs two signature passes over the DAG (a two-direction
Weisfeiler-Leman-style refinement):

1. **forward**: ``fwd[i] = H(fields(i), [fwd[j] for j in inputs(i)])``
   -- input order is preserved, because it is semantic (``add``'s
   ``inputs[1:]`` are the shortcut operands);
2. **backward**: ``bwd[i] = H(fields(i), sorted((bwd[c], position of i
   in c.inputs) for consumers c))`` -- the consumer *set* is unordered,
   so it is sorted by value.

Nodes are then ordered by ``(fwd, bwd, original index)`` and input edges
remapped to canonical positions.  The original index appears only as the
final tie-break: two nodes tie on both signatures only when they are
automorphic twins (structurally interchangeable), in which case either
order encodes an isomorphic -- but not always byte-equal -- structure.
That is the documented best-effort boundary (exact canonical forms for
arbitrary DAGs are graph-isomorphism-hard); none of the zoo networks
contains such twins.
"""
from __future__ import annotations

import dataclasses
import hashlib

import msgpack

from repro.core.hw import FPGAConfig
from repro.core.ir import Graph
from repro.core.options import CompileOptions

# Bumped whenever the canonical encoding, the plan codec, or the cache
# record layout changes shape: records written under a different schema
# version are never served (the cache treats them as evictable misses).
# v2: search records carry the search path ("exhaustive"/"descent") so
# the warm-start donor filter can tell oracle-exact argmins from descent
# results; record metadata carries "path" for the same reason.
CACHE_SCHEMA_VERSION = 2

# Structural LayerNode fields, in hash order.  `idx`, `name` and `inputs`
# are deliberately absent: indices and edges enter through the signature
# recursion, names are cosmetic.
_NODE_FIELDS = ("kind", "in_ch", "out_ch", "in_h", "in_w", "out_h",
                "out_w", "k", "stride", "groups", "act", "fused_pool",
                "qa", "qw", "qs")


def _digest(obj) -> bytes:
    return hashlib.sha256(
        msgpack.packb(obj, use_bin_type=True)).digest()


def _fields(node) -> list:
    return [getattr(node, f) for f in _NODE_FIELDS]


def canonical_graph(graph: Graph) -> list:
    """Insertion-order-independent structural encoding of ``graph``.

    Returns a msgpack-able nested list: one ``[fields..., inputs]`` entry
    per node, in canonical order, with ``inputs`` remapped to canonical
    positions.  Isomorphic graphs built in different node-insertion
    orders encode byte-identically (up to the automorphic-twin boundary
    in the module docstring).
    """
    nodes = graph.nodes
    fwd: list[bytes | None] = [None] * len(nodes)
    for n in nodes:                       # nodes are topologically ordered
        fwd[n.idx] = _digest([_fields(n), [fwd[j] for j in n.inputs]])
    bwd: list[bytes | None] = [None] * len(nodes)
    consumers: list[list] = [[] for _ in nodes]
    for n in nodes:
        for pos, j in enumerate(n.inputs):
            consumers[j].append((n.idx, pos))
    for n in reversed(nodes):
        uses = sorted((bwd[c], pos) for c, pos in consumers[n.idx])
        bwd[n.idx] = _digest([_fields(n), uses])
    order = sorted(range(len(nodes)),
                   key=lambda i: (fwd[i], bwd[i], i))
    position = {old: new for new, old in enumerate(order)}
    return [[*_fields(nodes[i]),
             [position[j] for j in nodes[i].inputs]] for i in order]


def graph_fingerprint(graph: Graph) -> str:
    """sha256 hex of the canonical graph alone (no hw, no options) --
    the "net family" identity the warm-start nearest-plan lookup matches
    on."""
    return hashlib.sha256(
        msgpack.packb([CACHE_SCHEMA_VERSION, canonical_graph(graph)],
                      use_bin_type=True)).hexdigest()


def hw_signature(hw: FPGAConfig) -> list:
    """All FPGAConfig fields, name included (a renamed config with equal
    numbers still keys equal: the name is dropped from the hash but kept
    in the record metadata for reports)."""
    return [[f.name, getattr(hw, f.name)]
            for f in dataclasses.fields(hw) if f.name != "name"]


def plan_key_signature(options: CompileOptions) -> list:
    """``CompileOptions.plan_key()`` as a msgpack-able list.  Scheduling
    fields never appear here -- that is the point of the split."""
    return [[name, value] for name, value in options.plan_key()]


def request_key(graph: Graph, hw: FPGAConfig,
                options: CompileOptions) -> str:
    """The cache key: sha256 hex over (schema version, canonical graph,
    hw signature, plan-affecting options)."""
    payload = msgpack.packb(
        [CACHE_SCHEMA_VERSION, canonical_graph(graph),
         hw_signature(hw), plan_key_signature(options)],
        use_bin_type=True)
    return hashlib.sha256(payload).hexdigest()
