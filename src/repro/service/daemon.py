"""Compile-as-a-service daemon.

:class:`CompileService` is a long-lived, in-process daemon answering
``(graph, hw, CompileOptions)`` requests with :class:`ExecutionPlan`\\ s:

* **request flow** -- every request (hit or miss) goes through one
  bounded queue drained by worker threads; a full queue raises
  :class:`ServiceOverloaded` at submit time (backpressure, never
  unbounded buffering).  Hits decode from the cache in ~ms; misses run
  a full ``compile_graph`` -- whose search-level parallelism, retries,
  journal resume and preemption machinery arrive unchanged through the
  request's own ``CompileOptions`` -- then commit the encoded plan back
  to the cache atomically.
* **cache key** -- :func:`repro.service.canonical.request_key`: sha256
  over (schema version, canonical graph, hw signature,
  ``CompileOptions.plan_key()``).  Scheduling-only fields never reach
  the key, so e.g. a ``workers=16`` request hits a record compiled at
  ``workers=1`` -- the repo's bit-identity contract is what makes that
  sound.  ``verify`` is also excluded: it is a pure post-check, so the
  service re-runs the verifier on every hit at the request's own mode
  instead of fragmenting the cache by it.
* **coalescing** -- concurrent submissions of an identical request
  (same cache key *and* same full options value) share one in-flight
  compile and one resulting plan object; plans are treated as
  read-only.
* **warm start** -- a miss first consults :meth:`PlanCache.nearest`
  for the same net family's plan on the closest hw config and seeds
  the search with it (``warm_start=`` through ``compile_graph``).  On
  the exhaustive path (``prune`` + ``count_pruned`` on) this provably
  cannot change the plan bytes, so every such record is byte-identical
  to a cold compile.  Descent-path requests (which never promised
  hit/cold byte-identity) also warm-start since schema v2, but only
  from donors whose recorded search path is ``"exhaustive"`` -- see
  :meth:`CompileService._warm_start`.
* **failure semantics** -- a failed compile fails *that ticket* (the
  exception re-raises from :meth:`Ticket.result`, for every coalesced
  waiter); the daemon and its queue keep serving.  Nothing is cached on
  failure.  Corrupt or stale-schema cache records are misses, not
  errors.

The daemon is deliberately transport-free: it is the serving core
(queueing, caching, coalescing, warm starts) that an RPC front end
would wrap, and what ``benchmarks/serve_traffic.py`` drives directly.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

from repro.core.compiler import (ExecutionPlan, apply_verification,
                                 compile_graph)
from repro.core.hw import KCU1500, FPGAConfig
from repro.core.ir import Graph
from repro.core.options import CompileOptions
from repro.service.cache import DEFAULT_CAPACITY, PlanCache
from repro.service.canonical import (graph_fingerprint, hw_signature,
                                     request_key)
from repro.service.codec import PlanCodecError, decode_plan, encode_plan


class ServiceOverloaded(RuntimeError):
    """The bounded request queue is full; the caller should back off and
    resubmit.  Raised at submit time -- overload is backpressure, not a
    silently growing buffer."""


class ServiceClosed(RuntimeError):
    """submit() after close()."""


@dataclass
class Ticket:
    """One submitted request; resolves to an ExecutionPlan.

    ``hit`` / ``warm_started`` / ``queue_wait_s`` / ``service_s`` are
    populated when the ticket completes -- they are what the traffic
    benchmark measures.  Coalesced submissions share one ticket.
    """
    key: str
    submitted_at: float
    hit: bool = False
    warm_started: bool = False
    queue_wait_s: float = 0.0
    service_s: float = 0.0
    _done: threading.Event = field(default_factory=threading.Event,
                                   repr=False)
    _plan: ExecutionPlan | None = field(default=None, repr=False)
    _exc: BaseException | None = field(default=None, repr=False)

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> ExecutionPlan:
        if not self._done.wait(timeout):
            raise TimeoutError(f"compile ticket {self.key[:12]} not done "
                               f"within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._plan

    def _resolve(self, plan=None, exc=None) -> None:
        self._plan, self._exc = plan, exc
        self._done.set()


class CompileService:
    """See module docstring.

    Parameters
    ----------
    cache_dir:
        Root of the persistent plan cache (created if absent).  Distinct
        services pointed at the same directory share plans -- records
        are committed atomically and every read is digest-verified.
    options:
        Default :class:`CompileOptions` for requests that don't bring
        their own.
    capacity:
        Plan-cache record bound (LRU eviction beyond it).
    max_pending:
        Bounded queue depth; submissions beyond it raise
        :class:`ServiceOverloaded`.
    threads:
        Worker threads draining the queue.  One (the default) serializes
        compiles -- usually right, since a miss already fans out over
        ``options.workers`` processes; more threads let hits overtake a
        long-running miss.
    """

    def __init__(self, cache_dir, options: CompileOptions | None = None,
                 capacity: int = DEFAULT_CAPACITY, max_pending: int = 64,
                 threads: int = 1):
        self.cache = PlanCache(cache_dir, capacity=capacity)
        self.options = options if options is not None else CompileOptions()
        self._queue: queue.Queue = queue.Queue(maxsize=max_pending)
        self._inflight: dict = {}
        self._lock = threading.Lock()
        self._closed = False
        self.stats = {"requests": 0, "hits": 0, "misses": 0,
                      "coalesced": 0, "warm_starts": 0, "overloads": 0,
                      "failures": 0}
        self._threads = [
            threading.Thread(target=self._serve, daemon=True,
                             name=f"compile-serve-{i}")
            for i in range(max(1, threads))]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        for _ in self._threads:
            self._queue.put(None)          # one sentinel per worker
        for t in self._threads:
            t.join()

    def __enter__(self) -> "CompileService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- serving
    def submit(self, graph: Graph, hw: FPGAConfig = KCU1500,
               options: CompileOptions | None = None) -> Ticket:
        """Enqueue one request; returns immediately with a Ticket."""
        opts = options if options is not None else self.options
        if not isinstance(opts, CompileOptions):
            raise TypeError(f"options must be a CompileOptions, got "
                            f"{type(opts).__name__}")
        key = request_key(graph, hw, opts)
        with self._lock:
            if self._closed:
                raise ServiceClosed("submit() on a closed CompileService")
            self.stats["requests"] += 1
            # coalesce on (cache key, full options): requests differing
            # only in scheduling knobs share the cache record but not an
            # in-flight ticket (their verify/resume behavior may differ)
            ck = (key, opts)
            ticket = self._inflight.get(ck)
            if ticket is not None:
                self.stats["coalesced"] += 1
                return ticket
            ticket = Ticket(key=key, submitted_at=time.perf_counter())
            try:
                self._queue.put_nowait((ticket, graph, hw, opts, ck))
            except queue.Full:
                self.stats["overloads"] += 1
                raise ServiceOverloaded(
                    f"compile queue full ({self._queue.maxsize} pending); "
                    f"retry with backoff") from None
            self._inflight[ck] = ticket
            return ticket

    def compile(self, graph: Graph, hw: FPGAConfig = KCU1500,
                options: CompileOptions | None = None,
                timeout: float | None = None) -> ExecutionPlan:
        """Synchronous convenience wrapper: submit and wait."""
        return self.submit(graph, hw, options).result(timeout)

    def _serve(self) -> None:
        while True:
            item = self._queue.get()
            if item is None:
                return
            ticket, graph, hw, opts, ck = item
            t0 = time.perf_counter()
            ticket.queue_wait_s = t0 - ticket.submitted_at
            try:
                plan = self._fulfil(ticket, graph, hw, opts)
            except BaseException as e:
                with self._lock:
                    self.stats["failures"] += 1
                    self._inflight.pop(ck, None)
                ticket.service_s = time.perf_counter() - t0
                ticket._resolve(exc=e)
            else:
                with self._lock:
                    self._inflight.pop(ck, None)
                ticket.service_s = time.perf_counter() - t0
                ticket._resolve(plan=plan)

    def _warm_start(self, graph: Graph, fp: str, hw_sig: list,
                    opts: CompileOptions):
        """Nearest cached cuts, guarded by the request's search path.

        *Exhaustive-path* requests (space within ``exhaustive_limit``,
        ``prune`` + ``count_pruned`` on) may seed from any family donor:
        a seeded incumbent only prunes earlier, ``evaluated`` stays the
        full enumeration count and the argmin is oracle-exact, so the
        stored plan bytes provably cannot change.  Under
        ``count_pruned=False`` a warm start would shift ``evaluated``,
        so those requests compile cold.

        *Descent-path* requests (space beyond the limit) never promised
        hit/cold byte-identity -- a warm start there is an extra
        deterministic start that can only improve the result -- but the
        donor must itself be trustworthy: only records whose stored
        search path is ``"exhaustive"`` (oracle-exact argmins, recorded
        per plan since schema v2) are used, so descent results never
        cascade into other descent searches."""
        if not (opts.prune and opts.count_pruned):
            return None
        from repro.core.cutpoint import monotone_runs, split_blocks
        from repro.core.grouping import group_nodes
        space = 1
        for r in monotone_runs(split_blocks(group_nodes(graph))):
            space *= len(r) + 1
        if space > opts.exhaustive_limit:
            return self.cache.nearest(fp, hw_sig,
                                      require_path="exhaustive")
        return self.cache.nearest(fp, hw_sig)

    def _fulfil(self, ticket: Ticket, graph: Graph, hw: FPGAConfig,
                opts: CompileOptions) -> ExecutionPlan:
        blob = self.cache.get(ticket.key)
        if blob is not None:
            try:
                plan = decode_plan(blob, graph, hw)
            except PlanCodecError:
                # stale-schema or undecodable record: a miss, never a
                # ticket failure -- recompile and overwrite it below
                blob = None
            else:
                ticket.hit = True
                with self._lock:
                    self.stats["hits"] += 1
                # verify is scheduling-only: re-run it per request at
                # the requested mode rather than trusting (or keying on)
                # whatever mode the record was compiled under
                return apply_verification(plan, opts.verify,
                                          site="serve")
        with self._lock:
            self.stats["misses"] += 1
        fp = graph_fingerprint(graph)
        hw_sig = hw_signature(hw)
        warm = self._warm_start(graph, fp, hw_sig, opts)
        if warm is not None:
            ticket.warm_started = True
            with self._lock:
                self.stats["warm_starts"] += 1
        plan = compile_graph(graph, hw, opts, warm_start=warm)
        self.cache.put(ticket.key, encode_plan(plan),
                       meta={"graph_fp": fp, "hw_sig": hw_sig,
                             "hw_name": hw.name, "net": graph.name,
                             "cuts": list(plan.candidate.cuts),
                             "path": (plan.search.path
                                      if plan.search is not None
                                      else "policy"),
                             "plan_key": [list(kv) for kv
                                          in opts.plan_key()]})
        return plan
