"""ExecutionPlan <-> bytes codec for the persistent plan cache.

Serves the byte-identity contract: a decoded cache hit must equal a cold
``compile_graph`` of the same request in every field the contract covers
-- candidate metrics, allocation, the three analytic reports, the
``evaluated`` count, the raw instruction-stream words, and the verifier
diagnostics.  So the codec stores those *verbatim* (msgpack round-trips
int and float64 bit-exactly) instead of recomputing anything at decode
time -- recomputation would be both slower (hits must serve in ~ms) and
a place for drift to hide.

Only the structural skeleton is rebuilt at decode: ``graph`` and ``hw``
arrive with the request itself (the cache key guarantees they match what
the record was compiled from), and ``grouped``/``blocks``/``runs`` are
pure deterministic functions of them (``group_nodes`` /
``split_blocks`` / ``monotone_runs``).  ``SearchResult.events`` (what one
historical run *survived*) and ``SearchResult.pruned`` (how much of the
space one run's incumbent bounded away -- a warm-started compile prunes
more than a cold one while producing the identical plan) are run
*history*, not plan content, so both are deliberately dropped; decoded
plans report ``events=[]`` / ``pruned=0``.  ``evaluated`` IS kept: under
``count_pruned=True`` it equals the full enumeration count, a
deterministic function of the request.

Layout: one msgpack map, ``{"v": CACHE_SCHEMA_VERSION, ...}``; the
instruction stream rides as the raw little-endian uint32 byte string of
``isa.encode_stream`` (terminator words included), so hit/cold stream
equality is literal ``bytes`` equality.
"""
from __future__ import annotations

import msgpack
import numpy as np

from repro.analysis.diagnostics import Diagnostic, Severity
from repro.core.allocator import Allocation
from repro.core.compiler import ExecutionPlan
from repro.core.cutpoint import (Candidate, SearchResult, monotone_runs,
                                 split_blocks)
from repro.core.dram import DRAMReport
from repro.core.grouping import group_nodes
from repro.core.hw import FPGAConfig
from repro.core.ir import Graph
from repro.core.isa import decode_stream, encode_stream
from repro.core.sram import SRAMReport
from repro.core.timing import LatencyReport
from repro.service.canonical import CACHE_SCHEMA_VERSION


class PlanCodecError(ValueError):
    """The blob is not a plan record this codec version can decode."""


def _enc_policy(policy: dict[int, str]) -> list:
    return [[gid, mode] for gid, mode in sorted(policy.items())]


def _dec_policy(items: list) -> dict[int, str]:
    return {gid: mode for gid, mode in items}


def _enc_alloc(a: Allocation) -> dict:
    return {
        "policy": _enc_policy(a.policy),
        "in": sorted(a.alloc_in.items()),
        "out": sorted(a.alloc_out.items()),
        "shortcut": sorted(a.alloc_shortcut.items()),
        "buff": list(a.buff),
        "side_buff": a.side_buff,
        "spilled": sorted(a.spilled),
        "boundary_writes": sorted(a.boundary_writes),
        "boundary_reads": sorted(a.boundary_reads.items()),
    }


def _dec_alloc(d: dict) -> Allocation:
    return Allocation(
        policy=_dec_policy(d["policy"]),
        alloc_in=dict(map(tuple, d["in"])),
        alloc_out=dict(map(tuple, d["out"])),
        alloc_shortcut=dict(map(tuple, d["shortcut"])),
        buff=list(d["buff"]),
        side_buff=d["side_buff"],
        spilled=set(d["spilled"]),
        boundary_writes=set(d["boundary_writes"]),
        boundary_reads=dict(map(tuple, d["boundary_reads"])),
    )


def encode_plan(plan: ExecutionPlan) -> bytes:
    cand = plan.candidate
    rec = {
        "v": CACHE_SCHEMA_VERSION,
        "candidate": {
            "cuts": list(cand.cuts),
            "policy": _enc_policy(cand.policy),
            "lat": cand.latency_cycles,
            "dram_total": cand.dram_total,
            "dram_fm": cand.dram_fm,
            "sram": cand.sram_total,
            "bram": cand.bram18k,
            "feasible": bool(cand.feasible),
        },
        "alloc": _enc_alloc(plan.alloc),
        "sram": {
            "weight_buff": plan.sram.weight_buff,
            "row_buff": plan.sram.row_buff,
            "out_buff": plan.sram.out_buff,
            "write_buff": plan.sram.write_buff,
            "buff": list(plan.sram.buff),
            "side_buff": plan.sram.side_buff,
            "sram_total": plan.sram.sram_total,
            "bram18k": plan.sram.bram18k,
        },
        "dram": {"fm": plan.dram.fm_bytes, "w": plan.dram.weight_bytes},
        "latency": {
            "cycles": plan.latency.cycles,
            "per_group": sorted(plan.latency.per_group.items()),
        },
        "stream": encode_stream(plan.instructions).tobytes()
        if plan.instructions else b"",
        "diagnostics": [
            [d.code, d.message, d.gid, d.word, d.context,
             d.severity.value] for d in plan.diagnostics],
    }
    if plan.search is not None:
        # `pruned` (like `events`) is run-history, not plan content: a
        # warm-started compile legitimately prunes MORE than a cold one
        # while producing the identical plan, so it stays out of the
        # record -- otherwise hit/cold byte-identity would break for
        # warm-compiled records.  `path` IS plan content: whether the
        # record came from the oracle-exact exhaustive argmin or from
        # coordinate descent is a deterministic function of the request
        # (space vs exhaustive_limit), and the warm-start donor filter
        # keys on it.
        rec["search"] = {"evaluated": plan.search.evaluated,
                         "path": plan.search.path}
    return msgpack.packb(rec, use_bin_type=True)


def decode_plan(blob: bytes, graph: Graph, hw: FPGAConfig) -> ExecutionPlan:
    """Rebuild an ExecutionPlan for ``(graph, hw)`` from ``blob``.

    The caller owns the guarantee that ``blob`` was compiled from an
    equivalent request -- in the service that guarantee *is* the cache
    key.
    """
    try:
        rec = msgpack.unpackb(blob, raw=False)
    except Exception as e:
        raise PlanCodecError(f"undecodable plan record: {e}") from e
    if not isinstance(rec, dict) or rec.get("v") != CACHE_SCHEMA_VERSION:
        raise PlanCodecError(
            f"plan record schema {rec.get('v') if isinstance(rec, dict) else '?'} "
            f"!= expected {CACHE_SCHEMA_VERSION}")
    gg = group_nodes(graph)
    alloc = _dec_alloc(rec["alloc"])
    c = rec["candidate"]
    cand = Candidate(
        cuts=tuple(c["cuts"]), policy=alloc.policy, alloc=alloc,
        latency_cycles=c["lat"], dram_total=c["dram_total"],
        dram_fm=c["dram_fm"], sram_total=c["sram"], bram18k=c["bram"],
        feasible=c["feasible"])
    search = None
    if "search" in rec:
        blocks = split_blocks(gg)
        search = SearchResult(
            best=cand, evaluated=rec["search"]["evaluated"],
            runs=monotone_runs(blocks), blocks=blocks,
            pruned=0, path=rec["search"].get("path", "exhaustive"))
    s = rec["sram"]
    stream = np.frombuffer(rec["stream"], dtype=np.uint32)
    return ExecutionPlan(
        graph=graph, grouped=gg, hw=hw, candidate=cand, alloc=alloc,
        sram=SRAMReport(weight_buff=s["weight_buff"],
                        row_buff=s["row_buff"], out_buff=s["out_buff"],
                        write_buff=s["write_buff"], buff=list(s["buff"]),
                        side_buff=s["side_buff"],
                        sram_total=s["sram_total"], bram18k=s["bram18k"]),
        dram=DRAMReport(fm_bytes=rec["dram"]["fm"],
                        weight_bytes=rec["dram"]["w"]),
        latency=LatencyReport(cycles=rec["latency"]["cycles"],
                              per_group=dict(map(
                                  tuple, rec["latency"]["per_group"]))),
        instructions=decode_stream(stream) if stream.size else [],
        search=search,
        diagnostics=[
            Diagnostic(code=code, message=msg, gid=gid, word=word,
                       context=ctx, severity=Severity(sev))
            for code, msg, gid, word, ctx, sev in rec["diagnostics"]])
