"""Deterministic, restart-safe LM data pipeline.

Two sources behind one interface:
  * SyntheticSource -- hash-based token stream, reproducible per
    (seed, step, host): byte-identical across restarts and host counts,
    so fault-tolerant resume never replays or skips a batch.
  * BinTokenSource  -- memory-mapped uint32 token file (the standard
    packed-tokens format); each host reads only its shard.

The pipeline yields per-host batches; `fast_forward(step)` is O(1) --
the fault-tolerance substrate uses it after checkpoint restore.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from pathlib import Path
from queue import Queue

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    path: str | None = None          # None -> synthetic

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticSource:
    """splitmix64-based reproducible token stream with LEARNABLE structure.

    Tokens are drawn from a 512-token active subset (so the unigram
    distribution alone is worth ln(V) - ln(512) nats and is learnable in
    tens of steps) and every odd position is a deterministic hash of its
    predecessor (pair structure worth another ~ln(512)/2).  Uniform noise
    over the full vocab would pin the loss at ln(V) forever."""

    ACTIVE = 512

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        active = min(c.vocab, self.ACTIVE)
        n = c.host_batch * (c.seq_len + 1)
        base = (np.uint64(step) << np.uint64(32)) \
            | (np.uint64(c.host_id) << np.uint64(20))
        idx = np.arange(n, dtype=np.uint64) + np.uint64(c.seed) * np.uint64(
            0x9E3779B97F4A7C15)
        with np.errstate(over="ignore"):
            x = base + idx
            # splitmix64 finalizer
            x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
            x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
            x = x ^ (x >> np.uint64(31))
            toks = (x % np.uint64(active)).astype(np.int64).reshape(
                c.host_batch, c.seq_len + 1)
            # structure: odd positions are a fixed hash of the previous
            # token (predictable); even positions stay random
            pred = (toks * 2654435761 + 12345) % active
            out = toks.copy()
            out[:, 1::2] = pred[:, 0:-1:2]
        out = out.astype(np.int32)
        return {"tokens": out[:, :-1],
                "labels": out[:, 1:].copy()}


class BinTokenSource:
    """Packed uint32 tokens on disk; hosts stride disjoint slices."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(Path(cfg.path), dtype=np.uint32, mode="r")
        self.tokens_per_batch = cfg.host_batch * (cfg.seq_len + 1)
        self.n_batches = (len(self.data) // cfg.n_hosts
                          ) // self.tokens_per_batch
        assert self.n_batches > 0, "file too small for one batch"

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        b = step % self.n_batches
        start = (self.cfg.host_id * self.n_batches + b) \
            * self.tokens_per_batch
        flat = np.asarray(
            self.data[start:start + self.tokens_per_batch],
            dtype=np.int32).reshape(c.host_batch, c.seq_len + 1)
        flat = flat % c.vocab
        return {"tokens": flat[:, :-1], "labels": flat[:, 1:].copy()}


class Pipeline:
    """Prefetching iterator with O(1) fast-forward."""

    def __init__(self, cfg: DataConfig, prefetch: int = 2):
        self.cfg = cfg
        self.source = BinTokenSource(cfg) if cfg.path else SyntheticSource(cfg)
        self.step = 0
        self._q: Queue = Queue(maxsize=prefetch)
        self._prefetch = prefetch
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------ control
    def fast_forward(self, step: int) -> None:
        assert self._thread is None, "fast_forward before iteration"
        self.step = step

    def _worker(self) -> None:
        s = self.step
        while not self._stop.is_set():
            self._q.put((s, self.source.batch_at(s)))
            s += 1

    def __iter__(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def close(self) -> None:
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()
