"""Sharding-aware, fault-tolerant checkpointing (msgpack + zstd).

Layout (one directory per step):
    <dir>/step_000123/
        host_<k>.ckpt      -- this host's addressable shards
        MANIFEST.json      -- tree structure, shapes, dtypes, shardings,
                              integrity digests
        COMMITTED          -- written last; restore ignores dirs without it

Properties needed at cluster scale:
  * each host writes only the shards it owns (no gather);
  * atomic commit via the COMMITTED marker after an fsync'd rename --
    a preemption mid-write can never corrupt the restore point;
  * elastic restore: the manifest stores global shapes, so restoring into
    a DIFFERENT mesh re-shards automatically via jax.device_put;
  * async mode double-buffers the host->disk copy off the training loop.

The same codec (msgpack + zstd/zlib) and atomic-commit machinery also
backs :class:`TaskJournal`, the task-granular record store the compiler's
search pool uses for checkpointed compile resume (one digest-verified,
atomically-renamed record per completed sub-space task -- see
core/search_pool.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path

import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:            # optional dep: fall back to stdlib zlib
    zstandard = None


# ------------------------------------------------------------ codec helpers
def get_codec():
    """(name, compress) -- zstd when available, stdlib zlib otherwise."""
    if zstandard is not None:
        return "zstd", zstandard.ZstdCompressor(level=3).compress
    return "zlib", (lambda b: zlib.compress(b, 3))


def get_decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not "
                "installed")
        return zstandard.ZstdDecompressor().decompress
    return zlib.decompress


def atomic_write_bytes(path: Path, data: bytes) -> None:
    """Crash-atomic file write: tmp file in the same directory, fsync,
    then ``os.replace`` -- a reader never observes a partial file."""
    path = Path(path)
    tmp = path.parent / f".tmp_{path.name}.{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(k) for k in path), leaf)
            for path, leaf in leaves], jax.tree.structure(tree)


def _host_shards(arr) -> list[tuple[tuple, np.ndarray]]:
    """(index, data) for every addressable shard of a jax array."""
    out = []
    shape = np.shape(arr)
    if hasattr(arr, "addressable_shards"):
        for s in arr.addressable_shards:
            idx = tuple(
                (sl.start or 0, sl.stop if sl.stop is not None else dim)
                for sl, dim in zip(s.index, shape))
            out.append((idx, np.asarray(s.data)))
    else:
        a = np.asarray(arr)
        out.append((tuple((0, d) for d in a.shape), a))
    return out


def save(tree, directory: str | Path, step: int,
         host_id: int = 0, n_hosts: int = 1) -> Path:
    directory = Path(directory)
    final = directory / f"step_{step:09d}"
    tmp = directory / f".tmp_step_{step:09d}_{host_id}"
    tmp.mkdir(parents=True, exist_ok=True)
    final.mkdir(parents=True, exist_ok=True)

    named, _ = _flatten(tree)
    codec, compress = get_codec()
    manifest = {"step": step, "leaves": {}, "n_hosts": n_hosts,
                "codec": codec}
    payload = {}
    for name, leaf in named:
        arr = leaf
        shards = _host_shards(arr)
        entries = []
        for idx, data in shards:
            blob = compress(np.ascontiguousarray(data).tobytes())
            key = f"{name}::{idx}"
            payload[key] = blob
            entries.append({
                "index": idx,
                "shape": list(data.shape),
                "digest": hashlib.sha256(blob).hexdigest()[:16],
            })
        manifest["leaves"][name] = {
            "global_shape": list(np.shape(arr)),
            "dtype": str(np.dtype(arr.dtype)),
            "shards": entries,
        }
    blob_path = tmp / f"host_{host_id}.ckpt"
    with open(blob_path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    blob_path.rename(final / f"host_{host_id}.ckpt")
    (final / f"MANIFEST_{host_id}.json").write_text(json.dumps(manifest))
    if host_id == 0:
        (final / "COMMITTED").write_text("ok")
    tmp.rmdir()
    return final


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(abstract_tree, directory: str | Path, step: int,
            shardings=None, host_id: int = 0):
    """Rebuild the tree; `shardings` (optional NamedSharding tree) may
    target a different mesh than the one that saved (elastic restore)."""
    directory = Path(directory) / f"step_{step:09d}"
    if not (directory / "COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {directory}")
    manifest = json.loads(
        (directory / f"MANIFEST_{host_id}.json").read_text())

    decompressor = get_decompressor

    # Each host chose its codec independently (zstd, or the zlib fallback
    # when zstandard is missing) and recorded it in its own manifest, so
    # pair every host's blobs with that host's decompressor; decompression
    # itself stays lazy (one shard at a time at the use site below).
    payload = {}
    for f in sorted(directory.glob("host_*.ckpt")):
        hid = f.stem.split("_", 1)[1]
        man_path = directory / f"MANIFEST_{hid}.json"
        if not man_path.exists():
            raise RuntimeError(
                f"{f.name} present but {man_path.name} is missing -- "
                f"host {hid}'s checkpoint write was incomplete")
        host_codec = json.loads(man_path.read_text()).get("codec", "zstd")
        decompress = decompressor(host_codec)
        with open(f, "rb") as fh:
            for key, blob in msgpack.unpackb(fh.read(), raw=False).items():
                payload[key] = (blob, decompress)

    named, _ = _flatten(abstract_tree)
    flat_shard = None
    if shardings is not None:
        flat_shard = dict(_flatten(shardings)[0])

    out = []
    for name, leaf in named:
        meta = manifest["leaves"][name]
        dtype = np.dtype(meta["dtype"])
        full = np.zeros(meta["global_shape"], dtype)
        for key, (blob, decompress) in payload.items():
            if not key.startswith(name + "::"):
                continue
            idx = eval(key.split("::", 1)[1])       # trusted local manifest
            raw = decompress(blob)
            piece_shape = [stop - start for (start, stop) in idx] \
                if idx else []
            piece = np.frombuffer(raw, dtype).reshape(piece_shape)
            sl = tuple(slice(start, stop) for (start, stop) in idx)
            full[sl] = piece
        if flat_shard is not None and name in flat_shard:
            out.append(jax.device_put(full, flat_shard[name]))
        else:
            out.append(jnp.asarray(full))
    leaves, treedef = jax.tree_util.tree_flatten(abstract_tree)
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Double-buffered async save: the train loop hands off host-local
    numpy copies and continues; a worker thread does compression + IO."""

    def __init__(self, directory: str | Path, host_id: int = 0,
                 n_hosts: int = 1):
        self.directory = Path(directory)
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._pending: threading.Thread | None = None

    def save(self, tree, step: int) -> None:
        self.wait()
        # Materialize host copies SYNCHRONOUSLY: the caller's next train
        # step donates these buffers, so the IO thread must never touch
        # the live device arrays (a lazy snapshot raced donation and read
        # deleted buffers -- regression-tested in test_substrates).
        snapshot = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = threading.Thread(
            target=save, args=(snapshot, self.directory, step,
                               self.host_id, self.n_hosts), daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None


# ---------------------------------------------------------- task journal
class JournalError(RuntimeError):
    """A journal record exists but cannot be trusted (truncated file,
    digest mismatch, undecodable payload).  Raised instead of silently
    recomputing: a corrupt record means the journal directory is damaged
    and resuming from its siblings may be equally wrong."""


class TaskJournal:
    """Task-granular completion journal for resumable batch compiles.

    One journal covers one *search* (identified by ``search_key``, a
    content hash of graph/hw/objective/partition -- the caller computes
    it); each completed task commits one record file

        <root>/search_<search_key>/task_<task_key>.rec

    written with :func:`atomic_write_bytes` (tmp + fsync + ``os.replace``,
    the same commit discipline as the training checkpoints above), so a
    kill mid-write never corrupts the journal -- the record is either
    fully present or absent.  Records are msgpack maps compressed with
    the shared codec and carry a sha256 digest that :meth:`get` verifies
    on read; any mismatch raises :class:`JournalError` rather than
    resuming from damaged state.

    Records must be msgpack-representable (ints, float64, bools, str,
    lists/maps).  msgpack round-trips float64 bit-exactly, which is what
    lets a resumed search reproduce byte-identical metrics.
    """

    def __init__(self, root, search_key: str):
        self.dir = Path(root) / f"search_{search_key}"
        self.dir.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def task_key(obj) -> str:
        """Stable 16-hex key for a task identity (e.g. a prefix tuple)."""
        return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]

    def _path(self, task_key: str) -> Path:
        return self.dir / f"task_{task_key}.rec"

    def put(self, task_key: str, record: dict) -> None:
        codec, compress = get_codec()
        blob = compress(msgpack.packb(record, use_bin_type=True))
        payload = msgpack.packb(
            {"codec": codec, "digest": hashlib.sha256(blob).hexdigest(),
             "blob": blob}, use_bin_type=True)
        atomic_write_bytes(self._path(task_key), payload)

    def get(self, task_key: str):
        """The committed record for ``task_key``, or None if absent."""
        path = self._path(task_key)
        if not path.exists():
            return None
        try:
            wrapper = msgpack.unpackb(path.read_bytes(), raw=False)
            blob = wrapper["blob"]
            if hashlib.sha256(blob).hexdigest() != wrapper["digest"]:
                raise ValueError("digest mismatch")
            decompress = get_decompressor(wrapper["codec"])
            return msgpack.unpackb(decompress(blob), raw=False)
        except Exception as e:
            # any decode/digest/decompress failure: the record is damaged
            raise JournalError(
                f"corrupt task-journal record {path}: {e}") from e

    def __len__(self) -> int:
        return sum(1 for _ in self.dir.glob("task_*.rec"))
