"""Int8 error-feedback gradient compression for cross-pod reduction.

At 2x16x16 scale the 'pod' axis rides the slow inter-pod links; gradients
crossing it are quantized to int8 with a per-leaf scale, and the
quantization error is fed back into the next step's gradient (error
feedback keeps SGD/Adam convergence -- Karimireddy et al. 2019).  The
compressed tree is what the pod-axis all-reduce sees: 4x fewer bytes for
fp32 grads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, error):
    """-> (q_int8 tree, scales tree, new_error tree)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    qs, scales, errs = zip(*[one(g, e) for g, e in zip(flat, flat_e)])
    return (jax.tree.unflatten(tdef, qs),
            jax.tree.unflatten(tdef, scales),
            jax.tree.unflatten(tdef, errs))


def decompress(q_tree, scale_tree):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def compressed_psum(grads, error, axis_name: str):
    """All-reduce `grads` over `axis_name` in int8 with error feedback.
    Use inside shard_map/pmap-style code where the pod axis is manual."""
    q, s, new_error = compress(grads, error)
    q_sum = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.int32), axis_name), q)
    # scales differ per participant: reduce with max for a safe bound
    s_max = jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), s)
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(
        lambda qq, ss: qq.astype(jnp.float32) * ss / n, q_sum, s_max)
    return mean, new_error
