"""In-house AdamW with decoupled weight decay, linear-warmup cosine
schedule and global-norm clipping.  Tree-based and shard-transparent: the
optimizer state mirrors the parameter tree (and its shardings)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / (1 - cfg.b1 ** step)
        vh = v / (1 - cfg.b2 ** step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gn, "lr": lr}
