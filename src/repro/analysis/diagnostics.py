"""Typed diagnostics for the static plan verifier.

Every check in ``repro.analysis.verifier`` reports through a
:class:`Diagnostic` carrying a stable ``SF0xx`` code, a severity, the
group / instruction-word anchor the finding points at, and a rendered
source-context line.  Codes are stable across releases (tests, CI gates
and downstream tooling key on them); new checks take new codes instead of
reusing retired ones.

Code map (the check catalog lives in ``docs/architecture.md``):

====== ====================================================================
SF01x  dataflow (def-before-use, single producer, stream shape)
SF02x  buffer liveness (clobbers, unavailable operands, lost outputs)
SF03x  capacity (SRAM/BRAM budgets, buffer occupancy vs declared maxima)
SF04x  DRAM conservation (double writes, dangling reads, model agreement)
SF05x  ISA well-formedness (bit-field ranges, mode/fusion legality)
====== ====================================================================
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"

    def __str__(self) -> str:  # render as "error"/"warning" in reports
        return self.value


#: code -> (title, default severity).  The verifier may downgrade capacity
#: errors to warnings when the plan itself is marked infeasible (the
#: optimizer already knows and reports it; strict mode gates on errors).
CODES: dict[str, tuple[str, Severity]] = {
    # ---- SF01x: dataflow
    "SF010": ("use-before-def: src operand refers to a gid not yet "
              "produced", Severity.ERROR),
    "SF011": ("unknown producer: src operand out of range", Severity.ERROR),
    "SF012": ("duplicate producer: gid encoded more than once",
              Severity.ERROR),
    "SF013": ("stream order: instructions not in dense ascending gid "
              "order", Severity.ERROR),
    "SF014": ("missing group: no instruction for a graph group",
              Severity.ERROR),
    "SF015": ("src_main disagrees with the grouped graph's main input",
              Severity.ERROR),
    "SF016": ("src_shortcut disagrees with the grouped graph's shortcut "
              "source", Severity.ERROR),
    # ---- SF02x: buffer liveness
    "SF020": ("shortcut clobber: write evicts a live tensor another "
              "consumer will read", Severity.ERROR),
    "SF021": ("operand unavailable: frame-mode read finds the tensor in "
              "no buffer and not in DRAM", Severity.ERROR),
    "SF022": ("row-mode read of a frame-produced tensor never written "
              "out at the boundary", Severity.ERROR),
    "SF023": ("frame-mode output has no destination (no buffer, not "
              "spilled, not a boundary write)", Severity.ERROR),
    "SF024": ("allocation record diverges from the allocator journal "
              "replay", Severity.ERROR),
    "SF025": ("alloc field inconsistent with the abstract machine's "
              "buffer state", Severity.ERROR),
    # ---- SF03x: capacity
    "SF030": ("SRAM total exceeds the hardware budget", Severity.ERROR),
    "SF031": ("BRAM18K count exceeds the hardware budget (advisory: the "
              "optimizer's feasibility contract constrains SRAM bytes, "
              "not BRAM banks)", Severity.WARNING),
    "SF032": ("buffer occupancy exceeds the allocation's declared "
              "capacity", Severity.ERROR),
    # ---- SF04x: DRAM conservation
    "SF040": ("tensor written to DRAM more than once", Severity.ERROR),
    "SF041": ("DRAM read of a tensor never written to DRAM",
              Severity.ERROR),
    "SF042": ("static DRAM byte count disagrees with the analytic model",
              Severity.ERROR),
    "SF043": ("dead DRAM spill: tensor written off-chip but never read",
              Severity.WARNING),
    # ---- SF05x: ISA well-formedness
    "SF050": ("bit-field overflow: field value does not fit its encoding "
              "slot", Severity.ERROR),
    "SF051": ("unknown opcode / mode / activation code", Severity.ERROR),
    "SF052": ("alloc field is not a physical buffer id or OFFCHIP",
              Severity.ERROR),
    "SF053": ("row-mode group carries an on-chip buffer assignment",
              Severity.ERROR),
    "SF054": ("fusion legality: eltwise/shortcut operand rules violated",
              Severity.ERROR),
    "SF055": ("instruction geometry disagrees with the graph group",
              Severity.ERROR),
}


@dataclass(frozen=True)
class Diagnostic:
    """One verifier finding.

    ``gid`` anchors the group the finding is about (None for stream-level
    findings); ``word`` the instruction word index within the 11-word
    encoding, when the finding points at a specific field; ``context`` is
    a rendered source-context line (group repr, live interval, field
    dump) for human reports."""
    code: str
    message: str
    gid: int | None = None
    word: int | None = None
    context: str = ""
    severity: Severity = field(default=Severity.ERROR)

    @property
    def title(self) -> str:
        return CODES[self.code][0]

    def render(self) -> str:
        anchor = "" if self.gid is None else f" @g{self.gid}"
        anchor += "" if self.word is None else f".w{self.word}"
        out = f"{self.code}{anchor} [{self.severity}] {self.message}"
        if self.context:
            out += f"\n        | {self.context}"
        return out


def make(code: str, message: str, gid: int | None = None,
         word: int | None = None, context: str = "",
         severity: Severity | None = None) -> Diagnostic:
    """Build a Diagnostic with the catalog's default severity unless
    overridden (unknown codes are a programming error, caught here)."""
    if code not in CODES:
        raise KeyError(f"unknown diagnostic code {code!r}")
    return Diagnostic(code=code, message=message, gid=gid, word=word,
                      context=context,
                      severity=severity or CODES[code][1])


class VerificationError(RuntimeError):
    """Raised by ``compile_graph(verify="strict")`` / the CLI when a plan
    has error-severity diagnostics.  Carries the full diagnostic list."""

    def __init__(self, name: str, diagnostics: list[Diagnostic]):
        self.diagnostics = diagnostics
        errors = [d for d in diagnostics if d.severity is Severity.ERROR]
        lines = "\n".join("  " + d.render() for d in diagnostics)
        super().__init__(
            f"static verification of {name!r} failed: "
            f"{len(errors)} error(s), "
            f"{len(diagnostics) - len(errors)} warning(s)\n{lines}")


def render_report(name: str, diagnostics: list[Diagnostic],
                  extra: str = "") -> str:
    """Human-readable per-plan report block (the CLI's output unit)."""
    errors = sum(d.severity is Severity.ERROR for d in diagnostics)
    warnings = len(diagnostics) - errors
    head = (f"== {name}: "
            + ("clean" if not diagnostics
               else f"{errors} error(s), {warnings} warning(s)"))
    body = "\n".join("  " + d.render() for d in diagnostics)
    parts = [head]
    if extra:
        parts.append(extra)
    if body:
        parts.append(body)
    return "\n".join(parts)
