"""Per-buffer live intervals, derived from the allocator journal replay.

Algorithm 1 is sequential: walking groups in gid order, each frame-mode
group may claim one of the three physical buffers for its output and each
consumption may release one.  ``core.allocator.iter_alloc_states`` replays
that walk and exposes the state after every step; the ownership
transitions of ``live_in_buffer`` between consecutive steps are exactly
the claim/release events of the allocator's journal, so a full interval
timeline costs one O(groups) replay -- no simulation, no search.

The verifier uses these intervals two ways:

* **consistency** -- the instruction stream's ``alloc_out`` assignments
  must land inside the journal's intervals (a swapped or clobbered
  assignment diverges, diagnostic SF024);
* **context** -- liveness diagnostics render the overlapping interval
  (owner, span) so a clobber report names the tensor that would have been
  destroyed.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.allocator import Allocation, Policy, iter_alloc_states
from repro.core.grouping import GroupedGraph


@dataclass(frozen=True)
class BufferInterval:
    """Tensor ``owner``'s residency in physical buffer ``buffer``:
    claimed while processing group ``start`` (== owner for output claims),
    still resident through group ``end`` inclusive."""
    buffer: int
    owner: int
    start: int
    end: int

    def covers(self, gid: int) -> bool:
        return self.start <= gid <= self.end

    def render(self) -> str:
        return f"buf{self.buffer}<-g{self.owner} live [g{self.start}, g{self.end}]"


@dataclass
class JournalTrace:
    """Everything the verifier needs from one journal replay."""
    intervals: list[BufferInterval]
    # the replayed (authoritative) allocation for the policy
    alloc: Allocation

    def intervals_in(self, buffer: int) -> list[BufferInterval]:
        return [iv for iv in self.intervals if iv.buffer == buffer]

    def owner_at(self, buffer: int, gid: int) -> BufferInterval | None:
        """The interval occupying ``buffer`` when group ``gid`` runs."""
        for iv in self.intervals:
            if iv.buffer == buffer and iv.covers(gid):
                return iv
        return None


def journal_trace(gg: GroupedGraph, policy: Policy) -> JournalTrace:
    """Replay the allocator under ``policy`` and derive per-buffer live
    intervals from the ownership transitions of its journal."""
    open_ivs: dict[int, tuple[int, int]] = {}      # buffer -> (owner, start)
    intervals: list[BufferInterval] = []
    prev_gid = 0
    state = None
    for step, state in iter_alloc_states(gg, policy):
        cur = state.live_in_buffer
        for b, (owner, start) in list(open_ivs.items()):
            if cur.get(b) != owner:
                # Released during this step: the tensor was still readable
                # while this group consumed it, so the interval includes
                # step.gid.
                intervals.append(BufferInterval(b, owner, start, step.gid))
                del open_ivs[b]
        for b, owner in cur.items():
            if b not in open_ivs:
                open_ivs[b] = (owner, step.gid)
        prev_gid = step.gid
    for b, (owner, start) in open_ivs.items():
        intervals.append(BufferInterval(b, owner, start, prev_gid))
    intervals.sort(key=lambda iv: (iv.start, iv.buffer))
    alloc = state.alloc if state is not None else Allocation(policy={})
    return JournalTrace(intervals=intervals, alloc=alloc)


def render_intervals(trace: JournalTrace, limit: int = 12) -> str:
    """Compact interval summary for CLI reports."""
    ivs = trace.intervals
    shown = ", ".join(iv.render() for iv in ivs[:limit])
    more = f", ... ({len(ivs) - limit} more)" if len(ivs) > limit else ""
    return f"{len(ivs)} buffer live intervals: {shown}{more}"
