"""CLI: statically verify compiled plans and run the mutation-kill gate.

Usage::

    python -m repro.analysis --all                    # verify every zoo net
    python -m repro.analysis --net resnet50 --strict  # one net, exit 1 on error
    python -m repro.analysis --all --mutation-kill    # coverage gate
    python -m repro.analysis --all --report out.txt   # write rendered report

Each net is compiled (bounded search, identical to the tier-1 audit
setup), verified with the full check battery, and reported per plan.
``--strict`` exits nonzero when any error-severity diagnostic survives;
``--mutation-kill`` additionally injects every applicable mutation class
x seed and exits nonzero unless the verifier kills 100% of them.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.diagnostics import Severity, render_report
from repro.analysis.liveness import journal_trace, render_intervals
from repro.analysis.mutate import kill_matrix, render_kill_matrix
from repro.analysis.verifier import verify_execution_plan
from repro.cnn import build_cnn
from repro.core.compiler import compile_graph
from repro.core.options import CompileOptions

ZOO = [("vgg16-conv", 224), ("yolov2", 416), ("yolov3", 416),
       ("resnet50", 224), ("resnet152", 224), ("efficientnet-b1", 256),
       ("retinanet", 512), ("mobilenet-v3", 224)]

# Same bound as tests/test_simulator_audit.py: detector-scale nets take
# the coordinate-descent path so a full-zoo verify stays interactive.
DEFAULT_LIMIT = 50_000


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification of compiled ExecutionPlans.")
    ap.add_argument("--net", action="append", default=[],
                    help="zoo net to verify (repeatable); see --all")
    ap.add_argument("--all", action="store_true",
                    help="verify every zoo net")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any error-severity diagnostic is found")
    ap.add_argument("--report", metavar="PATH",
                    help="also write the rendered report to PATH")
    ap.add_argument("--mutation-kill", action="store_true",
                    help="run the seeded mutation fuzzer; exit 1 unless "
                         "every applicable mutant is killed")
    ap.add_argument("--seeds", type=int, default=3,
                    help="seeds per mutation class (default 3)")
    ap.add_argument("--engine", default="journal",
                    help="execution engine for the compile search "
                         "(e.g. journal, device, device:pallas, pipeline)")
    ap.add_argument("--exhaustive-limit", type=int, default=DEFAULT_LIMIT,
                    help=f"cut-search exhaustive bound "
                         f"(default {DEFAULT_LIMIT})")
    ap.add_argument("--intervals", action="store_true",
                    help="include the buffer live-interval summary")
    args = ap.parse_args(argv)

    sizes = dict(ZOO)
    nets = [n for n, _ in ZOO] if args.all else args.net
    if not nets:
        ap.error("pick nets with --net NAME (repeatable) or --all")
    unknown = [n for n in nets if n not in sizes]
    if unknown:
        ap.error(f"unknown net(s) {unknown}; zoo: {sorted(sizes)}")

    blocks: list[str] = []
    plans: dict[str, object] = {}
    total_errors = 0
    for name in nets:
        plan = compile_graph(
            build_cnn(name, sizes[name]),
            options=CompileOptions(
                exhaustive_limit=args.exhaustive_limit,
                engine=args.engine))
        plans[name] = plan
        diags = verify_execution_plan(plan)
        total_errors += sum(d.severity is Severity.ERROR for d in diags)
        extra = ""
        if args.intervals:
            extra = "  " + render_intervals(
                journal_trace(plan.grouped, plan.alloc.policy))
        blocks.append(render_report(
            f"{name} ({len(plan.grouped.groups)} groups, "
            f"{'feasible' if plan.candidate.feasible else 'infeasible'})",
            diags, extra=extra))

    out = "\n".join(blocks)
    exit_code = 0
    if args.strict and total_errors:
        exit_code = 1

    if args.mutation_kill:
        rows = kill_matrix(plans, seeds=tuple(range(args.seeds)))
        out += "\n\n" + render_kill_matrix(rows)
        applied = [r for r in rows if r["applied"]]
        missed = [r for r in applied if not r["killed"]]
        if missed or not applied:
            exit_code = 1

    print(out)
    if args.report:
        with open(args.report, "w") as fh:
            fh.write(out + "\n")
        print(f"report written to {args.report}", file=sys.stderr)
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
