"""Static plan verifier: prove an ExecutionPlan safe without executing it.

The paper's whole design rests on the *static* buffer allocation
``{alloc_in, alloc_out, alloc_shortcut}`` (Fig. 5b / Algorithm 1) never
clobbering live shortcut data and never exceeding the on-chip budgets.
``verify_plan`` checks that in O(plan) -- no tensors, no simulation -- by
running an *abstract location machine* over the instruction stream: the
functional simulator's dry-mode traversal with every tensor replaced by
its location (buffer id / side space / DRAM) and every transition checked
for legality.  Five check families (codes in ``diagnostics.CODES``):

1. **Dataflow** (SF01x) -- def-before-use and single-producer over the
   decoded ``src_main``/``src_shortcut`` fields; stream shape/order.
2. **Liveness** (SF02x) -- per-buffer live intervals derived from the
   allocator journal (``liveness.journal_trace``); a write to
   ``alloc_out`` must never evict a tensor another consumer will still
   read (the shortcut-clobber class Algorithm 1 exists to prevent), and
   the stream's assignments must land inside the journal's intervals.
3. **Capacity** (SF03x) -- static occupancy of each physical buffer from
   the stream's own claims, the eq. (5) write-buffer bound and the
   eq. (6)/(7) SRAM/BRAM totals vs the ``FPGAConfig`` budgets.
4. **DRAM conservation** (SF04x) -- every off-chip tensor written once
   and read once per consumer, weights fetched exactly once; the
   machine's byte count must equal the analytic model (eqs. (8)/(9)),
   which is the same invariant the dynamic simulator audits -- so any
   traffic divergence the simulator could observe is caught statically.
5. **ISA well-formedness** (SF05x) -- bit-field ranges against the
   11-word encoding (``isa.FIELD_WIDTHS``), opcode/mode/activation
   validity, row-mode and eltwise/shortcut fusion legality, geometry
   agreement with the grouped graph.

The dynamic ``Simulator`` stays the oracle of record for *numerics*; the
verifier is the O(plan) referee every backend-independent consumer (the
compile service, device replays, mutated streams) can run before trusting
a plan.  ``analysis.mutate`` proves the coverage: every class of injected
violation must raise at least one diagnostic, and every mutant the
simulator can detect dynamically must be caught here statically.
"""
from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic, Severity, make
from repro.analysis.liveness import JournalTrace, journal_trace
from repro.core.allocator import Allocation, _is_side
from repro.core.dram import dram_fm
from repro.core.grouping import GroupedGraph
from repro.core.hw import FPGAConfig
from repro.core.isa import (ACTS, FIELD_WIDTHS, MODES, OFFCHIP, OPCODES,
                            GroupInstruction, field_overflows)
from repro.core.sram import _bram18k_total, sram_report

# instruction word each field is packed into (diagnostic anchors)
_FIELD_WORD = {
    "opcode": 0, "mode": 0, "act": 0, "k": 0, "stride": 0,
    "in_ch": 1, "out_ch": 2, "in_h": 3, "in_w": 4,
    "fused_pool": 5, "fused_eltwise": 5, "fused_upsample": 5,
    "alloc_in": 6, "alloc_out": 6, "alloc_shortcut": 6,
    "src_main": 7, "src_shortcut": 8, "gid": 9,
}
_BUFFER_IDS = (0, 1, 2, OFFCHIP)
_OPCODE_SET = set(OPCODES.values())
_ACT_SET = set(ACTS.values())


def _instr_context(i: GroupInstruction) -> str:
    return (f"op={i.opcode} mode={i.mode} k={i.k} s={i.stride} "
            f"alloc=({i.alloc_in},{i.alloc_out},{i.alloc_shortcut}) "
            f"src=({i.src_main},{i.src_shortcut})")


# ------------------------------------------------------------ SF01x / SF05x
def _check_stream_shape(gg: GroupedGraph,
                        instructions: list[GroupInstruction],
                        diags: list[Diagnostic]) -> dict[int, GroupInstruction]:
    n = len(gg.groups)
    by_gid: dict[int, GroupInstruction] = {}
    prev = -1
    for pos, ins in enumerate(instructions):
        if ins.gid in by_gid:
            diags.append(make("SF012", f"gid {ins.gid} encoded twice "
                              f"(stream positions {pos} and earlier)",
                              gid=ins.gid, word=9))
            continue
        if ins.gid <= prev:
            diags.append(make(
                "SF013", f"stream position {pos} carries gid {ins.gid} "
                f"after gid {prev} (instructions must be dense ascending)",
                gid=ins.gid, word=9))
        prev = max(prev, ins.gid)
        by_gid[ins.gid] = ins
    for g in gg.groups:
        if g.gid not in by_gid:
            diags.append(make("SF014", f"group {g.gid} ({g!r}) has no "
                              f"instruction", gid=g.gid))
    for gid in by_gid:
        if not 0 <= gid < n:
            diags.append(make("SF011", f"instruction gid {gid} does not "
                              f"name a graph group (0..{n - 1})",
                              gid=gid, word=9))
    return by_gid


def _check_wellformed(gg: GroupedGraph, alloc: Allocation,
                      by_gid: dict[int, GroupInstruction],
                      diags: list[Diagnostic]) -> None:
    n = len(gg.groups)
    for gid, ins in sorted(by_gid.items()):
        if not 0 <= gid < n:
            continue
        g = gg.groups[gid]
        ctx = _instr_context(ins)
        # ---- bit-field ranges (SF050): the decoded form must round-trip
        # through the 11-word encoding without truncation.
        for name in FIELD_WIDTHS:
            v = getattr(ins, name)
            if field_overflows(name, v):
                diags.append(make(
                    "SF050", f"{name}={v} does not fit its "
                    f"{FIELD_WIDTHS[name]}-bit slot",
                    gid=gid, word=_FIELD_WORD[name], context=ctx))
        for name in ("src_main", "src_shortcut"):
            if field_overflows(name, getattr(ins, name)):
                diags.append(make(
                    "SF050", f"{name}={getattr(ins, name)} does not fit "
                    f"its signed 32-bit slot",
                    gid=gid, word=_FIELD_WORD[name], context=ctx))
        # ---- enum validity (SF051)
        if ins.opcode not in _OPCODE_SET:
            diags.append(make("SF051", f"opcode {ins.opcode} unknown",
                              gid=gid, word=0, context=ctx))
        if ins.mode not in (0, 1):
            diags.append(make("SF051", f"mode {ins.mode} unknown "
                              f"(0=row, 1=frame)", gid=gid, word=0,
                              context=ctx))
        if ins.act not in _ACT_SET:
            diags.append(make("SF051", f"act {ins.act} unknown",
                              gid=gid, word=0, context=ctx))
        if ins.fused_pool not in (0, 1, 2) or ins.fused_eltwise not in (0, 1):
            diags.append(make(
                "SF054", f"fused_pool={ins.fused_pool} / "
                f"fused_eltwise={ins.fused_eltwise} outside the legal "
                f"fusion codes", gid=gid, word=5, context=ctx))
        # ---- alloc fields (SF052 / SF053)
        for name in ("alloc_in", "alloc_out", "alloc_shortcut"):
            v = getattr(ins, name)
            if v not in _BUFFER_IDS:
                diags.append(make(
                    "SF052", f"{name}={v} is neither a physical buffer "
                    f"{{0,1,2}} nor OFFCHIP({OFFCHIP})",
                    gid=gid, word=6, context=ctx))
        if ins.mode == 0:
            onchip = [name for name in ("alloc_in", "alloc_out",
                                        "alloc_shortcut")
                      if getattr(ins, name) != OFFCHIP]
            if onchip:
                diags.append(make(
                    "SF053", f"row-mode group assigns {', '.join(onchip)} "
                    f"on-chip; the row pipeline streams through DRAM",
                    gid=gid, word=6, context=ctx))
        # ---- dataflow srcs (SF010 / SF011 / SF015 / SF016)
        for name in ("src_main", "src_shortcut"):
            src = getattr(ins, name)
            if src >= gid:
                diags.append(make(
                    "SF010", f"{name}={src} is not produced before "
                    f"group {gid}", gid=gid, word=_FIELD_WORD[name],
                    context=ctx))
            elif src < -1 or src >= n:
                diags.append(make(
                    "SF011", f"{name}={src} names no producer",
                    gid=gid, word=_FIELD_WORD[name], context=ctx))
        gin = gg.group_inputs(g)
        want_main = gin[0] if gin else -1
        if ins.src_main != want_main:
            diags.append(make(
                "SF015", f"src_main={ins.src_main} but the grouped graph "
                f"feeds group {gid} from {want_main}",
                gid=gid, word=7, context=ctx))
        sc = gg.shortcut_source_group(g)
        want_sc = sc if sc is not None else -1
        if ins.src_shortcut != want_sc:
            diags.append(make(
                "SF016", f"src_shortcut={ins.src_shortcut} but the "
                f"grouped graph's shortcut source is {want_sc}",
                gid=gid, word=8, context=ctx))
        # ---- fusion legality (SF054)
        has_add = g.fused_add is not None
        if bool(ins.fused_eltwise) != has_add:
            diags.append(make(
                "SF054", f"fused_eltwise={ins.fused_eltwise} but the "
                f"group {'has' if has_add else 'has no'} eltwise add",
                gid=gid, word=5, context=ctx))
        if not ins.fused_eltwise and ins.src_shortcut != -1:
            diags.append(make(
                "SF054", f"src_shortcut={ins.src_shortcut} forged on a "
                f"group with no eltwise operand", gid=gid, word=8,
                context=ctx))
        if (ins.fused_eltwise and ins.src_shortcut != -1
                and ins.src_shortcut == ins.src_main):
            diags.append(make(
                "SF054", "eltwise operands collapse: src_shortcut == "
                "src_main (row-mode add reads two distinct operands)",
                gid=gid, word=8, context=ctx))
        # ---- geometry / mode agreement with the graph (SF055)
        head, tail = g.head, g.tail
        expect = {
            "opcode": OPCODES[head.kind], "k": head.k,
            "stride": head.stride, "in_ch": head.in_ch,
            "out_ch": tail.out_ch, "in_h": head.in_h, "in_w": head.in_w,
        }
        for name, want in expect.items():
            got = getattr(ins, name)
            if got != want:
                diags.append(make(
                    "SF055", f"{name}={got} disagrees with the graph "
                    f"({name}={want} for {g!r})",
                    gid=gid, word=_FIELD_WORD[name], context=ctx))
        mode = alloc.policy.get(gid)
        if mode is not None and ins.mode in (0, 1) \
                and ins.mode != MODES[mode]:
            diags.append(make(
                "SF055", f"mode={ins.mode} disagrees with the "
                f"allocation's policy ({mode!r})", gid=gid, word=0,
                context=ctx))


# ----------------------------------------------------- SF02x / SF03x / SF04x
def _abstract_machine(gg: GroupedGraph, alloc: Allocation,
                      by_gid: dict[int, GroupInstruction], hw: FPGAConfig,
                      trace: JournalTrace | None,
                      diags: list[Diagnostic],
                      capacity_severity: Severity) -> None:
    """Dry simulator traversal over *locations*: every fetch must find its
    operand somewhere legal, every store must not destroy live data, and
    the resulting byte counts must reproduce the analytic DRAM model."""
    groups = gg.groups
    n = len(groups)
    remaining = [len(gg.group_consumers(g)) for g in groups]
    remaining.append(1)                        # graph input (index -1)
    buffers: dict[int, int] = {}               # buffer id -> owner gid
    dram: set[int] = {-1}                      # gids materialized off-chip
    side: set[int] = set()
    reads_of: dict[int, int] = {}              # DRAM fetch count per gid
    dram_reads = dram_writes = weight_reads = 0
    occ = [0, 0, 0]                            # observed buffer occupancy
    side_occ = 0
    input_size = gg.graph.nodes[0].out_size

    def nbytes(src: int) -> int:
        return input_size if src == -1 else groups[src].out_size

    for g in groups:
        ins = by_gid.get(g.gid)
        if ins is None:
            continue                           # SF014 already reported
        gid = g.gid
        weight_reads += g.weight_size
        gin = gg.group_inputs(g) or [-1]
        frame = ins.mode == 1
        is_side_g = _is_side(gg, g)
        counted = not (is_side_g
                       or (not frame and g.kind in ("concat", "route")))
        main_src = gin[0]
        sc = gg.shortcut_source_group(g)
        for src in gin:
            loc_buf = None
            if src not in side:
                if frame:
                    for b, owner in buffers.items():
                        if owner == src:
                            loc_buf = b
                            break
                if loc_buf is None:
                    # DRAM fetch (row streaming, boundary, spill, input)
                    reads_of[src] = reads_of.get(src, 0) + 1
                    if counted:
                        dram_reads += nbytes(src)
                    if src not in dram and counted:
                        if frame:
                            diags.append(make(
                                "SF021", f"group {gid} reads operand "
                                f"g{src} from no buffer and DRAM never "
                                f"received it (clobbered or never "
                                f"materialized)", gid=gid, word=7,
                                context=repr(g)))
                        else:
                            prod = by_gid.get(src)
                            code = ("SF022" if prod is not None
                                    and prod.mode == 1 else "SF041")
                            diags.append(make(
                                code, f"row-mode group {gid} streams "
                                f"operand g{src} from DRAM but its "
                                f"producer never wrote it out",
                                gid=gid, word=7, context=repr(g)))
            if frame and loc_buf is not None and src == main_src \
                    and ins.alloc_in != OFFCHIP and ins.alloc_in != loc_buf:
                diags.append(make(
                    "SF025", f"alloc_in={ins.alloc_in} but the main "
                    f"operand g{src} lives in buffer {loc_buf}",
                    gid=gid, word=6, context=_instr_context(ins)))
            if frame and loc_buf is not None and sc == src \
                    and ins.alloc_shortcut != OFFCHIP \
                    and ins.alloc_shortcut != loc_buf:
                diags.append(make(
                    "SF025", f"alloc_shortcut={ins.alloc_shortcut} but "
                    f"the shortcut operand g{src} lives in buffer "
                    f"{loc_buf}", gid=gid, word=6,
                    context=_instr_context(ins)))
            remaining[src] -= 1
        # DRAM-fetched main input claims alloc_in transiently (Alg. 1):
        # it occupies the buffer while the group reads it.
        if frame and not is_side_g and ins.alloc_in != OFFCHIP \
                and not any(o == main_src for o in buffers.values()):
            if ins.alloc_in < 3:
                if g.in_size > occ[ins.alloc_in]:
                    occ[ins.alloc_in] = g.in_size
                if ins.alloc_out == ins.alloc_in:
                    diags.append(make(
                        "SF025", f"alloc_out={ins.alloc_out} overwrites "
                        f"the buffer the DRAM-fetched input is being "
                        f"read from", gid=gid, word=6,
                        context=_instr_context(ins)))

        # ---------------------------------------------------------- store
        if is_side_g:
            side.add(gid)
            if g.out_size > side_occ:
                side_occ = g.out_size
            continue
        if not frame:
            if g.kind not in ("concat", "route"):
                if gid in dram:
                    diags.append(make(
                        "SF040", f"group {gid} writes its output to DRAM "
                        f"twice", gid=gid, context=repr(g)))
                dram_writes += g.out_size
            dram.add(gid)
            continue
        spilled = gid in alloc.spilled
        boundary = gid in alloc.boundary_writes
        if ins.alloc_out != OFFCHIP and not spilled and ins.alloc_out < 3:
            prev = buffers.get(ins.alloc_out)
            if prev is not None and prev != gid and remaining[prev] > 0 \
                    and prev not in dram:
                iv = trace.owner_at(ins.alloc_out, gid) if trace else None
                diags.append(make(
                    "SF020", f"group {gid} writes buffer "
                    f"{ins.alloc_out} and destroys g{prev}, which "
                    f"{remaining[prev]} consumer(s) still read and DRAM "
                    f"does not hold", gid=gid, word=6,
                    context=(iv.render() if iv is not None
                             else _instr_context(ins))))
            buffers[ins.alloc_out] = gid
            if g.out_size > occ[ins.alloc_out]:
                occ[ins.alloc_out] = g.out_size
        if spilled or boundary:
            if gid in dram:
                diags.append(make(
                    "SF040", f"group {gid} writes its output to DRAM "
                    f"twice", gid=gid, context=repr(g)))
            dram_writes += g.out_size
            dram.add(gid)
        elif ins.alloc_out == OFFCHIP and remaining[gid] > 0:
            diags.append(make(
                "SF023", f"frame-mode group {gid} produces a tensor with "
                f"{remaining[gid]} consumer(s) but assigns no buffer, is "
                f"not spilled and is not a boundary write -- the data is "
                f"lost", gid=gid, word=6, context=repr(g)))

    # ------------------------------------------------- DRAM conservation
    for gid in sorted(alloc.spilled):
        if reads_of.get(gid, 0) == 0 and 0 <= gid < n:
            diags.append(make(
                "SF043", f"group {gid}'s output is spilled to DRAM but "
                f"no consumer ever reads it back", gid=gid,
                context=repr(groups[gid])))
    model_fm = dram_fm(gg, alloc)
    machine_fm = dram_reads + dram_writes
    if machine_fm != model_fm:
        diags.append(make(
            "SF042", f"stream moves {machine_fm} feature-map bytes "
            f"(r={dram_reads} w={dram_writes}) but the analytic model "
            f"(eq. 8) accounts {model_fm} (drift "
            f"{machine_fm - model_fm:+d})"))
    model_w = sum(g.weight_size for g in groups)
    if weight_reads != model_w:
        diags.append(make(
            "SF042", f"stream fetches {weight_reads} weight bytes but "
            f"constraint (10) requires exactly {model_w} (each layer's "
            f"weights once)"))

    # ------------------------------------------------------- capacity
    declared = list(alloc.buff) + [alloc.side_buff]
    observed = occ + [side_occ]
    names = ["buffer 0", "buffer 1", "buffer 2", "side space"]
    for name, d, o in zip(names, declared, observed):
        if o > d:
            diags.append(make(
                "SF032", f"{name} holds {o} bytes but the allocation "
                f"declares only {d}", severity=capacity_severity))
    sram = sram_report(gg, alloc, hw)
    buff = [max(d, o) for d, o in zip(sram.buff, occ)]
    side_b = max(alloc.side_buff, side_occ)
    total = (sram.row_buff + sram.out_buff + sram.write_buff
             + sum(buff) + side_b)
    if total > hw.sram_budget:
        diags.append(make(
            "SF030", f"SRAM total {total} bytes exceeds the "
            f"{hw.sram_budget}-byte budget (row={sram.row_buff} "
            f"out={sram.out_buff} wr={sram.write_buff} buff={buff} "
            f"side={side_b})", severity=capacity_severity))
    bram = _bram18k_total(sram.row_buff, sram.out_buff, sram.write_buff,
                          buff, side_b, hw)
    if bram > hw.bram18k_total:
        # Advisory only: the optimizer's feasibility contract is byte-level
        # SRAM + frame feasibility; bram18k is reported, not constrained.
        diags.append(make(
            "SF031", f"BRAM18K count {bram} exceeds the "
            f"{hw.bram18k_total} available"))


# ------------------------------------------------------------------ SF024
def _check_journal(gg: GroupedGraph, alloc: Allocation,
                   by_gid: dict[int, GroupInstruction],
                   trace: JournalTrace,
                   diags: list[Diagnostic]) -> None:
    """The plan's allocation record and the stream's buffer assignments
    must both match a fresh journal replay of Algorithm 1 under the
    plan's own policy -- the replay is deterministic, so any divergence
    means the record or the stream was corrupted after allocation."""
    truth = trace.alloc
    for label, got, want in (
            ("alloc_in", alloc.alloc_in, truth.alloc_in),
            ("alloc_out", alloc.alloc_out, truth.alloc_out),
            ("alloc_shortcut", alloc.alloc_shortcut, truth.alloc_shortcut)):
        for gid in sorted(set(got) | set(want)):
            a, b = got.get(gid), want.get(gid)
            if a != b:
                iv = (trace.owner_at(b, gid)
                      if isinstance(b, int) else None)
                diags.append(make(
                    "SF024", f"{label}[{gid}]={a} but the journal replay "
                    f"assigns {b}", gid=gid, word=6,
                    context=(iv.render() if iv is not None else "")))
    for label, got, want in (
            ("spilled", alloc.spilled, truth.spilled),
            ("boundary_writes", alloc.boundary_writes,
             truth.boundary_writes)):
        for gid in sorted(got ^ want):
            diags.append(make(
                "SF024", f"{label} {'records' if gid in got else 'drops'} "
                f"g{gid}, the journal replay "
                f"{'does not' if gid in got else 'does'}", gid=gid))
    if alloc.boundary_reads != truth.boundary_reads:
        delta = {k: (alloc.boundary_reads.get(k), truth.boundary_reads.get(k))
                 for k in set(alloc.boundary_reads) | set(truth.boundary_reads)
                 if alloc.boundary_reads.get(k) != truth.boundary_reads.get(k)}
        diags.append(make(
            "SF024", f"boundary_reads diverge from the journal replay: "
            f"{delta}"))
    for gid, ins in sorted(by_gid.items()):
        if not 0 <= gid < len(gg.groups):
            continue
        for label, attr in (("alloc_in", truth.alloc_in),
                            ("alloc_out", truth.alloc_out),
                            ("alloc_shortcut", truth.alloc_shortcut)):
            want = attr.get(gid, OFFCHIP)
            got = getattr(ins, label)
            if got != want:
                iv = trace.owner_at(want, gid) if want != OFFCHIP else None
                diags.append(make(
                    "SF024", f"instruction {label}={got} but the journal "
                    f"replay assigns {want}", gid=gid, word=6,
                    context=(iv.render() if iv is not None
                             else _instr_context(ins))))


# ------------------------------------------------------------------- entry
def verify_plan(gg: GroupedGraph, alloc: Allocation,
                instructions: list[GroupInstruction], hw: FPGAConfig,
                feasible: bool | None = None,
                with_journal: bool = True) -> list[Diagnostic]:
    """Statically verify one compiled plan; returns all diagnostics.

    ``feasible`` is the plan's own feasibility claim: when the optimizer
    already reports the plan infeasible (no feasible point exists),
    capacity overruns are expected and downgraded to warnings; a plan
    claiming feasibility gets them at error severity.  ``with_journal``
    gates the SF024 journal-replay cross-check (one extra O(groups)
    allocator replay)."""
    diags: list[Diagnostic] = []
    by_gid = _check_stream_shape(gg, instructions, diags)
    _check_wellformed(gg, alloc, by_gid, diags)
    trace: JournalTrace | None = None
    if with_journal and all(g.gid in alloc.policy for g in gg.groups):
        trace = journal_trace(gg, alloc.policy)
        _check_journal(gg, alloc, by_gid, trace, diags)
    capacity_severity = (Severity.WARNING if feasible is False
                         else Severity.ERROR)
    _abstract_machine(gg, alloc, by_gid, hw, trace, diags,
                      capacity_severity)
    return diags


def verify_execution_plan(plan) -> list[Diagnostic]:
    """``verify_plan`` over a ``compiler.ExecutionPlan``."""
    return verify_plan(plan.grouped, plan.alloc, plan.instructions,
                       plan.hw, feasible=plan.candidate.feasible)


def errors_of(diags: list[Diagnostic]) -> list[Diagnostic]:
    return [d for d in diags if d.severity is Severity.ERROR]
