"""Seeded plan-mutation fuzzer: prove the static verifier's coverage.

A verifier that passes every healthy plan proves nothing until it also
*fails* every broken one.  ``mutate_plan`` injects one violation from a
known class into a deep copy of a compiled plan -- clobber a buffer
assignment, swap two live ranges, overflow a bit-field, drop a spill,
forge a shortcut operand -- and records which diagnostic codes the
injection must trigger.  Two gates ride on it:

* **mutation kill** -- for every class that applies to a plan, the
  verifier must emit at least one error-severity diagnostic, including
  one of the class's expected codes (``kill_matrix``);
* **differential** -- every mutant the dynamic ``Simulator`` can detect
  (an exception, or DRAM counters drifting from the original plan's
  reports) must also be caught statically (``simulator_detects`` vs the
  static verdict), so the O(plan) verifier never lags the oracle.

Mutations are seeded and deterministic: the same ``(plan, cls, seed)``
always produces the same mutant, so CI failures replay exactly.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from dataclasses import dataclass

from repro.analysis.diagnostics import Diagnostic, Severity
from repro.analysis.liveness import journal_trace
from repro.analysis.verifier import verify_plan
from repro.core.allocator import Allocation
from repro.core.isa import FIELD_WIDTHS, OFFCHIP, GroupInstruction

#: every violation class the fuzzer knows how to inject, with the
#: diagnostic codes at least one of which must fire on the mutant.
CLASSES: dict[str, tuple[str, ...]] = {
    # reroute a frame group's output into a buffer whose tensor is still
    # live -> the shortcut-clobber class Algorithm 1 exists to prevent
    "clobber_alloc": ("SF020", "SF024", "SF021", "SF025"),
    # swap the alloc_out assignments of two frame groups -> both diverge
    # from the journal and at least one read goes to the wrong place
    "swap_live": ("SF024", "SF020", "SF021", "SF025"),
    # write a field value past its encoding slot width
    "overflow_field": ("SF050",),
    # erase a spill record -> the tensor silently never reaches DRAM
    "drop_spill": ("SF023", "SF024", "SF041", "SF022", "SF042"),
    # invent a shortcut operand on a group with no eltwise add
    "forge_shortcut": ("SF054", "SF016", "SF010"),
}


@dataclass
class Mutant:
    """One injected violation: the mutated plan pieces plus provenance."""
    cls: str
    seed: int
    description: str
    gg: object
    hw: object
    alloc: Allocation
    instructions: list[GroupInstruction]
    expect: tuple[str, ...]

    def verify(self) -> list[Diagnostic]:
        return verify_plan(self.gg, self.alloc, self.instructions,
                           self.hw, feasible=True)

    def statically_killed(self) -> bool:
        """True when the verifier both errors AND names an expected code."""
        diags = self.verify()
        errs = [d for d in diags if d.severity is Severity.ERROR]
        return bool(errs) and any(d.code in self.expect for d in errs)


def _copy_alloc(a: Allocation) -> Allocation:
    return Allocation(
        policy=dict(a.policy), alloc_in=dict(a.alloc_in),
        alloc_out=dict(a.alloc_out),
        alloc_shortcut=dict(a.alloc_shortcut), buff=list(a.buff),
        side_buff=a.side_buff, spilled=set(a.spilled),
        boundary_writes=set(a.boundary_writes),
        boundary_reads=dict(a.boundary_reads))


def _copy_instructions(ins: list[GroupInstruction]) -> list[GroupInstruction]:
    return [dataclasses.replace(i) for i in ins]


def mutate_plan(plan, cls: str, seed: int) -> Mutant | None:
    """Inject one ``cls`` violation into a copy of ``plan``.

    Returns None when the class does not apply (e.g. ``drop_spill`` on a
    plan with no spills) -- callers record the skip, they do not fail."""
    if cls not in CLASSES:
        raise KeyError(f"unknown mutation class {cls!r}; "
                       f"expected one of {sorted(CLASSES)}")
    rng = random.Random(seed)
    gg, hw = plan.grouped, plan.hw
    alloc = _copy_alloc(plan.alloc)
    instructions = _copy_instructions(plan.instructions)
    by_gid = {i.gid: i for i in instructions}

    def built(desc: str) -> Mutant:
        return Mutant(cls=cls, seed=seed, description=desc, gg=gg, hw=hw,
                      alloc=alloc, instructions=instructions,
                      expect=CLASSES[cls])

    if cls == "clobber_alloc":
        # Victims: journal intervals still live strictly after some frame
        # group that owns a different buffer -- rerouting that group's
        # output onto the victim's buffer destroys data a later consumer
        # reads.
        trace = journal_trace(gg, alloc.policy)
        options = []
        for gid, b in sorted(alloc.alloc_out.items()):
            for iv in trace.intervals:
                if iv.buffer != b and iv.owner != gid \
                        and iv.start <= gid < iv.end:
                    options.append((gid, iv))
        if not options:
            return None
        gid, iv = rng.choice(options)
        alloc.alloc_out[gid] = iv.buffer
        by_gid[gid].alloc_out = iv.buffer
        return built(f"rerouted g{gid}.alloc_out -> buf{iv.buffer}, "
                     f"destroying {iv.render()}")

    if cls == "swap_live":
        gids = sorted(gid for gid, b in alloc.alloc_out.items()
                      if gid in by_gid)
        pairs = [(a, b) for i, a in enumerate(gids) for b in gids[i + 1:]
                 if alloc.alloc_out[a] != alloc.alloc_out[b]]
        if not pairs:
            return None
        a, b = rng.choice(pairs)
        alloc.alloc_out[a], alloc.alloc_out[b] = \
            alloc.alloc_out[b], alloc.alloc_out[a]
        by_gid[a].alloc_out, by_gid[b].alloc_out = \
            alloc.alloc_out[a], alloc.alloc_out[b]
        return built(f"swapped alloc_out of g{a} (buf"
                     f"{alloc.alloc_out[b]}) and g{b} "
                     f"(buf{alloc.alloc_out[a]})")

    if cls == "overflow_field":
        ins = rng.choice(instructions)
        name = rng.choice([n for n in FIELD_WIDTHS
                           if FIELD_WIDTHS[n] < 32])
        width = FIELD_WIDTHS[name]
        value = (1 << width) + rng.randrange(1 << width)
        setattr(ins, name, value)
        return built(f"g{ins.gid}.{name} = {value} "
                     f"(past its {width}-bit slot)")

    if cls == "drop_spill":
        if not alloc.spilled:
            return None
        gid = rng.choice(sorted(alloc.spilled))
        alloc.spilled.discard(gid)
        return built(f"dropped spill record of g{gid}: its output now "
                     f"never reaches DRAM")

    if cls == "forge_shortcut":
        options = [i for i in instructions
                   if i.fused_eltwise == 0 and i.src_shortcut == -1
                   and i.gid > 0]
        if not options:
            return None
        ins = rng.choice(options)
        forged = rng.randrange(len(gg.groups))
        ins.src_shortcut = forged
        return built(f"forged g{ins.gid}.src_shortcut = {forged} on a "
                     f"group with no eltwise add")

    raise AssertionError(cls)


def simulator_detects(plan, mutant: Mutant) -> bool:
    """Dynamic-oracle verdict on a mutant: does the dry-mode Simulator
    observe the corruption?  Detection = an exception during the run, a
    dangling DRAM read, or DRAM counters drifting from the *original*
    plan's reports (the analytic model of the unmutated allocation)."""
    from repro.core.simulator import simulate
    try:
        _, c = simulate(mutant.gg, mutant.alloc, mutant.instructions,
                        execute=False)
    except Exception:
        return True
    return (c.fm_total != plan.dram.fm_bytes
            or c.weight_reads != plan.dram.weight_bytes
            or c.dangling_reads > 0)


def kill_matrix(plans: dict[str, object],
                seeds: tuple[int, ...] = (0, 1, 2)) -> list[dict]:
    """Run every mutation class x seed over every plan; one row per
    attempted injection.  Rows: net, cls, seed, applied, killed,
    matched_codes, description."""
    rows = []
    for net, plan in plans.items():
        for cls in CLASSES:
            for seed in seeds:
                m = mutate_plan(plan, cls, seed)
                if m is None:
                    rows.append({"net": net, "cls": cls, "seed": seed,
                                 "applied": False, "killed": None,
                                 "codes": [], "description": "n/a"})
                    continue
                diags = m.verify()
                errs = sorted({d.code for d in diags
                               if d.severity is Severity.ERROR})
                rows.append({
                    "net": net, "cls": cls, "seed": seed, "applied": True,
                    "killed": bool(errs) and any(c in m.expect
                                                 for c in errs),
                    "codes": errs, "description": m.description})
    return rows


# --------------------------------------------------- bound-mutation fuzzer
# Adversarial mutations of ``CutpointEngine.prefix_bound``, the admissible
# lower bound branch-and-bound pruning rests on (core/cutpoint.py).  A
# broken bound does NOT corrupt a plan -- it silently prunes the true
# argmin -- so the plan verifier above cannot see it; instead the
# *differential property layer* (tests/test_branch_bound.py) must kill it:
#
# * ``deflate_bound`` -- the bound claims lower than the prefix-exact
#   value.  Deflation is still admissible (it never prunes the optimum,
#   only prunes less), which is exactly why a bit-identity test can never
#   catch it; the full-depth exactness property does: at
#   ``depth == len(runs)`` the completion is unique, so the bound must
#   EQUAL the candidate's exact primary metric, and any deflation breaks
#   the equality.
# * ``inflate_bound`` -- the bound claims higher than the true completion
#   floor: the production-dangerous direction (prunes sub-spaces that may
#   hold the argmin).  Killed by the admissibility property -- bound key
#   <= every brute-forced completion key -- and by full-depth exactness.
#
# The gate is the same shape as ``kill_matrix``: every (net, class, seed)
# mutant must fail at least one differential probe, 100%.
BOUND_CLASSES: dict[str, str] = {
    "deflate_bound": "bound claims lower than the prefix-exact value",
    "inflate_bound": "bound claims higher than the true completion floor",
}


def mutate_bound(bound_fn, cls: str, seed: int):
    """A broken variant of ``bound_fn`` (a ``prefix_bound`` method).

    Deterministic in ``(cls, seed)``: the same seed always produces the
    same deflation/inflation factor.  The constant +-1 keeps the mutation
    strict even at a zero bound."""
    if cls not in BOUND_CLASSES:
        raise KeyError(f"unknown bound-mutation class {cls!r}; "
                       f"expected one of {sorted(BOUND_CLASSES)}")
    rng = random.Random(seed)
    if cls == "deflate_bound":
        scale = rng.uniform(0.3, 0.9)

        def mutated(cuts, depth, objective):
            return bound_fn(cuts, depth, objective) * scale - 1
    else:
        scale = rng.uniform(1.5, 4.0)

        def mutated(cuts, depth, objective):
            return bound_fn(cuts, depth, objective) * scale + 1
    mutated.cls = cls
    mutated.seed = seed
    mutated.scale = scale
    return mutated


def bound_survives_differential(engine, bound_fn=None, seed: int = 0,
                                probes: int = 6,
                                max_slice: int = 256) -> bool:
    """Run the property layer's two bound checks against ``bound_fn``.

    Returns True iff every probe passes -- the genuine
    ``engine.prefix_bound`` survives (that is
    ``test_branch_bound.test_bound_differential_sound``); every
    :func:`mutate_bound` mutant must NOT.  Probes are seeded and
    deterministic:

    1. **full-depth exactness** -- on a random full tuple, the bound at
       ``depth == len(runs)`` must equal ``evaluate``'s exact primary
       metric for each objective;
    2. **admissibility vs brute force** -- on the deepest prefix of that
       tuple whose completion count fits ``max_slice``, the bound key
       ``(False, lb, 0)`` must not exceed any brute-forced completion's
       objective key.
    """
    from repro.core.cutpoint import _key
    if bound_fn is None:
        bound_fn = engine.prefix_bound
    runs = engine.runs
    nr = len(runs)
    if not nr:
        return True
    dims = [len(r) + 1 for r in runs]
    rng = random.Random(seed ^ 0x5FBD)
    objectives = ("latency", "sram", "dram")
    for _ in range(probes):
        t = tuple(rng.randrange(d) for d in dims)
        m = engine.evaluate(t, memoize=False)
        for obj in objectives:
            if bound_fn(t, nr, obj) != _key(m, obj)[1]:
                return False
        depth, total = nr, 1
        while depth > 1 and total * dims[depth - 1] <= max_slice:
            depth -= 1
            total *= dims[depth]
        if depth == nr:
            continue
        batch = [t[:depth] + s for s in
                 itertools.product(*[range(d) for d in dims[depth:]])]
        scored = engine.score_batch(batch, memoize=False)
        for obj in objectives:
            bk = (False, bound_fn(t, depth, obj), 0)
            if any(bk > _key(c, obj) for c in scored):
                return False
    return True


def bound_kill_matrix(engines: dict[str, object],
                      seeds: tuple[int, ...] = (0, 1, 2),
                      probes: int = 6) -> list[dict]:
    """Every bound-mutation class x seed over every engine; one row per
    injection.  Rows: net, cls, seed, killed, scale."""
    rows = []
    for net, engine in engines.items():
        for cls in BOUND_CLASSES:
            for seed in seeds:
                mutated = mutate_bound(engine.prefix_bound, cls, seed)
                killed = not bound_survives_differential(
                    engine, mutated, seed=seed, probes=probes)
                rows.append({"net": net, "cls": cls, "seed": seed,
                             "killed": killed, "scale": mutated.scale})
    return rows


def render_kill_matrix(rows: list[dict]) -> str:
    lines = ["net                cls             seed killed codes"]
    for r in rows:
        status = ("skip" if not r["applied"]
                  else "KILL" if r["killed"] else "MISS")
        lines.append(f"{r['net']:<18} {r['cls']:<15} {r['seed']:>4} "
                     f"{status:<6} {','.join(r['codes'])}")
    applied = [r for r in rows if r["applied"]]
    killed = sum(r["killed"] for r in applied)
    lines.append(f"-- {killed}/{len(applied)} applied mutants killed "
                 f"({len(rows) - len(applied)} skipped as inapplicable)")
    return "\n".join(lines)
