"""Static verification of compiled ExecutionPlans (see ``verifier``).

Public surface:

* :func:`verify_plan` / :func:`verify_execution_plan` -- run every static
  check over a plan, returning typed :class:`Diagnostic` findings;
* :class:`Diagnostic` / :class:`Severity` / :data:`CODES` /
  :class:`VerificationError` -- the diagnostic vocabulary;
* :func:`journal_trace` -- per-buffer live intervals from the allocator
  journal replay;
* :mod:`repro.analysis.mutate` -- the seeded mutation fuzzer proving the
  verifier's coverage;
* ``python -m repro.analysis`` -- the CLI (verify zoo plans, run the
  mutation-kill gate, write reports).
"""
from repro.analysis.diagnostics import (CODES, Diagnostic, Severity,
                                        VerificationError, render_report)
from repro.analysis.liveness import (BufferInterval, JournalTrace,
                                     journal_trace, render_intervals)
from repro.analysis.mutate import (BOUND_CLASSES, CLASSES, Mutant,
                                   bound_kill_matrix,
                                   bound_survives_differential, kill_matrix,
                                   mutate_bound, mutate_plan,
                                   render_kill_matrix, simulator_detects)
from repro.analysis.verifier import (errors_of, verify_execution_plan,
                                     verify_plan)

__all__ = [
    "CODES", "Diagnostic", "Severity", "VerificationError",
    "render_report", "BufferInterval", "JournalTrace", "journal_trace",
    "render_intervals", "BOUND_CLASSES", "CLASSES", "Mutant",
    "bound_kill_matrix", "bound_survives_differential", "kill_matrix",
    "mutate_bound", "mutate_plan", "render_kill_matrix",
    "simulator_detects", "errors_of", "verify_execution_plan",
    "verify_plan",
]
