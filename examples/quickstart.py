"""Quickstart: compile a CNN with ShortcutFusion and inspect the plan.

    PYTHONPATH=src python examples/quickstart.py [--net efficientnet-b1]

Shows the full pipeline of the paper (Fig. 4): parse/group -> reuse-aware
allocation -> cut-point optimization -> instruction stream -> functional
simulation (numerical check vs the JAX reference + DRAM traffic audit).
"""
import argparse

import numpy as np

from repro.cnn import build_cnn
from repro.cnn.jax_ref import init_params, run_graph
from repro.core.compiler import compile_graph
from repro.core.simulator import simulate

MB = 1 << 20


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="efficientnet-b1")
    ap.add_argument("--size", type=int, default=0)
    args = ap.parse_args()

    g = build_cnn(args.net, args.size or None)
    print(f"graph: {len(g)} nodes, {g.total_macs() / 1e9:.2f} GMACs, "
          f"{g.total_weight_bytes() / MB:.1f} MB weights")

    plan = compile_graph(g)
    print(plan.summary())
    print(f"cut-point search evaluated "
          f"{plan.search.evaluated if plan.search else 0} candidates over "
          f"{len(plan.search.runs) if plan.search else 0} monotone runs")

    modes = [i.mode for i in plan.instructions]
    print(f"policy: {modes.count(0)} row-reuse groups, "
          f"{modes.count(1)} frame-reuse groups")
    print(f"buffers {{0,1,2}}: "
          f"{[round(b / MB, 3) for b in plan.alloc.buff]} MB, "
          f"side {plan.alloc.side_buff / 1024:.1f} KB")

    # dry traffic audit: instruction-stream simulation == analytic model
    _, counters = simulate(plan.grouped, plan.alloc, plan.instructions,
                           execute=False)
    assert counters.fm_total == plan.dram.fm_bytes
    print(f"simulator audit: fm={counters.fm_total / MB:.2f} MB "
          f"(matches eq.8), weights={counters.weight_reads / MB:.1f} MB "
          f"(read exactly once)")

    # numerical check on a reduced-size twin of the same family
    small = build_cnn(args.net, 64)
    splan = compile_graph(small)
    params = init_params(small)
    x = np.random.default_rng(0).standard_normal(
        (1, 64, 64, 3), dtype=np.float32)
    out, _ = simulate(splan.grouped, splan.alloc, splan.instructions,
                      params, x, execute=True)
    ref = run_graph(small, params, x)[len(small.nodes) - 1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print("numerical check vs JAX reference: OK")


if __name__ == "__main__":
    main()
