"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on CPU with the full production stack (pipeline, AdamW, checkpointing,
preemption guard, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The config is a width-reduced smollm (same family/code path as the
assigned arch); loss should fall from ~ln(V)=9.6 to well below 7.
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.launch.train import TrainConfig, train
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    # ~100M params: smollm family, 12 layers, d=640
    cfg = get_config("smollm-360m").replace(
        name="smollm-100m", n_layers=12, d_model=640, n_heads=8,
        n_kv_heads=4, head_dim=80, d_ff=1920, max_seq=args.seq,
        dtype="float32")
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.0f}M params, "
          f"seq {args.seq}, batch {args.batch}")

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab=cfg.vocab, seed=0)
    tc = TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                     ckpt_dir=args.ckpt_dir,
                     opt=AdamWConfig(lr=6e-4, warmup_steps=50,
                                     total_steps=args.steps))
    out = train(cfg, tc, data_cfg=dc)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} in {out['wall_s']:.0f}s "
          f"({len(out['stragglers'])} straggler steps flagged)")
    assert last < first - 1.0, "loss should drop by >1 nat"


if __name__ == "__main__":
    main()
