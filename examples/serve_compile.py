"""Compile-as-a-service demo: the persistent plan cache end to end.

    PYTHONPATH=src python examples/serve_compile.py [--cache-dir DIR]

Starts an in-process :class:`repro.service.CompileService`, then shows
the three request paths:

1. **cold miss** -- full cut-point search, plan committed to the cache;
2. **hit** -- the same request decoded from the cache in milliseconds,
   byte-identical to the cold compile (asserted via ``encode_plan``);
3. **warm-started miss** -- the same net on a *new* hw config: the
   nearest cached plan seeds the branch-and-bound incumbent, the result
   is still the oracle-exact argmin.

Point two runs at the same ``--cache-dir`` to see the hits survive a
process restart.
"""
import argparse
import dataclasses
import tempfile
import time

from repro.cnn import build_cnn
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions
from repro.service import CompileService, encode_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="resnet50")
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--cache-dir", default=None,
                    help="persistent cache root (default: a temp dir)")
    args = ap.parse_args()

    cache_dir = args.cache_dir or tempfile.mkdtemp(prefix="sf-plans-")
    opts = CompileOptions(exhaustive_limit=50_000)
    g = build_cnn(args.net, args.size)

    with CompileService(cache_dir, options=opts) as svc:
        t0 = time.perf_counter()
        cold = svc.compile(g)
        cold_s = time.perf_counter() - t0
        print(f"cold miss:  {cold_s * 1000:8.1f} ms   "
              f"cuts={cold.candidate.cuts}")

        t0 = time.perf_counter()
        ticket = svc.submit(g)
        hit = ticket.result()
        hit_s = time.perf_counter() - t0
        assert ticket.hit
        assert encode_plan(hit) == encode_plan(cold)   # byte-identical
        print(f"cache hit:  {hit_s * 1000:8.1f} ms   "
              f"({cold_s / max(hit_s, 1e-9):.0f}x faster, byte-identical)")

        # the same net on a new hw config: a miss, but warm-started from
        # the plan above
        hw2 = dataclasses.replace(KCU1500, name="kcu1500-halfsram",
                                  sram_budget=KCU1500.sram_budget // 2)
        t0 = time.perf_counter()
        ticket = svc.submit(g, hw2)
        warm = ticket.result()
        warm_s = time.perf_counter() - t0
        assert not ticket.hit
        print(f"warm miss:  {warm_s * 1000:8.1f} ms   "
              f"cuts={warm.candidate.cuts} "
              f"(warm_started={ticket.warm_started}, oracle-exact)")

        print(f"stats: {svc.stats}")
        print(f"cache: {len(svc.cache)} records in {cache_dir}")


if __name__ == "__main__":
    main()
