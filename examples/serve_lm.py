"""Serve a small model with batched requests: prefill + greedy decode
through the production cache machinery (ring caches for local attention,
recurrent states for SSM/RG-LRU).

    PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma-2b]
"""
import argparse

from repro.configs import smoke_config
from repro.launch.serve import ServeConfig, serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(max_seq=args.prompt + args.gen)
    print(f"serving {cfg.name} ({cfg.family}), batch={args.batch}, "
          f"prompt={args.prompt}, gen={args.gen}")
    out = serve(cfg, ServeConfig(batch=args.batch, prompt_len=args.prompt,
                                 gen_len=args.gen))
    print(f"prefill {1e3 * out['prefill_s']:.0f} ms, "
          f"decode {1e3 * out['decode_s']:.0f} ms "
          f"({out['tok_per_s']:.1f} tok/s)")
    for i, row in enumerate(out["tokens"][:2]):
        print(f"request {i}: {row[:16].tolist()} ...")


if __name__ == "__main__":
    main()
