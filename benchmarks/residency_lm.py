"""ShortcutFusion residency planning applied to the LM stacks
(EXPERIMENTS.md §Perf, iteration set 3 -- the paper-representative cell).

For each (arch x shape) the planner chooses per transformer block between
  streaming  (row-reuse analogue): weights + activations round-trip HBM
  resident   (frame-reuse analogue): fused Pallas block, shortcut pinned
             in VMEM, weights streamed exactly once
under the 128 MiB VMEM budget, using (a) the paper's single-cut policy and
(b) the beyond-paper DP.  Reports HBM bytes/step/device and the est. step
time, vs the all-streaming baseline.

The per-(arch x shape) cells are independent -- one ResidencyEngine per
stack, nothing shared -- so ``all_reports(workers=N)`` fans them out over
the same :class:`~repro.core.search_pool.ParallelSearchDriver` pool the
CNN cut-point search uses.

Usage:
    PYTHONPATH=src python benchmarks/residency_lm.py [--workers N]
"""
from __future__ import annotations

import argparse
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeCell
from repro.core.hw import V5E
from repro.core.residency import (LMBlockSpec, ResidencyEngine, plan_cutpoint,
                                  plan_dp, streaming_baseline)
from repro.utils.costmodel import _ffn_flops, _layer_kinds, forward_flops


def make_blocks(cfg: ModelConfig, cell: ShapeCell,
                model_shards: int = 16, batch_shards: int = 16,
                dtype_bytes: int = 2) -> list[LMBlockSpec]:
    """Per-device LMBlockSpecs for one step of this cell."""
    S = 1 if cell.mode == "decode" else cell.seq_len
    B_loc = max(1, cell.global_batch // batch_shards)
    d = cfg.d_model
    stream = B_loc * S * d * dtype_bytes
    param_shards = model_shards * (batch_shards if cfg.param_count()
                                   * dtype_bytes > 40e9 else 1)
    per_layer_params = (cfg.param_count() - cfg.vocab * d) / cfg.n_layers
    w_bytes = int(per_layer_params * dtype_bytes / param_shards)
    kinds = _layer_kinds(cfg)
    layer_flops = forward_flops(cfg, S, S if cell.mode == "decode"
                                else (S + 1) / 2, cell.mode) / len(kinds)
    blocks = []
    for i, kind in enumerate(kinds):
        ff_loc = cfg.d_ff / model_shards if cfg.d_ff else d
        heads_loc = max(1, (cfg.n_heads or 8) / model_shards)
        act = int(B_loc * S * (4 * heads_loc * cfg.hd + 3 * ff_loc + 2 * d)
                  * dtype_bytes)
        kv = 0
        if kind in ("global", "local", "encdec"):
            eff = min(cell.seq_len, cfg.window) if kind == "local" \
                else cell.seq_len
            kv = int(2 * B_loc * eff * max(1, cfg.n_kv_heads
                                           / model_shards) * cfg.hd
                     * dtype_bytes)
        elif kind == "ssm":
            kv = int(B_loc * cfg.ssm_nheads * cfg.ssm_headdim
                     * cfg.ssm_state * 4 / model_shards)
        elif kind == "recurrent":
            kv = int(B_loc * (cfg.lru_width or d) * 4 / model_shards)
        mexp = cfg.n_experts if (cfg.n_experts and kind == "global") else 0
        blocks.append(LMBlockSpec(
            idx=i,
            kind="moe" if mexp else kind,
            weight_bytes=w_bytes,
            stream_bytes=stream,
            act_bytes=act,
            flops=int(B_loc * layer_flops / model_shards),
            state_bytes=kv if cell.mode == "decode" else 0))
    return blocks


def report(arch: str, shape: str) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    blocks = make_blocks(cfg, cell)
    engine = ResidencyEngine(blocks, V5E)        # shared cost tables/sums
    base = streaming_baseline(blocks, V5E)
    cut = plan_cutpoint(blocks, V5E, engine=engine)
    dp = plan_dp(blocks, V5E, engine=engine)
    gb = 1 / (1 << 30)
    return {
        "arch": arch, "shape": shape,
        "streaming_hbm_gb": round(base.hbm_bytes * gb, 3),
        "cutpoint_hbm_gb": round(cut.hbm_bytes * gb, 3),
        "dp_hbm_gb": round(dp.hbm_bytes * gb, 3),
        "streaming_ms": round(1e3 * base.est_seconds, 3),
        "cutpoint_ms": round(1e3 * cut.est_seconds, 3),
        "dp_ms": round(1e3 * dp.est_seconds, 3),
        "cut": cut.cut,
        "resident_blocks": dp.n_resident,
        "vmem_peak_mb": round(dp.vmem_peak / (1 << 20), 1),
        "hbm_reduction_pct": round(
            100 * (1 - dp.hbm_bytes / max(base.hbm_bytes, 1)), 1),
    }


# The paper-representative (arch x shape) cells; residency_throughput.py
# regenerates this table into BENCH_residency.json from the same list.
CASES = [
    ("granite-20b", "decode_32k"), ("granite-20b", "prefill_32k"),
    ("gemma2-27b", "decode_32k"), ("moonshot-v1-16b-a3b", "decode_32k"),
    ("smollm-360m", "decode_32k"), ("mamba2-2.7b", "decode_32k"),
    ("qwen3-moe-235b-a22b", "decode_32k"),
]


def _report_pair(pair: tuple[str, str]) -> dict:
    return report(*pair)


def all_reports(workers: int = 1,
                cases: list[tuple[str, str]] = CASES) -> list[dict]:
    """Plan every (arch, shape) cell, fanning out across ``workers``
    processes (each cell builds its own ResidencyEngine; the cells share
    nothing, so this is the pool's embarrassingly-parallel case)."""
    if workers <= 1 or len(cases) <= 1:
        return [report(*pair) for pair in cases]
    from repro.core.search_pool import ParallelSearchDriver
    with ParallelSearchDriver(workers=min(workers, len(cases))) as driver:
        return driver.map(_report_pair, cases)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=os.cpu_count() or 1,
                    help="worker processes for the per-(arch x shape) "
                         "planning fan-out (default: all cores)")
    args = ap.parse_args()
    print("arch,shape,streaming_hbm,dp_hbm,reduction%,streaming_ms,dp_ms,"
          "resident,vmem_mb")
    for r in all_reports(workers=args.workers):
        print(f"{r['arch']},{r['shape']},{r['streaming_hbm_gb']}GB,"
              f"{r['dp_hbm_gb']}GB,{r['hbm_reduction_pct']}%,"
              f"{r['streaming_ms']}ms,{r['dp_ms']}ms,"
              f"{r['resident_blocks']},{r['vmem_peak_mb']}")


if __name__ == "__main__":
    main()
