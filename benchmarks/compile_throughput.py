"""Compiler-throughput benchmark: incremental engine vs direct evaluator.

For every CNN-zoo network, measures
  * candidate evaluations/sec of the direct oracle (``cutpoint.evaluate``:
    full allocate + whole-graph reports per tuple, the seed inner loop),
  * candidate evaluations/sec of :class:`CutpointEngine` over the same
    product-order enumeration the exhaustive search walks,
  * end-to-end ``compile_graph`` wall time (at ``--workers``, since the
    default 8M ``exhaustive_limit`` makes yolov2's 7.96M-tuple space fully
    enumerable),
plus a **workers sweep**: the same fixed slice of yolov2's partitioned cut
space pushed through the search pool at 1/2/4/8 workers, recording wall
time, evals/sec and speedup (with ``cpu_count`` alongside -- scaling
plateaus at the physical core count).  Everything lands in
``BENCH_compile.json``.  The engine numbers are only meaningful because the
engine is oracle-exact -- equivalence is enforced by
tests/test_cutpoint_engine.py, and serial/parallel search bit-identity by
tests/test_search_pool.py; both are spot-checked here in smoke mode.

Usage:
    PYTHONPATH=src python benchmarks/compile_throughput.py [--smoke] [-o F]

``--smoke`` runs two small networks with short budgets and asserts the
engine/oracle agreement plus serial-vs-parallel search bit-identity
instead of writing the JSON (CI regression gate).
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import pickle
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cnn import build_cnn                                  # noqa: E402
from repro.core.compiler import compile_graph                    # noqa: E402
from repro.core.cutpoint import (CutpointEngine, _key, evaluate,  # noqa: E402
                                 monotone_runs, search, split_blocks)
from repro.core.grouping import group_nodes                      # noqa: E402
from repro.core.hw import KCU1500                                # noqa: E402
from repro.core.search_pool import (ParallelSearchDriver,        # noqa: E402
                                    _run_subspace, partition_space)

ZOO = [("vgg16-conv", 224), ("yolov2", 416), ("yolov3", 416),
       ("resnet50", 224), ("resnet152", 224), ("efficientnet-b1", 256),
       ("retinanet", 512), ("mobilenet-v3", 224)]
SMOKE_ZOO = [("vgg16-conv", 224), ("resnet50", 224)]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]


def _product_tuples(runs):
    return itertools.product(*[range(len(r) + 1) for r in runs])


def _burn(n: int) -> int:
    x = 0
    for i in range(n):
        x += i * i
    return x


def measure_parallel_capacity(workers: int, n: int = 20_000_000) -> float:
    """Effective parallel speedup of this machine for pure-Python work.

    Containers and hypervisors routinely advertise more CPUs than they
    deliver; this runs ``workers`` identical busy loops concurrently and
    reports (total work)/(wall x serial rate).  The workers-sweep speedup
    below should be read against this ceiling, not against the advertised
    ``cpu_count``.
    """
    import multiprocessing as mp
    t0 = time.perf_counter()
    _burn(n)
    serial = time.perf_counter() - t0
    procs = [mp.Process(target=_burn, args=(n,)) for _ in range(workers)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    wall = time.perf_counter() - t0
    return workers * serial / wall


def bench_workers_sweep(name: str, size: int, worker_counts: list[int],
                        n_tasks: int = 16) -> dict:
    """Fixed-work scaling measurement on a detector-scale cut space.

    Partitions the network's cut product exactly as ``search(workers=N)``
    does, takes the first ``n_tasks`` equal-sized sub-spaces (a deep slice
    of yolov2's 7.96M tuples -- large enough to amortize pool startup,
    small enough to sweep four worker counts in minutes), and pushes the
    *same* slice through the pool at each worker count.  Also asserts that
    every configuration merges to the same argmin (determinism is not a
    matter of luck -- tests/test_search_pool.py proves it, this keeps the
    benchmark honest about it).
    """
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(
        runs, target_tasks=max(64, 8 * max(worker_counts)))
    prefixes = prefixes[:n_tasks]
    task_size = 1
    for d in suffix_dims:
        task_size *= d + 1
    tuples = len(prefixes) * task_size
    payload = pickle.dumps((gg, KCU1500), protocol=pickle.HIGHEST_PROTOCOL)

    sweep: dict[str, dict] = {}
    argmins = set()
    base_eps = None
    for w in worker_counts:
        token = ("sweep", name, size, w)
        tasks = [(token, payload, p, suffix_dims, "latency")
                 for p in prefixes]
        t0 = time.perf_counter()
        if w == 1:
            results = [_run_subspace(t) for t in tasks]
        else:
            with ParallelSearchDriver(workers=w) as driver:
                results = driver.map(_run_subspace, tasks)
        wall = time.perf_counter() - t0
        evals = sum(n for _, n in results)
        assert evals == tuples
        best = min((m for m, _ in results),
                   key=lambda m: (_key(m, "latency"), m.cuts))
        argmins.add(best.cuts)
        eps = evals / wall
        if base_eps is None:
            base_eps = eps
        sweep[str(w)] = {"wall_s": round(wall, 2),
                         "evals_per_sec": round(eps, 1),
                         "speedup_vs_1w": round(eps / base_eps, 2)}
        print(f"workers sweep {name}: w={w} {wall:.1f}s "
              f"{eps:.0f} evals/s ({sweep[str(w)]['speedup_vs_1w']}x)")
    assert len(argmins) == 1, "sub-space merge must be worker-independent"
    capacity = measure_parallel_capacity(max(worker_counts))
    print(f"machine parallel capacity at {max(worker_counts)} busy loops: "
          f"{capacity:.2f}x")
    return {
        "network": f"{name}@{size}",
        "tuples": tuples,
        "tasks": len(prefixes),
        "cpu_count": os.cpu_count(),
        "parallel_capacity": round(capacity, 2),
        "note": "fixed slice of the partitioned cut space; speedup "
                "saturates at the machine's measured parallel_capacity "
                "(busy-loop ceiling), not at the advertised cpu_count",
        "workers": sweep,
    }


def bench_network(name: str, size: int, budget_s: float,
                  check_equiv: bool = False,
                  compile_workers: int = 1) -> dict:
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    # direct oracle throughput
    n_direct = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        evaluate(gg, blocks, runs, cuts, KCU1500)
        n_direct += 1
        if time.perf_counter() - t0 > budget_s:
            break
    direct_eps = n_direct / (time.perf_counter() - t0)

    # incremental engine throughput over the same enumeration order
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    n_engine = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        engine.evaluate(cuts, memoize=False)    # as the exhaustive search does
        n_engine += 1
        if n_engine % 256 == 0 and time.perf_counter() - t0 > budget_s:
            break
    engine_eps = n_engine / (time.perf_counter() - t0)

    if check_equiv:
        fresh = CutpointEngine(gg, KCU1500, blocks, runs)
        for cuts in itertools.islice(_product_tuples(runs), 10):
            o = evaluate(gg, blocks, runs, cuts, KCU1500)
            m = fresh.evaluate(cuts)
            for f in METRICS:
                assert getattr(o, f) == getattr(m, f), (name, cuts, f)

    # end-to-end compile (grouping + search + instruction generation)
    graph = build_cnn(name, size)
    t0 = time.perf_counter()
    plan = compile_graph(graph, KCU1500, workers=compile_workers)
    compile_s = time.perf_counter() - t0

    row = {
        "groups": len(gg.groups), "blocks": len(blocks), "runs": len(runs),
        "search_space": space,
        "direct_evals_per_sec": round(direct_eps, 1),
        "engine_evals_per_sec": round(engine_eps, 1),
        "speedup": round(engine_eps / direct_eps, 2),
        "compile_wall_s": round(compile_s, 3),
        "search_evaluations": plan.search.evaluated if plan.search else 0,
    }
    print(f"{name}: space={space} direct={direct_eps:.0f}/s "
          f"engine={engine_eps:.0f}/s speedup={row['speedup']}x "
          f"compile={compile_s:.2f}s")
    return row


def smoke_parallel_gate() -> None:
    """CI gate for the search pool: parallel search must reproduce the
    serial SearchResult exactly (metrics, winning tuple, evaluation
    count) on a real network whose space is actually partitioned."""
    gg = group_nodes(build_cnn("resnet50", 224))
    serial = search(gg, KCU1500)
    parallel = search(gg, KCU1500, workers=2)
    assert serial.best.cuts == parallel.best.cuts
    for f in METRICS:
        assert getattr(serial.best, f) == getattr(parallel.best, f), f
    assert serial.evaluated == parallel.evaluated
    print(f"parallel smoke OK: {parallel.evaluated} evals, "
          f"cuts={parallel.best.cuts}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run: 2 networks, equivalence + parallel "
                         "bit-identity asserted, no JSON written")
    ap.add_argument("--workers", type=int, default=os.cpu_count() or 1,
                    help="worker processes for the end-to-end compiles "
                         "(default: all cores)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="re-measure only the workers sweep and splice it "
                         "into the existing output JSON (the per-network "
                         "table takes ~20 min; the sweep ~5)")
    ap.add_argument("-o", "--output", default="BENCH_compile.json")
    args = ap.parse_args()

    if args.sweep_only:
        payload = json.loads(Path(args.output).read_text())
        payload["workers_sweep"] = bench_workers_sweep(
            "yolov2", 416, worker_counts=[1, 2, 4, 8])
        Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"updated workers_sweep in {args.output}")
        return

    zoo = SMOKE_ZOO if args.smoke else ZOO
    budget = 0.4 if args.smoke else 3.0
    results = {}
    for name, size in zoo:
        results[f"{name}@{size}"] = bench_network(
            name, size, budget, check_equiv=args.smoke,
            compile_workers=1 if args.smoke else args.workers)

    if args.smoke:
        worst = min(r["speedup"] for r in results.values())
        # regression gate: the engine must stay clearly ahead of the direct
        # oracle even on small graphs / loaded CI machines (real margin on
        # an idle machine is 3-20x)
        assert worst > 1.5, f"engine speedup regressed to {worst}x"
        print(f"smoke OK: min speedup {worst}x")
        smoke_parallel_gate()
        return

    sweep = bench_workers_sweep("yolov2", 416, worker_counts=[1, 2, 4, 8])

    payload = {
        "hw": KCU1500.name,
        "note": "evals/sec over product-order cut enumeration; engine is "
                "oracle-exact (tests/test_cutpoint_engine.py) and parallel "
                "search is bit-identical to serial "
                "(tests/test_search_pool.py)",
        "compile_workers": args.workers,
        "networks": results,
        "workers_sweep": sweep,
    }
    Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.output}")


if __name__ == "__main__":
    main()
