"""Compiler-throughput benchmark: incremental engine vs direct evaluator.

For every CNN-zoo network, measures
  * candidate evaluations/sec of the direct oracle (``cutpoint.evaluate``:
    full allocate + whole-graph reports per tuple, the seed inner loop),
  * candidate evaluations/sec of :class:`CutpointEngine` over the same
    product-order enumeration the exhaustive search walks,
  * end-to-end ``compile_graph`` wall time,
and writes ``BENCH_compile.json`` (schema below).  The engine numbers are
only meaningful because the engine is oracle-exact -- equivalence is
enforced by tests/test_cutpoint_engine.py and spot-checked here.

Usage:
    PYTHONPATH=src python benchmarks/compile_throughput.py [--smoke] [-o F]

``--smoke`` runs two small networks with short budgets and asserts the
engine/oracle agreement instead of writing the JSON (CI regression gate).
"""
from __future__ import annotations

import argparse
import itertools
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cnn import build_cnn                                  # noqa: E402
from repro.core.compiler import compile_graph                    # noqa: E402
from repro.core.cutpoint import (CutpointEngine, evaluate,       # noqa: E402
                                 monotone_runs, split_blocks)
from repro.core.grouping import group_nodes                      # noqa: E402
from repro.core.hw import KCU1500                                # noqa: E402

ZOO = [("vgg16-conv", 224), ("yolov2", 416), ("yolov3", 416),
       ("resnet50", 224), ("resnet152", 224), ("efficientnet-b1", 256),
       ("retinanet", 512), ("mobilenet-v3", 224)]
SMOKE_ZOO = [("vgg16-conv", 224), ("resnet50", 224)]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]


def _product_tuples(runs):
    return itertools.product(*[range(len(r) + 1) for r in runs])


def bench_network(name: str, size: int, budget_s: float,
                  check_equiv: bool = False) -> dict:
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    # direct oracle throughput
    n_direct = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        evaluate(gg, blocks, runs, cuts, KCU1500)
        n_direct += 1
        if time.perf_counter() - t0 > budget_s:
            break
    direct_eps = n_direct / (time.perf_counter() - t0)

    # incremental engine throughput over the same enumeration order
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    n_engine = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        engine.evaluate(cuts, memoize=False)    # as the exhaustive search does
        n_engine += 1
        if n_engine % 256 == 0 and time.perf_counter() - t0 > budget_s:
            break
    engine_eps = n_engine / (time.perf_counter() - t0)

    if check_equiv:
        fresh = CutpointEngine(gg, KCU1500, blocks, runs)
        for cuts in itertools.islice(_product_tuples(runs), 10):
            o = evaluate(gg, blocks, runs, cuts, KCU1500)
            m = fresh.evaluate(cuts)
            for f in METRICS:
                assert getattr(o, f) == getattr(m, f), (name, cuts, f)

    # end-to-end compile (grouping + search + instruction generation)
    graph = build_cnn(name, size)
    t0 = time.perf_counter()
    plan = compile_graph(graph, KCU1500)
    compile_s = time.perf_counter() - t0

    row = {
        "groups": len(gg.groups), "blocks": len(blocks), "runs": len(runs),
        "search_space": space,
        "direct_evals_per_sec": round(direct_eps, 1),
        "engine_evals_per_sec": round(engine_eps, 1),
        "speedup": round(engine_eps / direct_eps, 2),
        "compile_wall_s": round(compile_s, 3),
        "search_evaluations": plan.search.evaluated if plan.search else 0,
    }
    print(f"{name}: space={space} direct={direct_eps:.0f}/s "
          f"engine={engine_eps:.0f}/s speedup={row['speedup']}x "
          f"compile={compile_s:.2f}s")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run: 2 networks, equivalence asserted, "
                         "no JSON written")
    ap.add_argument("-o", "--output", default="BENCH_compile.json")
    args = ap.parse_args()

    zoo = SMOKE_ZOO if args.smoke else ZOO
    budget = 0.4 if args.smoke else 3.0
    results = {}
    for name, size in zoo:
        results[f"{name}@{size}"] = bench_network(
            name, size, budget, check_equiv=args.smoke)

    if args.smoke:
        worst = min(r["speedup"] for r in results.values())
        # regression gate: the engine must stay clearly ahead of the direct
        # oracle even on small graphs / loaded CI machines (real margin on
        # an idle machine is 3-20x)
        assert worst > 1.5, f"engine speedup regressed to {worst}x"
        print(f"smoke OK: min speedup {worst}x")
        return

    payload = {
        "hw": KCU1500.name,
        "note": "evals/sec over product-order cut enumeration; engine is "
                "oracle-exact (tests/test_cutpoint_engine.py)",
        "networks": results,
    }
    Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.output}")


if __name__ == "__main__":
    main()
