"""Compiler-throughput benchmark: batched scorer vs engine vs oracle.

For every CNN-zoo network, measures
  * candidate evaluations/sec of the direct oracle (``cutpoint.evaluate``:
    full allocate + whole-graph reports per tuple, the seed inner loop),
  * candidate evaluations/sec of :class:`CutpointEngine` per tuple over
    the same product-order enumeration the exhaustive search walks,
  * candidate evaluations/sec of ``CutpointEngine.score_batch`` (the
    mask-matrix batched scorer the search uses by default),
  * end-to-end ``compile_graph`` wall time (at ``--workers``, since the
    default 8M ``exhaustive_limit`` makes yolov2's 7.96M-tuple space fully
    enumerable),
plus a **batched slice** (the headline): a fixed slice of yolov2's
partitioned cut space scored per-tuple and batched, interleaved
best-of-N per mode so this container's CPU-burst variance mostly cancels,
with the PR 3 per-tuple engine rate as the committed reference point; an
**allocator-replay comparison** (``alloc_replay``): the same slice scored
under the journal Python replay vs the tensorized device replay of
kernels/alloc_scan.py (numpy reference / jax scan / Pallas interpret);
a **fused-pipeline comparison** (``pipeline_slice``): the same slice
searched end-to-end under ``engine="pipeline:lax"`` /
``"pipeline:reference"`` (kernels/search_pipeline.py -- in-kernel
enumeration, alloc-scan replay, cost reduction and hierarchical argmin,
no host candidate stream) vs the journal engine, argmin and evaluation
counts asserted identical; a **workers sweep**: the same kind of slice
pushed through the search
pool at 1/2/4/8 workers; and a **pruning benchmark** (``prune``): the
FULL yolov2 space searched unpruned vs branch-and-bound pruned vs
kill-healed at 2 workers, byte-identity asserted, recording the pruned
fraction, the normalized speedup and the healed search rate.  Everything
lands in ``BENCH_compile.json``.
The numbers are only meaningful because the engine and the batched scorer
are oracle-exact -- equivalence is enforced by
tests/test_cutpoint_engine.py and tests/test_score_batch.py, and
serial/parallel search bit-identity by tests/test_search_pool.py; all are
spot-checked here in smoke mode.

Usage:
    PYTHONPATH=src python benchmarks/compile_throughput.py [--smoke] [-o F]

``--smoke`` (the CI regression gate) runs two small networks with short
budgets, asserts engine/oracle/batched agreement plus serial-vs-parallel
bit-identity, and compares the batched scorer's evals/sec against the
committed floor in BENCH_compile.json -- normalized by the busy-loop
calibration so a slow CI machine doesn't trip it -- failing on >30%
regression.  It writes its measurements to BENCH_smoke.json (uploaded as
a CI artifact) instead of touching the committed JSON.
"""
from __future__ import annotations

import argparse
import itertools
import json
import multiprocessing as _mp
import os
import pickle
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.cnn import build_cnn                                  # noqa: E402
from repro.core.compiler import compile_graph                    # noqa: E402
from repro.core.cutpoint import (DEFAULT_BATCH_SIZE,             # noqa: E402
                                 CutpointEngine, _key, evaluate,
                                 monotone_runs, search, split_blocks)
from repro.core.grouping import group_nodes                      # noqa: E402
from repro.core.hw import KCU1500                                # noqa: E402
from repro.core.options import CompileOptions              # noqa: E402
from repro.core.search_pool import (TASKS_PER_WORKER,            # noqa: E402
                                    ParallelSearchDriver, SearchPreempted,
                                    _run_subspace, partition_space)
from repro.runtime import chaos                                  # noqa: E402
from repro.runtime.fault_tolerance import PreemptionGuard        # noqa: E402

try:                                                             # noqa: E402
    from busyloop import measure_busyloop_rate, measure_parallel_capacity
except ImportError:                                  # pragma: no cover
    from benchmarks.busyloop import (measure_busyloop_rate,
                                     measure_parallel_capacity)

# PR 3's committed per-tuple engine rate on the yolov2 slice (this
# machine, BENCH_compile.json workers_sweep["1"] before the batched
# scorer landed) -- the reference the batched slice's speedup is gated
# against.
PR3_SLICE_EVALS_PER_SEC = 11387.9

ZOO = [("vgg16-conv", 224), ("yolov2", 416), ("yolov3", 416),
       ("resnet50", 224), ("resnet152", 224), ("efficientnet-b1", 256),
       ("retinanet", 512), ("mobilenet-v3", 224)]
SMOKE_ZOO = [("vgg16-conv", 224), ("resnet50", 224)]

METRICS = ["latency_cycles", "dram_total", "dram_fm", "sram_total",
           "bram18k", "feasible"]


def _product_tuples(runs):
    return itertools.product(*[range(len(r) + 1) for r in runs])


def bench_workers_sweep(name: str, size: int, worker_counts: list[int],
                        n_tasks: int = 16) -> dict:
    """Fixed-work scaling measurement on a detector-scale cut space.

    Partitions the network's cut product exactly as ``search(workers=N)``
    does, takes the first ``n_tasks`` equal-sized sub-spaces (a deep slice
    of yolov2's 7.96M tuples -- large enough to amortize pool startup,
    small enough to sweep four worker counts in minutes), and pushes the
    *same* slice through the pool at each worker count.  Also asserts that
    every configuration merges to the same argmin (determinism is not a
    matter of luck -- tests/test_search_pool.py proves it, this keeps the
    benchmark honest about it).
    """
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(
        runs, target_tasks=max(64, 8 * max(worker_counts)))
    prefixes = prefixes[:n_tasks]
    task_size = 1
    for d in suffix_dims:
        task_size *= d + 1
    tuples = len(prefixes) * task_size
    payload = pickle.dumps((gg, KCU1500), protocol=pickle.HIGHEST_PROTOCOL)

    sweep: dict[str, dict] = {}
    argmins = set()
    base_eps = None
    for w in worker_counts:
        token = ("sweep", name, size, w)
        tasks = [(token, payload, p, suffix_dims, "latency",
                  DEFAULT_BATCH_SIZE, "journal", "numpy") for p in prefixes]
        t0 = time.perf_counter()
        if w == 1:
            results = [_run_subspace(t) for t in tasks]
        else:
            with ParallelSearchDriver(workers=w) as driver:
                results = driver.map(_run_subspace, tasks)
        wall = time.perf_counter() - t0
        evals = sum(n for _, n, _p, _e in results)
        assert evals == tuples
        best = min((m for m, _n, _p, _e in results),
                   key=lambda m: (_key(m, "latency"), m.cuts))
        argmins.add(best.cuts)
        eps = evals / wall
        if base_eps is None:
            base_eps = eps
        sweep[str(w)] = {"wall_s": round(wall, 2),
                         "evals_per_sec": round(eps, 1),
                         "speedup_vs_1w": round(eps / base_eps, 2)}
        print(f"workers sweep {name}: w={w} {wall:.1f}s "
              f"{eps:.0f} evals/s ({sweep[str(w)]['speedup_vs_1w']}x)")
    assert len(argmins) == 1, "sub-space merge must be worker-independent"
    capacity = measure_parallel_capacity(max(worker_counts))
    print(f"machine parallel capacity at {max(worker_counts)} busy loops: "
          f"{capacity:.2f}x")
    return {
        "network": f"{name}@{size}",
        "tuples": tuples,
        "tasks": len(prefixes),
        "cpu_count": os.cpu_count(),
        "parallel_capacity": round(capacity, 2),
        "note": "fixed slice of the partitioned cut space; speedup "
                "saturates at the machine's measured parallel_capacity "
                "(busy-loop ceiling), not at the advertised cpu_count",
        "workers": sweep,
    }


def bench_batched_slice(name: str = "yolov2", size: int = 416,
                        n_tasks: int = 8, reps: int = 2) -> dict:
    """Headline measurement: batched vs per-tuple scoring on a fixed
    exhaustive sub-space slice of the detector's cut product.

    Runs the *same* ``_run_subspace`` worker body both ways
    (``batch_size=1`` vs the production default), interleaved
    ``reps`` times with best-of per mode so the container's bursty CPU
    mostly cancels, and asserts both modes merge to the same argmin.
    The recorded speedups are (a) batched vs the per-tuple rate measured
    in this same run and (b) batched vs the PR 3 per-tuple engine rate
    committed in BENCH_compile.json before the batched scorer existed."""
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(runs, target_tasks=64)
    prefixes = prefixes[:n_tasks]
    task_size = 1
    for d in suffix_dims:
        task_size *= d + 1
    tuples = len(prefixes) * task_size
    payload = pickle.dumps((gg, KCU1500), protocol=pickle.HIGHEST_PROTOCOL)

    modes = [("per_tuple", 1), ("batched", DEFAULT_BATCH_SIZE)]
    best_eps = {m: 0.0 for m, _ in modes}
    argmins = set()
    for rep in range(reps):
        for mode, bs in modes:
            token = ("slice", name, size, mode, rep)
            tasks = [(token, payload, p, suffix_dims, "latency", bs,
                      "journal", "numpy") for p in prefixes]
            t0 = time.perf_counter()
            results = [_run_subspace(t) for t in tasks]
            wall = time.perf_counter() - t0
            evals = sum(n for _, n, _p, _e in results)
            assert evals == tuples
            best = min((m for m, _n, _p, _e in results),
                       key=lambda m: (_key(m, "latency"), m.cuts))
            argmins.add(best.cuts)
            eps = evals / wall
            best_eps[mode] = max(best_eps[mode], eps)
            print(f"batched slice {name} rep{rep} {mode}: "
                  f"{wall:.1f}s {eps:.0f} evals/s")
    assert len(argmins) == 1, "batched/per-tuple argmin must agree"
    speedup = best_eps["batched"] / best_eps["per_tuple"]
    vs_pr3 = best_eps["batched"] / PR3_SLICE_EVALS_PER_SEC
    print(f"batched slice: {speedup:.2f}x vs same-run per-tuple, "
          f"{vs_pr3:.2f}x vs PR3 engine ({PR3_SLICE_EVALS_PER_SEC}/s)")
    return {
        "network": f"{name}@{size}",
        "tuples": tuples,
        "tasks": len(prefixes),
        "batch_size": DEFAULT_BATCH_SIZE,
        "reps": reps,
        "per_tuple_evals_per_sec": round(best_eps["per_tuple"], 1),
        "batched_evals_per_sec": round(best_eps["batched"], 1),
        "speedup_vs_per_tuple": round(speedup, 2),
        "pr3_per_tuple_evals_per_sec": PR3_SLICE_EVALS_PER_SEC,
        "speedup_vs_pr3_engine": round(vs_pr3, 2),
        "note": "interleaved best-of per mode on one fixed exhaustive "
                "slice; identical argmin asserted across modes",
    }


def bench_alloc_replay(name: str = "yolov2", size: int = 416,
                       n_tasks: int = 8, reps: int = 2,
                       pallas_batches: int = 4) -> dict:
    """Allocator-replay comparison on the fixed yolov2 slice: the
    journal-based Python replay vs the tensorized device replay
    (kernels/alloc_scan.py) under its numpy-reference, jax.lax.scan and
    Pallas-interpret backends.

    Each mode scores the *same* product-order slice through
    ``CutpointEngine.score_batch`` in production-size batches and must
    produce the same argmin (they are bit-identical by contract --
    tests/test_alloc_scan.py; the assertion keeps the benchmark honest).
    Interleaved best-of per mode, like the batched slice.  The Pallas
    interpret mode runs the kernel body un-compiled, so it is measured on
    a few batches and reported for completeness -- on a real TPU the same
    kernel compiles; off-TPU its rate is a correctness artifact, not a
    speed claim."""
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(runs, target_tasks=64)
    prefixes = prefixes[:n_tasks]
    tuples = [p + s for p in prefixes
              for s in itertools.product(*[range(d + 1)
                                           for d in suffix_dims])]
    chunks = [tuples[i:i + DEFAULT_BATCH_SIZE]
              for i in range(0, len(tuples), DEFAULT_BATCH_SIZE)]

    modes = [("python_journal", "journal", None),
             ("scan_reference", "device", "reference"),
             ("jax_scan", "device", "scan"),
             ("pallas_interpret", "device", "pallas")]
    best_eps = {m: 0.0 for m, _, _ in modes}
    argmins = {}
    for rep in range(reps):
        for mode, replay, alloc_backend in modes:
            engine = CutpointEngine(gg, KCU1500, blocks, runs,
                                    replay=replay,
                                    alloc_backend=alloc_backend)
            use = chunks if mode != "pallas_interpret" \
                else chunks[:pallas_batches]
            best = None
            t0 = time.perf_counter()
            for chunk in use:
                for c in engine.score_batch(chunk, memoize=False):
                    if best is None or (_key(c, "latency")
                                        < _key(best, "latency")):
                        best = c
            wall = time.perf_counter() - t0
            assert engine.evaluations == sum(len(c) for c in use)
            eps = engine.evaluations / wall
            best_eps[mode] = max(best_eps[mode], eps)
            if mode != "pallas_interpret":          # partial slice differs
                argmins.setdefault(mode, best.cuts)
            print(f"alloc replay {name} rep{rep} {mode}: "
                  f"{wall:.1f}s {eps:.0f} evals/s")
    assert len(set(argmins.values())) == 1, \
        "journal/device argmin must agree"
    return {
        "network": f"{name}@{size}",
        "tuples": len(tuples),
        "batch_size": DEFAULT_BATCH_SIZE,
        "reps": reps,
        "evals_per_sec": {m: round(r, 1) for m, r in best_eps.items()},
        "device_vs_journal": round(
            best_eps["scan_reference"] / best_eps["python_journal"], 2),
        "note": "same fixed yolov2 slice as batched_slice, scored via "
                "score_batch under each allocator-replay mode; argmin "
                "asserted identical (bit-identity is the tested "
                "contract); pallas_interpret is un-compiled kernel "
                "emulation measured on a few batches",
    }


def bench_pipeline_slice(name: str = "yolov2", size: int = 416,
                         n_tasks: int = 8, reps: int = 2) -> dict:
    """Fused-pipeline throughput on the fixed yolov2 slice: the same
    sub-spaces as ``batched_slice`` searched under
    ``engine="pipeline:lax"`` (the production fused on-device loop) and
    ``engine="pipeline:reference"`` (its numpy oracle), against the
    journal engine measured in the same run.

    Every mode runs the identical ``_run_subspace`` worker body the
    parallel search dispatches, interleaved best-of per mode; the argmin
    AND the per-task evaluation counts are asserted identical across
    engines (the pipeline scores everything in-kernel, so its count
    equals journal scored+pruned under the unpruned walk used here)."""
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(runs, target_tasks=64)
    prefixes = prefixes[:n_tasks]
    task_size = 1
    for d in suffix_dims:
        task_size *= d + 1
    tuples = len(prefixes) * task_size
    payload = pickle.dumps((gg, KCU1500), protocol=pickle.HIGHEST_PROTOCOL)

    modes = ["journal", "pipeline:reference", "pipeline:lax"]
    best_eps = {m: 0.0 for m in modes}
    argmins = set()
    counts = set()
    for rep in range(reps):
        for mode in modes:
            token = ("pipe", name, size, mode, rep)
            tasks = [(token, payload, p, suffix_dims, "latency",
                      DEFAULT_BATCH_SIZE, mode, "numpy") for p in prefixes]
            t0 = time.perf_counter()
            results = [_run_subspace(t) for t in tasks]
            wall = time.perf_counter() - t0
            evals = sum(n for _, n, _p, _e in results)
            assert evals == tuples, (mode, evals, tuples)
            counts.add(tuple(n for _, n, _p, _e in results))
            best = min((m for m, _n, _p, _e in results),
                       key=lambda m: (_key(m, "latency"), m.cuts))
            argmins.add(best.cuts)
            eps = evals / wall
            best_eps[mode] = max(best_eps[mode], eps)
            print(f"pipeline slice {name} rep{rep} {mode}: "
                  f"{wall:.1f}s {eps:.0f} evals/s")
    assert len(argmins) == 1, "pipeline/journal argmin must agree"
    assert len(counts) == 1, "pipeline/journal eval counts must agree"
    speedup = best_eps["pipeline:lax"] / best_eps["journal"]
    print(f"pipeline slice: lax {speedup:.2f}x vs same-run journal")
    return {
        "network": f"{name}@{size}",
        "tuples": tuples,
        "tasks": len(prefixes),
        "batch_size": DEFAULT_BATCH_SIZE,
        "reps": reps,
        "evals_per_sec": {m: round(r, 1) for m, r in best_eps.items()},
        "lax_speedup_vs_journal": round(speedup, 2),
        "note": "same fixed yolov2 slice as batched_slice, searched "
                "through _run_subspace under each engine; argmin and "
                "per-task evaluation counts asserted identical "
                "(tests/test_search_pipeline.py proves the contract)",
    }


def smoke_pipeline_gate(committed_path: Path | None) -> dict:
    """CI gate for the fused pipeline: on a small fixed yolov2 slice the
    ``pipeline:lax`` engine must (a) merge to the byte-identical argmin
    and evaluation counts as the journal engine, and (b) keep its
    evals/sec within ``max_regression`` of the committed
    ``pipeline_floor``, normalized by the busy-loop calibration (same
    discipline as the batched-scorer gate)."""
    gg = group_nodes(build_cnn("yolov2", 416))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(runs, target_tasks=256)
    prefixes = prefixes[:2]
    payload = pickle.dumps((gg, KCU1500), protocol=pickle.HIGHEST_PROTOCOL)
    rate = measure_busyloop_rate()

    outcomes = {}
    for mode in ("journal", "pipeline:lax"):
        tasks = [(("pipe-smoke", mode), payload, p, suffix_dims,
                  "latency", DEFAULT_BATCH_SIZE, mode, "numpy")
                 for p in prefixes]
        # warm-up pass: triggers the engine build and (for the pipeline)
        # the one jit compile per sub-space shape, so the timed pass
        # measures steady-state throughput -- the thing the floor gates
        # -- not fixed compile latency that busy-loop normalization
        # cannot scale
        [_run_subspace(t) for t in tasks]
        t0 = time.perf_counter()
        results = [_run_subspace(t) for t in tasks]
        wall = time.perf_counter() - t0
        evals = sum(n for _, n, _p, _e in results)
        best = min((m for m, _n, _p, _e in results),
                   key=lambda m: (_key(m, "latency"), m.cuts))
        outcomes[mode] = (best.cuts, evals, evals / wall)
    assert outcomes["journal"][:2] == outcomes["pipeline:lax"][:2], \
        "pipeline argmin/evaluated diverged from journal"
    measured = outcomes["pipeline:lax"][2]
    record: dict = {
        "network": "yolov2@416",
        "tuples": outcomes["journal"][1],
        "busyloop_ops_per_sec": round(rate, 1),
        "journal_evals_per_sec": round(outcomes["journal"][2], 1),
        "pipeline_evals_per_sec": round(measured, 1),
        "bit_identical": True,               # asserted above
    }
    floor = None
    if committed_path is not None and committed_path.exists():
        floor = json.loads(committed_path.read_text()).get("pipeline_floor")
    if not floor:
        print("pipeline gate: no committed pipeline_floor -- "
              "measuring only")
        return record
    speed = rate / floor["busyloop_ops_per_sec"]
    need = floor["pipeline_evals_per_sec"] * speed \
        * (1 - floor["max_regression"])
    record.update({
        "floor_evals_per_sec": floor["pipeline_evals_per_sec"],
        "machine_speed_vs_floor": round(speed, 3),
        "required_evals_per_sec": round(need, 1),
        "passed": measured >= need,
    })
    if record["passed"]:
        print(f"pipeline gate OK: {measured:.0f} evals/s >= {need:.0f} "
              f"required (machine speed {speed:.2f}x vs floor)")
    else:
        record["fail_msg"] = (
            f"pipeline regression gate: measured {measured:.0f} evals/s "
            f"< required {need:.0f} (committed floor "
            f"{floor['pipeline_evals_per_sec']:.0f} x machine speed "
            f"{speed:.2f} x {1 - floor['max_regression']:.2f})")
    return record


def bench_chaos(name: str = "yolov2", size: int = 416,
                n_tasks: int = 24, workers: int = 2,
                max_overhead: float = 0.15) -> dict:
    """Fault-tolerance benchmark + gate on a yolov2 slice (the PR 6
    acceptance scenario at benchmark scale).

    Pushes the *same* fixed slice of yolov2's partitioned cut space
    through the pool four ways -- clean; with an injected worker death
    (seeded chaos harness: the pool heals, the lost task is re-dispatched,
    the run completes); preempted by a latched SIGTERM (clean drain,
    completed tasks journaled, ``SearchPreempted``); and resumed from
    that journal -- asserting every completed run's ``SearchResult`` is
    byte-identical to the clean one (cuts, metrics, ``evaluated``) with
    the recovery events surfaced, and gating the kill run's overhead at
    ``max_overhead`` vs the clean floor (both walls normalized by the
    busy-loop calibration taken next to each run, so a CPU burst between
    runs doesn't fake a regression).  Failures land in the returned
    record (``passed``/``fail_msg``) so the caller can write the
    BENCH_chaos.json artifact *before* raising."""
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    prefixes, suffix_dims = partition_space(runs, target_tasks=256)
    prefixes = prefixes[:n_tasks]
    task_size = 1
    for d in suffix_dims:
        task_size *= d + 1

    def run_slice(tag, injector=None, guard=None, resume_dir=None,
                  expect_preempt=False):
        if injector is not None:
            chaos.install(injector)
        try:
            rate = measure_busyloop_rate()
            t0 = time.perf_counter()
            with ParallelSearchDriver(workers=workers, mp_context="fork",
                                      guard=guard) as d:
                try:
                    res = d.run_subspaces(
                        gg, KCU1500, prefixes, suffix_dims,
                        CompileOptions(resume_dir=resume_dir),
                        blocks=blocks, runs=runs)
                except SearchPreempted:
                    assert expect_preempt, "unexpected preemption"
                    res = None
            wall = time.perf_counter() - t0
        finally:
            if injector is not None:
                chaos.uninstall()
        ev = [] if res is None else [e.kind for e in res.events]
        print(f"chaos {tag}: {wall:.1f}s busyloop={rate:.0f}/s "
              f"events={ev or ('preempted' if res is None else 'none')}")
        return res, wall, rate

    def assert_identical(res, ctx):
        assert res.best.cuts == clean.best.cuts, ctx
        for f in METRICS:
            assert getattr(res.best, f) == getattr(clean.best, f), (ctx, f)
        assert res.evaluated == clean.evaluated, ctx

    clean, clean_wall, clean_rate = run_slice("clean")
    assert not clean.events

    # injected worker death mid-sweep: the task at the slice midpoint is
    # hard-killed on its first attempt; the pool heals and re-dispatches
    doomed = prefixes[n_tasks // 2]
    inj = chaos.ChaosInjector(
        events={("task", doomed): chaos.ChaosEvent("kill")})
    with tempfile.TemporaryDirectory() as td:
        killed, kill_wall, kill_rate = run_slice("worker-kill", injector=inj,
                                                 resume_dir=td)
        assert_identical(killed, "worker-kill")
        kinds = [e.kind for e in killed.events]
        assert "retry" in kinds, kinds

    # SIGTERM drain: the latched guard stops dispatch, in-flight tasks
    # finish and journal, the re-run resumes from the journal
    with tempfile.TemporaryDirectory() as td:
        guard = PreemptionGuard()
        guard.request()                   # as the SIGTERM handler would
        _, preempt_wall, _ = run_slice("sigterm-drain", guard=guard,
                                       resume_dir=td, expect_preempt=True)
        journaled = len(list(Path(td).glob("search_*/task_*.rec")))
        resumed, resume_wall, _ = run_slice("resume", resume_dir=td)
        assert_identical(resumed, "resume")
        n_resume = sum(1 for e in resumed.events if e.kind == "resume")
        assert n_resume == journaled

    # overhead gate: busy-loop-normalized work (wall x concurrent
    # busy-loop rate) of the kill run vs the clean floor
    overhead = (kill_wall * kill_rate) / (clean_wall * clean_rate) - 1
    record = {
        "network": f"{name}@{size}",
        "tasks": n_tasks,
        "tuples": n_tasks * task_size,
        "workers": workers,
        "clean_wall_s": round(clean_wall, 2),
        "kill_wall_s": round(kill_wall, 2),
        "preempt_drain_wall_s": round(preempt_wall, 2),
        "resume_wall_s": round(resume_wall, 2),
        "journaled_at_preempt": journaled,
        "resumed_tasks": n_resume,
        "busyloop_clean": round(clean_rate, 1),
        "busyloop_kill": round(kill_rate, 1),
        "kill_overhead_normalized": round(overhead, 4),
        "max_overhead": max_overhead,
        "bit_identical": True,            # asserted above for every run
        "passed": overhead < max_overhead,
        "note": "same fixed yolov2 slice through the pool clean / with an "
                "injected worker death / SIGTERM-drained+resumed; all "
                "completed runs asserted byte-identical (cuts, metrics, "
                "evaluated); overhead is busy-loop-normalized",
    }
    if record["passed"]:
        print(f"chaos gate OK: kill overhead "
              f"{100 * overhead:.1f}% < {100 * max_overhead:.0f}%")
    else:
        record["fail_msg"] = (
            f"chaos overhead gate: worker-kill run cost "
            f"{100 * overhead:.1f}% over the clean floor "
            f"(limit {100 * max_overhead:.0f}%; clean {clean_wall:.1f}s @ "
            f"{clean_rate:.0f} ops/s vs kill {kill_wall:.1f}s @ "
            f"{kill_rate:.0f} ops/s)")
    return record


def bench_prune(name: str = "yolov2", size: int = 416,
                workers: int = 2) -> dict:
    """Branch-and-bound pruning benchmark on the FULL detector cut space
    (the ISSUE 8 acceptance scenario): yolov2's 7.96M tuples searched
    unpruned and pruned at ``--workers`` worker processes, asserting the
    results byte-identical (cuts, metrics and -- under the default
    ``count_pruned`` -- ``evaluated``), then a third *healed* pruned run
    with an injected worker death mid-space, also byte-identical.  The
    record lands in BENCH_compile.json: ``pruned`` / ``pruned_fraction``
    (how much of the space the admissible bound eliminated before any
    replay), the busy-loop-normalized wall-clock speedup, and the healed
    run's search rate (``healed_evals_per_sec``)."""
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    def run(tag, prune, injector=None):
        if injector is not None:
            chaos.install(injector)
        try:
            rate = measure_busyloop_rate()
            t0 = time.perf_counter()
            with ParallelSearchDriver(workers=workers,
                                      mp_context="fork") as d:
                res = d.search(gg, KCU1500, CompileOptions(prune=prune))
            wall = time.perf_counter() - t0
        finally:
            if injector is not None:
                chaos.uninstall()
        print(f"prune bench {tag}: {wall:.1f}s busyloop={rate:.0f}/s "
              f"pruned={res.pruned}")
        return res, wall, rate

    unp, unp_wall, unp_rate = run("unpruned", False)
    prn, prn_wall, prn_rate = run("pruned", True)
    for f in METRICS:
        assert getattr(prn.best, f) == getattr(unp.best, f), f
    assert prn.best.cuts == unp.best.cuts
    assert prn.evaluated == unp.evaluated      # count_pruned default
    assert unp.pruned == 0 and prn.pruned > 0

    # healed pruned run: hard-kill the worker on the mid-space task's
    # first attempt; the pool heals, re-dispatches, and must still merge
    # to the identical result with pruning active
    prefixes, _sd = partition_space(runs, workers * TASKS_PER_WORKER)
    doomed = prefixes[len(prefixes) // 2]
    inj = chaos.ChaosInjector(
        events={("task", doomed): chaos.ChaosEvent("kill")})
    healed, healed_wall, healed_rate = run("healed", True, injector=inj)
    assert healed.best.cuts == prn.best.cuts
    assert healed.evaluated == prn.evaluated
    assert any(e.kind == "retry" for e in healed.events)

    speedup = (unp_wall * unp_rate) / (prn_wall * prn_rate)
    record = {
        "network": f"{name}@{size}",
        "workers": workers,
        "search_space": space,
        "pruned": prn.pruned,
        "pruned_fraction": round(prn.pruned / space, 4),
        "unpruned_wall_s": round(unp_wall, 2),
        "pruned_wall_s": round(prn_wall, 2),
        "speedup_normalized": round(speedup, 2),
        "busyloop_unpruned": round(unp_rate, 1),
        "busyloop_pruned": round(prn_rate, 1),
        "healed_wall_s": round(healed_wall, 2),
        "healed_evals_per_sec": round(healed.evaluated / healed_wall, 1),
        "healed_pruned": healed.pruned,
        "healed_bit_identical": True,          # asserted above
        "note": "full cut space searched unpruned vs branch-and-bound "
                "pruned (argmin, metrics, evaluated asserted identical); "
                "speedup is busy-loop-normalized; healed run repeats the "
                "pruned search through an injected worker death",
    }
    print(f"prune bench: {100 * record['pruned_fraction']:.1f}% of "
          f"{space} tuples pruned, {speedup:.2f}x normalized speedup, "
          f"healed rate {record['healed_evals_per_sec']:.0f} evals/s")
    return record


def smoke_prune_gate() -> dict:
    """CI gate for branch-and-bound pruning: on resnet50 the pruned
    serial search must (a) return the byte-identical SearchResult of the
    unpruned search, (b) eliminate at least half the cut space (measured
    share on this net is ~0.8), and (c) win on busy-loop-normalized wall
    clock by >=1.3x (measured ~4-5x; the floor leaves room for CI
    weather without letting the bound rot into a no-op)."""
    gg = group_nodes(build_cnn("resnet50", 224))
    rate_u = measure_busyloop_rate()
    t0 = time.perf_counter()
    unp = search(gg, KCU1500, CompileOptions(prune=False))
    unp_wall = time.perf_counter() - t0
    rate_p = measure_busyloop_rate()
    t0 = time.perf_counter()
    prn = search(gg, KCU1500)
    prn_wall = time.perf_counter() - t0
    assert prn.best.cuts == unp.best.cuts
    for f in METRICS:
        assert getattr(prn.best, f) == getattr(unp.best, f), f
    assert prn.evaluated == unp.evaluated
    fraction = prn.pruned / unp.evaluated
    speedup = (unp_wall * rate_u) / (prn_wall * rate_p)
    record = {
        "network": "resnet50@224",
        "pruned_fraction": round(fraction, 4),
        "unpruned_wall_s": round(unp_wall, 3),
        "pruned_wall_s": round(prn_wall, 3),
        "speedup_normalized": round(speedup, 2),
        "min_fraction": 0.5,
        "min_speedup": 1.3,
        "bit_identical": True,                 # asserted above
        "passed": fraction >= 0.5 and speedup >= 1.3,
    }
    if record["passed"]:
        print(f"prune gate OK: {100 * fraction:.1f}% pruned, "
              f"{speedup:.2f}x normalized speedup")
    else:
        record["fail_msg"] = (
            f"prune gate: {100 * fraction:.1f}% pruned (need >=50%) at "
            f"{speedup:.2f}x normalized speedup (need >=1.3x) -- the "
            f"bound stopped eliminating work")
    return record


def bench_network(name: str, size: int, budget_s: float,
                  check_equiv: bool = False,
                  compile_workers: int = 1) -> dict:
    gg = group_nodes(build_cnn(name, size))
    blocks = split_blocks(gg)
    runs = monotone_runs(blocks)
    space = 1
    for r in runs:
        space *= len(r) + 1

    # direct oracle throughput
    n_direct = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        evaluate(gg, blocks, runs, cuts, KCU1500)
        n_direct += 1
        if time.perf_counter() - t0 > budget_s:
            break
    direct_eps = n_direct / (time.perf_counter() - t0)

    # incremental engine throughput over the same enumeration order
    engine = CutpointEngine(gg, KCU1500, blocks, runs)
    n_engine = 0
    t0 = time.perf_counter()
    for cuts in _product_tuples(runs):
        engine.evaluate(cuts, memoize=False)    # as the exhaustive search does
        n_engine += 1
        if n_engine % 256 == 0 and time.perf_counter() - t0 > budget_s:
            break
    engine_eps = n_engine / (time.perf_counter() - t0)

    # batched scorer throughput over the same enumeration order (the
    # production search inner loop since the mask-matrix scorer landed)
    engine_b = CutpointEngine(gg, KCU1500, blocks, runs)
    n_batched = 0
    it = _product_tuples(runs)
    t0 = time.perf_counter()
    while True:
        chunk = list(itertools.islice(it, DEFAULT_BATCH_SIZE))
        if not chunk:
            break
        engine_b.score_batch(chunk, memoize=False)
        n_batched += len(chunk)
        if time.perf_counter() - t0 > budget_s:
            break
    batched_eps = n_batched / (time.perf_counter() - t0)

    if check_equiv:
        fresh = CutpointEngine(gg, KCU1500, blocks, runs)
        fresh_b = CutpointEngine(gg, KCU1500, blocks, runs)
        fresh_d = CutpointEngine(gg, KCU1500, blocks, runs,
                                 engine="device")
        sample = list(itertools.islice(_product_tuples(runs), 10))
        for cuts, m_b, m_d in zip(sample,
                                  fresh_b.score_batch(sample,
                                                      memoize=False),
                                  fresh_d.score_batch(sample,
                                                      memoize=False)):
            o = evaluate(gg, blocks, runs, cuts, KCU1500)
            m = fresh.evaluate(cuts)
            for f in METRICS:
                assert getattr(o, f) == getattr(m, f), (name, cuts, f)
                assert getattr(o, f) == getattr(m_b, f), (name, cuts, f)
                assert getattr(o, f) == getattr(m_d, f), (name, cuts, f)

    # end-to-end compile (grouping + search + instruction generation)
    graph = build_cnn(name, size)
    t0 = time.perf_counter()
    plan = compile_graph(graph, KCU1500,
                         CompileOptions(workers=compile_workers))
    compile_s = time.perf_counter() - t0

    row = {
        "groups": len(gg.groups), "blocks": len(blocks), "runs": len(runs),
        "search_space": space,
        "direct_evals_per_sec": round(direct_eps, 1),
        "engine_evals_per_sec": round(engine_eps, 1),
        "batched_evals_per_sec": round(batched_eps, 1),
        "speedup": round(engine_eps / direct_eps, 2),
        "batched_speedup_vs_engine": round(batched_eps / engine_eps, 2),
        "compile_wall_s": round(compile_s, 3),
        "search_evaluations": plan.search.evaluated if plan.search else 0,
    }
    print(f"{name}: space={space} direct={direct_eps:.0f}/s "
          f"engine={engine_eps:.0f}/s batched={batched_eps:.0f}/s "
          f"speedup={row['speedup']}x compile={compile_s:.2f}s")
    return row


def smoke_batched_gate(results: dict, committed_path: Path) -> dict:
    """Benchmark-regression gate: the batched scorer's measured evals/sec
    must stay within ``max_regression`` of the committed floor, after
    normalizing by the busy-loop calibration ratio (so the gate compares
    scorer efficiency, not machine speed).  Returns the gate record that
    lands in BENCH_smoke.json; a failure is reported via
    ``record["passed"]``/``record["fail_msg"]`` and raised by the caller
    only *after* the artifact is written (the diagnostic JSON must
    survive the exact failure it exists to explain)."""
    rate = measure_busyloop_rate()
    floor = None
    if committed_path.exists():
        floor = json.loads(committed_path.read_text()).get("smoke_floor")
    record: dict = {
        "busyloop_ops_per_sec": round(rate, 1),
        "measured": {n: r["batched_evals_per_sec"]
                     for n, r in results.items()},
    }
    if not floor:
        print("smoke gate: no committed smoke_floor -- measuring only")
        return record
    net = floor["network"]
    if net not in results:
        print(f"smoke gate: committed floor network {net!r} not among the "
              f"smoke networks -- measuring only (keep SMOKE_ZOO and the "
              f"committed floor in sync)")
        record["floor_network_missing"] = net
        return record
    measured = results[net]["batched_evals_per_sec"]
    speed = rate / floor["busyloop_ops_per_sec"]
    need = floor["batched_evals_per_sec"] * speed * (1 - floor["max_regression"])
    record.update({
        "floor_network": net,
        "floor_evals_per_sec": floor["batched_evals_per_sec"],
        "machine_speed_vs_floor": round(speed, 3),
        "required_evals_per_sec": round(need, 1),
        "passed": measured >= need,
    })
    if measured >= need:
        print(f"batched gate OK: {net} {measured:.0f} evals/s >= "
              f"{need:.0f} required (machine speed {speed:.2f}x vs floor)")
    else:
        record["fail_msg"] = (
            f"batched-scorer regression gate: {net} measured "
            f"{measured:.0f} evals/s < required {need:.0f} (committed "
            f"floor {floor['batched_evals_per_sec']:.0f} x machine speed "
            f"{speed:.2f} x {1 - floor['max_regression']:.2f})")
    return record


def smoke_parallel_gate() -> None:
    """CI gate for the search pool: parallel search must reproduce the
    serial SearchResult exactly (metrics, winning tuple, evaluation
    count) on a real network whose space is actually partitioned."""
    gg = group_nodes(build_cnn("resnet50", 224))
    serial = search(gg, KCU1500)
    parallel = search(gg, KCU1500, CompileOptions(workers=2))
    assert serial.best.cuts == parallel.best.cuts
    for f in METRICS:
        assert getattr(serial.best, f) == getattr(parallel.best, f), f
    assert serial.evaluated == parallel.evaluated
    print(f"parallel smoke OK: {parallel.evaluated} evals, "
          f"cuts={parallel.best.cuts}")


def smoke_verify_gate() -> dict:
    """CI gate for the static verifier's compile-time cost: the one
    ``verify_execution_plan`` pass that ``verify="warn"`` appends to
    ``compile_graph`` must cost <5% of the compile wall itself.  The
    verify pass is timed directly on the compiled plan (best of 5)
    against the best-of-3 compile wall -- differencing two full compile
    runs was tried first and is too noisy: compile-to-compile wall
    variance on a shared CI core exceeds the ~0.5% true cost, so the
    gate flaked on machine weather rather than on regressions.  The
    busy-loop rate is recorded in the artifact for cross-run
    comparability."""
    from repro.analysis import verify_execution_plan

    g = build_cnn("resnet50", 224)
    rate = measure_busyloop_rate()

    plan = None
    compile_walls = []
    for _ in range(3):
        t0 = time.perf_counter()
        plan = compile_graph(g, options=CompileOptions(exhaustive_limit=50_000))
        compile_walls.append(time.perf_counter() - t0)
    verify_walls = []
    for _ in range(5):
        t0 = time.perf_counter()
        diags = verify_execution_plan(plan)
        verify_walls.append(time.perf_counter() - t0)
        assert not [d for d in diags if d.severity.value == "error"]
    wall_compile, wall_verify = min(compile_walls), min(verify_walls)
    overhead = wall_verify / wall_compile
    record = {
        "network": "resnet50@224",
        "busyloop_ops_per_sec": round(rate, 1),
        "wall_compile_s": round(wall_compile, 3),
        "wall_verify_s": round(wall_verify, 4),
        "normalized_overhead": round(overhead, 4),
        "max_overhead": 0.05,
        "passed": overhead < 0.05,
    }
    if record["passed"]:
        print(f"verify gate OK: warn-mode verify pass costs "
              f"{100 * overhead:.2f}% of the compile wall (< 5%)")
    else:
        record["fail_msg"] = (
            f"verify overhead gate: the verify pass costs "
            f"{100 * overhead:.2f}% of the compile wall (limit 5%); "
            f"compile {wall_compile:.3f}s, verify {wall_verify:.4f}s")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short CI run: 2 networks, equivalence + parallel "
                         "bit-identity asserted, no JSON written")
    ap.add_argument("--workers", type=int, default=os.cpu_count() or 1,
                    help="worker processes for the end-to-end compiles "
                         "(default: all cores)")
    ap.add_argument("--sweep-only", action="store_true",
                    help="re-measure only the workers sweep and splice it "
                         "into the existing output JSON (the per-network "
                         "table takes ~20 min; the sweep ~5)")
    ap.add_argument("--alloc-only", action="store_true",
                    help="re-measure only the allocator-replay comparison "
                         "and splice it into the existing output JSON")
    ap.add_argument("--pipeline-only", action="store_true",
                    help="re-measure only the fused-pipeline slice and "
                         "its smoke floor and splice them into the "
                         "existing output JSON")
    ap.add_argument("--prune-only", action="store_true",
                    help="re-measure only the branch-and-bound pruning "
                         "benchmark (full yolov2 space, pruned vs unpruned "
                         "vs kill-healed at 2 workers) and splice it into "
                         "the existing output JSON")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-tolerance benchmark+gate on the yolov2 "
                         "slice (clean / worker-kill / SIGTERM-drain+"
                         "resume, bit-identity asserted, <15%% normalized "
                         "overhead); writes BENCH_chaos.json and runs "
                         "INSTEAD of the throughput benches (combine with "
                         "--smoke for the CI-sized slice)")
    ap.add_argument("-o", "--output", default="BENCH_compile.json")
    args = ap.parse_args()

    if args.chaos:
        if "fork" not in _mp.get_all_start_methods():
            print("chaos bench requires the fork start method (workers "
                  "must inherit the parent-installed injector); skipping")
            return
        record = bench_chaos(n_tasks=12 if args.smoke else 24)
        out = Path("BENCH_chaos.json")
        out.write_text(json.dumps(record, indent=2) + "\n")
        print(f"wrote {out}")
        # raised only now, after the diagnostic artifact is on disk
        assert record["passed"], record["fail_msg"]
        return

    if args.sweep_only:
        payload = json.loads(Path(args.output).read_text())
        payload["workers_sweep"] = bench_workers_sweep(
            "yolov2", 416, worker_counts=[1, 2, 4, 8])
        Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"updated workers_sweep in {args.output}")
        return

    if args.alloc_only:
        payload = json.loads(Path(args.output).read_text())
        payload["alloc_replay"] = bench_alloc_replay("yolov2", 416)
        Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"updated alloc_replay in {args.output}")
        return

    if args.pipeline_only:
        payload = json.loads(Path(args.output).read_text())
        payload["pipeline_slice"] = bench_pipeline_slice("yolov2", 416)
        gate = smoke_pipeline_gate(None)              # measure, no gate
        payload["pipeline_floor"] = {
            "network": gate["network"],
            "pipeline_evals_per_sec": gate["pipeline_evals_per_sec"],
            "busyloop_ops_per_sec": gate["busyloop_ops_per_sec"],
            "max_regression": 0.30,
        }
        Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"updated pipeline_slice + pipeline_floor in {args.output}")
        return

    if args.prune_only:
        if "fork" not in _mp.get_all_start_methods():
            print("prune bench requires the fork start method (the healed "
                  "run's injector must reach workers); skipping")
            return
        payload = json.loads(Path(args.output).read_text())
        payload["prune"] = bench_prune("yolov2", 416)
        Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"updated prune in {args.output}")
        return

    zoo = SMOKE_ZOO if args.smoke else ZOO
    budget = 0.4 if args.smoke else 3.0
    results = {}
    for name, size in zoo:
        results[f"{name}@{size}"] = bench_network(
            name, size, budget, check_equiv=args.smoke,
            compile_workers=1 if args.smoke else args.workers)

    if args.smoke:
        worst = min(r["speedup"] for r in results.values())
        # regression gate: the engine must stay clearly ahead of the direct
        # oracle even on small graphs / loaded CI machines (real margin on
        # an idle machine is 3-20x)
        assert worst > 1.5, f"engine speedup regressed to {worst}x"
        print(f"smoke OK: min speedup {worst}x")
        committed = Path(__file__).resolve().parent.parent / args.output
        gate = smoke_batched_gate(results, committed)
        smoke_parallel_gate()
        verify_gate = smoke_verify_gate()
        prune_gate = smoke_prune_gate()
        pipeline_gate = smoke_pipeline_gate(committed)
        smoke_out = Path("BENCH_smoke.json")
        smoke_out.write_text(json.dumps(
            {"networks": results, "batched_gate": gate,
             "verify_gate": verify_gate, "prune_gate": prune_gate,
             "pipeline_gate": pipeline_gate},
            indent=2) + "\n")
        print(f"wrote {smoke_out} (CI artifact; committed JSON untouched)")
        # raised only now, after the diagnostic artifacts are on disk
        assert gate.get("passed", True), gate["fail_msg"]
        assert verify_gate["passed"], verify_gate["fail_msg"]
        assert prune_gate["passed"], prune_gate["fail_msg"]
        assert pipeline_gate.get("passed", True), pipeline_gate["fail_msg"]
        return

    sweep = bench_workers_sweep("yolov2", 416, worker_counts=[1, 2, 4, 8])
    batched_slice = bench_batched_slice("yolov2", 416)
    alloc_replay = bench_alloc_replay("yolov2", 416)
    pipeline_slice = bench_pipeline_slice("yolov2", 416)
    pipe_gate = smoke_pipeline_gate(None)              # measure the floor
    pipeline_floor = {
        "network": pipe_gate["network"],
        "pipeline_evals_per_sec": pipe_gate["pipeline_evals_per_sec"],
        "busyloop_ops_per_sec": pipe_gate["busyloop_ops_per_sec"],
        "max_regression": 0.30,
    }
    prune = bench_prune("yolov2", 416) \
        if "fork" in _mp.get_all_start_methods() else None

    # the floor the CI smoke gate regresses against: the batched scorer's
    # rate on SMOKE_ZOO[1] (resnet50 -- the larger smoke network, whose
    # measurement window is the least noisy), next to this machine's
    # busy-loop calibration
    floor_net = f"{SMOKE_ZOO[1][0]}@{SMOKE_ZOO[1][1]}"
    smoke_floor = {
        "network": floor_net,
        "batched_evals_per_sec": results[floor_net]["batched_evals_per_sec"],
        "busyloop_ops_per_sec": round(measure_busyloop_rate(), 1),
        "max_regression": 0.30,
    }

    payload = {
        "hw": KCU1500.name,
        "note": "evals/sec over product-order cut enumeration; engine and "
                "batched scorer are oracle-exact "
                "(tests/test_cutpoint_engine.py, tests/test_score_batch.py) "
                "and parallel search is bit-identical to serial "
                "(tests/test_search_pool.py); per-network rows are "
                "single-shot and noisy on bursty container CPU -- "
                "batched_slice (interleaved best-of) is the robust "
                "batched-vs-per-tuple comparison",
        "compile_workers": args.workers,
        "networks": results,
        "batched_slice": batched_slice,
        "alloc_replay": alloc_replay,
        "pipeline_slice": pipeline_slice,
        "prune": prune,
        "smoke_floor": smoke_floor,
        "pipeline_floor": pipeline_floor,
        "workers_sweep": sweep,
    }
    Path(args.output).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.output}")


if __name__ == "__main__":
    main()
