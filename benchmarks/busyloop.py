"""Shared busy-loop machine calibration for benchmark regression gates.

Both CI benchmark gates (compile_throughput.py --smoke and
residency_throughput.py --smoke) compare a freshly measured rate against
a floor committed in the BENCH_*.json files.  Raw rates would gate on
machine speed, not code efficiency, so each committed floor is stored
next to the committing machine's busy-loop rate and the gate normalizes
by the ratio of the gating machine's rate to it -- measured right next
to the benchmark, with best-of-two runs because containers deliver
bursty CPU.
"""
from __future__ import annotations

import time


def _burn(n: int) -> int:
    x = 0
    for i in range(n):
        x += i * i
    return x


def measure_busyloop_rate(n: int = 10_000_000) -> float:
    """Single-core pure-Python ops/sec of ``_burn`` (best of two)."""
    best = 0.0
    for _ in range(2):
        t0 = time.perf_counter()
        _burn(n)
        best = max(best, n / (time.perf_counter() - t0))
    return best


def measure_parallel_capacity(workers: int, n: int = 20_000_000) -> float:
    """Effective parallel speedup of this machine for pure-Python work.

    Containers and hypervisors routinely advertise more CPUs than they
    deliver; this runs ``workers`` identical busy loops concurrently and
    reports (total work)/(wall x serial rate).  Parallel-benchmark
    speedups should be read against this ceiling, not the advertised
    ``cpu_count``.
    """
    import multiprocessing as mp
    t0 = time.perf_counter()
    _burn(n)
    serial = time.perf_counter() - t0
    procs = [mp.Process(target=_burn, args=(n,)) for _ in range(workers)]
    t0 = time.perf_counter()
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    wall = time.perf_counter() - t0
    return workers * serial / wall
