"""Benchmark harness: one function per paper table (see paper_tables.py)
plus LM-framework micro-benchmarks.  Prints ``table,network,metric,ours,
paper`` CSV rows and a compiler-throughput line.
"""
from __future__ import annotations

import time


def run_paper_tables() -> None:
    from benchmarks.paper_tables import ALL_TABLES
    print("table,network,metric,ours,paper")
    for fn in ALL_TABLES:
        t0 = time.time()
        for row in fn():
            print(row.csv())
        print(f"# {fn.__name__}: {time.time() - t0:.1f}s")


def run_lm_micro() -> None:
    """Micro-benchmarks of the LM substrate on CPU (smoke-size): step
    latency for train/prefill/decode per family."""
    import jax
    import numpy as np

    from repro.configs import smoke_config
    from repro.models.model import build_model

    print("bench,arch,us_per_call,derived")
    for arch in ["smollm-360m", "gemma2-2b", "qwen3-moe-235b-a22b",
                 "mamba2-2.7b", "recurrentgemma-2b"]:
        cfg = smoke_config(arch).replace(max_seq=64)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (2, 64), 0,
                                    cfg.vocab)
        batch = {"tokens": tokens, "labels": tokens}
        if cfg.family == "vlm":
            batch["patches"] = np.zeros((2, cfg.vision_seq, cfg.d_model),
                                        np.float32)
        loss_fn = jax.jit(model.loss)
        loss_fn(params, batch)[0].block_until_ready()
        t0 = time.time()
        n = 5
        for _ in range(n):
            loss_fn(params, batch)[0].block_until_ready()
        dt = (time.time() - t0) / n
        print(f"train_loss,{arch},{1e6 * dt:.0f},"
              f"tok_per_s={2 * 64 / dt:.0f}")


def run_kernel_micro() -> None:
    """Interpret-mode kernel calls (correctness-path timing only; TPU
    numbers come from the roofline analysis)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ref import fused_block_ref

    print("bench,kernel,us_per_call,derived")
    m, d, f = 512, 256, 1024
    x = jax.random.normal(jax.random.key(0), (m, d), jnp.float32)
    scale = jnp.zeros((d,))
    wg = jax.random.normal(jax.random.key(1), (d, f)) * d ** -0.5
    wu = jax.random.normal(jax.random.key(2), (d, f)) * d ** -0.5
    wd = jax.random.normal(jax.random.key(3), (f, d)) * f ** -0.5
    ref = jax.jit(lambda *a: fused_block_ref(*a))
    ref(x, scale, wg, wu, wd).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        ref(x, scale, wg, wu, wd).block_until_ready()
    dt = (time.time() - t0) / 10
    flops = 3 * 2 * m * d * f
    print(f"fused_block_ref,{m}x{d}x{f},{1e6 * dt:.0f},"
          f"gflops={flops / dt / 1e9:.1f}")


def main() -> None:
    run_paper_tables()
    run_lm_micro()
    run_kernel_micro()


if __name__ == "__main__":
    main()
