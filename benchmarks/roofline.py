"""Assemble the roofline table (EXPERIMENTS.md §Roofline) from the
dry-run JSON artifacts in experiments/dryrun/."""
from __future__ import annotations

import json
from pathlib import Path


def load(out_dir="experiments/dryrun", mesh="pod1") -> list[dict]:
    rows = []
    for f in sorted(Path(out_dir).glob(f"*_{mesh}.json")):
        r = json.loads(f.read_text())
        if r.get("ok"):
            rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{1e3 * x:.1f}ms"
    return f"{1e6 * x:.0f}us"


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | plan | compute | memory | collective | "
           "dominant | useful | MFU-bound | args/dev | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    lines = [hdr]
    for r in rows:
        rf = r["roofline"]
        uf = rf.get("useful_flops_frac") or 0.0
        mfu = rf.get("mfu_bound") or 0.0
        gib = r["total_arg_bytes_per_device"] / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('plan', '?')} | "
            f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
            f"{fmt_s(rf['collective_s'])} | **{rf['dominant']}** | "
            f"{uf:.2f} | {100 * mfu:.1f}% | {gib:.2f} GiB | "
            f"{'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    rows = load(args.dir, args.mesh)
    print(markdown_table(rows))
    print(f"\n{len(rows)} cells")
    # summary of bottleneck distribution
    from collections import Counter
    c = Counter(r["roofline"]["dominant"] for r in rows)
    print("bottlenecks:", dict(c))


if __name__ == "__main__":
    main()
