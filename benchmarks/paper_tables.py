"""Reproduction of the paper's tables/figures from the compiler.

One function per table; each returns rows of (name, value, paper_value)
and run.py prints them as CSV.  Paper values from TCSI'22 Tables II-VII,
Figs 16/17.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache

from repro.cnn import build_cnn
from repro.core.compiler import all_row_policy, compile_graph
from repro.core.cutpoint import sweep_single_cut
from repro.core.grouping import group_nodes
from repro.core.hw import KCU1500
from repro.core.options import CompileOptions

MB = 1 << 20


@lru_cache(maxsize=None)
def _plan(name: str, size: int, objective: str = "latency"):
    """Memoized compile: several tables hit the same (network, objective)
    pair, and plans are immutable once built.  Compiles with all cores --
    yolov2's space is fully enumerable at the 8M exhaustive_limit and the
    parallel result is bit-identical to serial (tests/test_search_pool.py),
    so the tables are unaffected by the worker count."""
    return compile_graph(build_cnn(name, size), KCU1500,
                         CompileOptions(objective=objective,
                                        workers=os.cpu_count() or 1))


@dataclass
class Row:
    table: str
    network: str
    metric: str
    ours: float
    paper: float | None = None

    def csv(self) -> str:
        p = "" if self.paper is None else f"{self.paper}"
        return f"{self.table},{self.network},{self.metric},{self.ours},{p}"


def table2_resnet152() -> list[Row]:
    """Table II: ResNet152 @224, 16-bit, vs ShortcutMining [8]."""
    g = build_cnn("resnet152", 224)
    for n in g.nodes:                      # 16-bit precision per Table II
        n.qa = n.qw = 2
    plan = compile_graph(g, KCU1500)
    return [
        Row("tableII", "resnet152", "offchip_fm_mb",
            round(plan.dram.fm_bytes / MB, 2), 11.97),
        Row("tableII", "resnet152", "weights_mb",
            round(plan.dram.weight_bytes / MB, 1), 112.6),
        Row("tableII", "resnet152", "latency_ms",
            round(plan.latency_ms, 2), 39.27),
        Row("tableII", "resnet152", "shortcutmining_fm_mb",
            62.93, 62.93),
    ]


def table3_min_buffers() -> list[Row]:
    """Table III: minimum buffer size satisfying constraint (10)."""
    cases = [("yolov2", 416, 0.762), ("vgg16-conv", 224, 0.712),
             ("yolov3", 416, 1.682), ("retinanet", 512, 2.392),
             ("resnet50", 224, 1.039), ("resnet152", 224, 1.039),
             ("efficientnet-b1", 256, 0.43)]
    rows = []
    for name, size, paper in cases:
        plan = _plan(name, size, objective="sram")
        rows.append(Row("tableIII", name, "min_buffer_mb",
                        round(plan.sram.sram_total / MB, 3), paper))
    return rows


def table4_vgg() -> list[Row]:
    """Table IV: VGG-CONV buffer size / DRAM access vs prior work."""
    plan = _plan("vgg16-conv", 224, objective="sram")
    return [
        Row("tableIV", "vgg16-conv", "sram_mb",
            round(plan.sram.sram_total / MB, 3), 0.712),
        Row("tableIV", "vgg16-conv", "dram_mb",
            round(plan.dram.total / MB, 1), 42.8),
        Row("tableIV", "vgg16-conv", "smartshuttle_dram_mb", 58.1, 58.1),
    ]


def table5_cnn_performance() -> list[Row]:
    """Table V: per-CNN latency / GOPS / MAC eff / off-chip reduction."""
    cases = [
        ("resnet50", 256, dict(latency_ms=11.69, gops=1006, mac_eff=61.4,
                               fm_mb=0.19, reduction=60.62)),
        ("resnet152", 256, dict(latency_ms=26.78, gops=1163, mac_eff=71.0,
                                fm_mb=0.19, reduction=56.7)),
        ("yolov2", 416, dict(latency_ms=14.73, gops=1166, mac_eff=71.2,
                             fm_mb=0.66, reduction=70.31)),
        ("yolov3", 416, dict(latency_ms=57.57, gops=1142, mac_eff=69.7,
                             fm_mb=90.6, reduction=60.34)),
        ("retinanet", 512, dict(latency_ms=93.16, gops=1097, mac_eff=67.0,
                                fm_mb=136.4, reduction=47.81)),
        ("efficientnet-b1", 256, dict(latency_ms=4.69, gops=317.1,
                                      mac_eff=19.37, fm_mb=0.19,
                                      reduction=84.81)),
    ]
    rows = []
    for name, size, paper in cases:
        plan = _plan(name, size)
        rows += [
            Row("tableV", name, "latency_ms", round(plan.latency_ms, 2),
                paper["latency_ms"]),
            Row("tableV", name, "gops", round(plan.gops, 0), paper["gops"]),
            Row("tableV", name, "mac_eff_pct",
                round(100 * plan.mac_efficiency, 1), paper["mac_eff"]),
            Row("tableV", name, "offchip_fm_mb",
                round(plan.dram.fm_bytes / MB, 2), paper["fm_mb"]),
            Row("tableV", name, "offchip_reduction_pct",
                round(100 * plan.offchip_reduction, 2), paper["reduction"]),
        ]
    return rows


def table7_efficientnet_scaling() -> list[Row]:
    """Table VII: EfficientNet-B1 at 256/512/768 input."""
    paper = {256: dict(fm_mb=0.19, total_mb=60.7, red=84.81),
             512: dict(fm_mb=144.0, total_mb=216.0, red=29.2),
             768: dict(fm_mb=344.0, total_mb=475.0, red=27.6)}
    rows = []
    for size, p in paper.items():
        plan = _plan("efficientnet-b1", size)
        rows += [
            Row("tableVII", f"efficientnet-b1@{size}", "offchip_fm_mb",
                round(plan.dram.fm_bytes / MB, 2), p["fm_mb"]),
            Row("tableVII", f"efficientnet-b1@{size}", "baseline_mb",
                round(plan.baseline_dram / MB, 1), p["total_mb"]),
            Row("tableVII", f"efficientnet-b1@{size}", "reduction_pct",
                round(100 * plan.offchip_reduction, 2), p["red"]),
        ]
    return rows


def fig16_yolov2_cutpoint_sweep() -> list[Row]:
    """Fig 16: YOLOv2 latency/SRAM/DRAM vs single cut position; paper
    reports 2.17x speedup and 5.73x smaller buffer vs all-row baseline."""
    g = build_cnn("yolov2", 416)
    gg = group_nodes(g)
    cands = sweep_single_cut(gg, KCU1500)
    all_row = cands[-1]                    # cut at the end => all row
    feas = [c for c in cands if c.feasible]
    best = min(feas, key=lambda c: c.latency_cycles)
    speedup = all_row.latency_cycles / best.latency_cycles
    min_sram = _plan("yolov2", 416, objective="sram").sram.sram_total
    return [
        Row("fig16", "yolov2", "speedup_vs_allrow", round(speedup, 2), 2.17),
        Row("fig16", "yolov2", "min_sram_mb",
            round(min_sram / MB, 3), 0.762),
        Row("fig16", "yolov2", "n_cut_candidates", len(cands), None),
    ]


def fig17_cutpoint_tradeoffs() -> list[Row]:
    """Fig 17: frame-early cut trades buffer size for latency/DRAM."""
    rows = []
    for name, size in [("yolov3", 416), ("resnet152", 256),
                       ("efficientnet-b1", 256)]:
        gg = group_nodes(build_cnn(name, size))
        cands = sweep_single_cut(gg, KCU1500)
        lat = [c.latency_cycles for c in cands]
        dram = [c.dram_total for c in cands]
        # paper's qualitative claim: earliest cut (all frame) is fastest
        # and lowest-DRAM, at the cost of buffer size
        rows.append(Row("fig17", name, "latency_monotone_nondec",
                        float(all(lat[i] <= lat[i + 1] + 1e6
                                  for i in range(len(lat) - 1))), 1.0))
        rows.append(Row("fig17", name, "dram_monotone_nondec",
                        float(all(dram[i] <= dram[i + 1]
                                  for i in range(len(dram) - 1))), 1.0))
    return rows


def extra_mobilenetv3() -> list[Row]:
    """Beyond-paper: MobileNetV3-Large (the paper's Fig. 1 block) through
    the same optimizer -- no published numbers, ours recorded."""
    plan = _plan("mobilenet-v3", 224)
    plan_min = _plan("mobilenet-v3", 224, objective="sram")
    return [
        Row("extra", "mobilenet-v3", "latency_ms",
            round(plan.latency_ms, 2), None),
        Row("extra", "mobilenet-v3", "offchip_fm_mb",
            round(plan.dram.fm_bytes / MB, 2), None),
        Row("extra", "mobilenet-v3", "offchip_reduction_pct",
            round(100 * plan.offchip_reduction, 2), None),
        Row("extra", "mobilenet-v3", "min_buffer_mb",
            round(plan_min.sram.sram_total / MB, 3), None),
    ]


ALL_TABLES = [table2_resnet152, table3_min_buffers, table4_vgg,
              table5_cnn_performance, table7_efficientnet_scaling,
              fig16_yolov2_cutpoint_sweep, fig17_cutpoint_tradeoffs,
              extra_mobilenetv3]
